"""Tacotron2-style decoder personalization (paper §5.2, Fig. 14).

The recurrent decoder (prenet -> 2 LSTM -> mel projection) is time-unrolled
by the Recurrent realizer; unrolled copies share weights via Tensor-sharing
mode E and accumulate gradients across time (Iteration lifespan) — the
optimizer applies them once per iteration, exactly as the paper describes
for Tacotron2 on NNTrainer.

    PYTHONPATH=src python examples/tts_unroll.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planned_exec import planned_loss_and_grads, sgd_update
from repro.core.zoo import tacotron2_decoder


def main() -> None:
    steps = 4
    cp = compile_plan(
        tacotron2_decoder(time_steps=steps, mel_dim=16, prenet_dim=48,
                          lstm_dim=48),
        MemoryPlanConfig(swap=False), batch=16)
    g = cp.graph

    # E-mode weight sharing: unrolled LSTM copies own NO extra weight memory
    shared = [n for n, t in cp.ordered.tensors.items()
              if n.startswith("W:") and t.merged_into]
    owned = [n for n, t in cp.ordered.tensors.items()
             if n.startswith("W:") and not t.merged_into]
    print(f"{steps}x unrolled: {len(owned)} owned weight tensors, "
          f"{len(shared)} E-shared views (zero extra bytes)")
    print(f"planned peak: {cp.plan.total_bytes/2**20:.2f} MiB")

    # teacher-forced mel regression on a synthetic voice-like target
    params = cp.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mel_in = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    target = jnp.tanh(mel_in * 0.7 + 0.2)            # fixed mapping to learn

    losses = []
    for it in range(300):
        loss, grads = planned_loss_and_grads(g, params, mel_in, target)
        # gradient clipping (paper: supported for the unrolled decoder)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                             for x in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, 5.0 / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda x: x * scale, grads)
        params = sgd_update(params, grads, lr=0.5)
        losses.append(float(loss))
    print(f"teacher-forced training: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    # the tied-weight unrolled stack is a hard function class; the
    # demo's point is the E-sharing mechanics (grads validated in tests)
    assert losses[-1] < losses[0] * 0.9


if __name__ == "__main__":
    main()
