"""End-to-end driver: pretrain a ~100M-parameter LM for a few hundred steps.

Uses the full production stack — config system, data pipeline with batch
queue, sharded jit train step (DP x TP on the local mesh), AdamW, async
checkpointing, heartbeats and the straggler watchdog — scaled to whatever
devices are present.  On a real pod, replace make_test_mesh with
make_production_mesh and raise the shape.

    PYTHONPATH=src python examples/distributed_pretrain.py [--steps 300]
"""

import argparse
import tempfile

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig


def make_100m_config() -> ModelConfig:
    # ~103M params: 12L, d=640, untied 16k vocab
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab=16128,
        attention_impl="naive", remat=False, dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_100m_config()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    mesh = make_test_mesh(model=1)
    shape = ShapeConfig("pretrain", args.seq_len, args.batch, "train")
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro100m_")
    tcfg = TrainerConfig(steps=args.steps, log_every=10,
                         ckpt_every=100, ckpt_dir=ckpt_dir,
                         heartbeat_dir=ckpt_dir + "/hb")
    trainer = Trainer(model, make_optimizer("adamw", lr=1e-3), mesh, shape,
                      tcfg)
    out = trainer.run()
    first = out["history"][0]["loss"]
    print(f"\nloss {first:.3f} -> {out['final_loss']:.3f} "
          f"over {args.steps} steps; checkpoints in {ckpt_dir}")
    assert out["final_loss"] < first


if __name__ == "__main__":
    main()
