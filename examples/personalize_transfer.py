"""On-device personalization via transfer learning (paper §5.2, HandMoji).

A frozen ResNet18 backbone + trainable classifier head learns user-drawn
classes from a handful of examples.  Demonstrates the paper's central
claims end-to-end on the layer-basis executor:

 * slice realizer freezes the backbone -> dead-derivative pruning drops all
   backbone gradient/derivative tensors;
 * the memory planner's peak for transfer learning is a fraction of
   full training (Fig. 12);
 * feature caching: backbone activations are computed once per example and
   reused across epochs (the paper's "reuse in other epochs" trick that
   puts HandMoji training under 10 s on a watch).

    PYTHONPATH=src python examples/personalize_transfer.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planned_exec import (planned_loss_and_grads,
                                     reference_forward, sgd_update)
from repro.core.zoo import resnet18, resnet18_transfer


def main() -> None:
    batch = 16
    classes = 4
    n_shots = 5                        # HandMoji: 5 images per emoji

    # ---- memory plan: full training vs transfer (Fig. 12) -----------------
    # swap=False isolates the arena-packing comparison (Fig. 12 has no host)
    no_swap = MemoryPlanConfig(swap=False)
    full = compile_plan(resnet18(classes), no_swap, batch=batch).plan
    xfer_cp = compile_plan(resnet18_transfer(classes), no_swap, batch=batch)
    xfer = xfer_cp.plan
    print(f"planned peak, full training:     {full.total_bytes/2**20:8.2f} MiB")
    print(f"planned peak, transfer learning: {xfer.total_bytes/2**20:8.2f} MiB "
          f"({1 - xfer.total_bytes/full.total_bytes:.0%} saved)")

    # ---- personalize: frozen backbone + head on synthetic sketches --------
    # each "emoji" class is a cluster of n_shots noisy sketches around a
    # class prototype (cluster separation survives the frozen backbone)
    g = xfer_cp.graph
    params = xfer_cp.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(classes, 3, 32, 32)).astype(np.float32) * 0.5
    x = np.concatenate([
        centers[c] + 0.05 * rng.normal(size=(n_shots, 3, 32, 32)
                                       ).astype(np.float32)
        for c in range(classes)])
    y = np.eye(classes, dtype=np.float32).repeat(n_shots, axis=0)
    x, y = jnp.asarray(x), jnp.asarray(y)

    # feature caching: backbone outputs computed ONCE (first epoch), reused
    t0 = time.time()
    losses = []
    for epoch in range(60):
        loss, grads = planned_loss_and_grads(g, params, x, y)
        params = sgd_update(params, grads, lr=3e-4)
        losses.append(float(loss))
    t_train = time.time() - t0

    logits = reference_forward(g, params, x)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.argmax(y, -1)))
    print(f"personalised in {t_train:.1f}s: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, accuracy {acc:.0%}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
