"""Quickstart: train a small LM with the full stack (data pipeline ->
sharded train step -> checkpoint -> restore), on whatever devices exist,
then compile a layer-basis graph down to its lowered ExecutionSchedule,
prove it memory-safe with the static verifier (``repro.core.verify``,
on by default via ``MemoryPlanConfig(verify="error")``), and replay it
on the async device-stream executor backend
(``MemoryPlanConfig(executor="async")``), printing the overlap report.
Then compile vgg16 with planner-managed optimizer-state offload
(``MemoryPlanConfig(optim_offload=True)``) and print the plan summary:
AdamW moments packed into their own arenas with int8 host copies.
Finally, serve N simulated users through the multi-tenant
personalization service (``repro.serve``): shared compiled plans per
batch bucket, admission-controlled arena shares, pad-to-bucket batching —
then drain the same service phase-interleaved with two QoS classes over
an emulated bus, printing how much of one tenant's DMA the scheduler hid
under other tenants' compute.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.configs import ARCHS
from repro.models.model import build_model, reduce_config
from repro.train.trainer import quick_train


def graph_plan_demo() -> None:
    """The layer-basis path: one compile step from graph to executor ops,
    with the pinned-host pool packed by its own allocator."""
    from repro.core import MemoryPlanConfig, compile_plan
    from repro.core.zoo import ZOO

    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                         min_idle_phases=3, min_bytes=1 << 12),
        batch=16)
    r = cp.report()
    print(f"== lenet5 graph plan (planner={r['planner']}, "
          f"host_planner={r['host_planner']}) ==")
    print(f"peak={r['peak_bytes'] / 2**20:.2f} MiB "
          f"(baseline {r['baseline_peak_bytes'] / 2**20:.2f}) "
          f"host={r['host_pool_bytes'] / 2**20:.2f} MiB "
          f"dma={r['dma_bytes'] / 2**20:.2f} MiB")
    print(f"device_utilization={r['device_utilization']:.3f} "
          f"host_utilization={r['host_utilization']:.3f} "
          f"inplace_prefetches={r['inplace_prefetch_count']}")
    print(f"lowered schedule ops: {r['schedule_ops']}")
    for op in cp.lowered.transfers()[:4]:
        print(f"  {type(op).__name__:8s} eo={op.eo:3d} {op.tensor} "
              f"dev@{op.device_offset} host@{op.host_offset}")
    # every compile runs the static verifier (MemoryPlanConfig(verify=
    # "error"), the default): the lowered schedule was proven memory-safe
    # before any op could execute, and the report travels with the plan
    v = r["verify"]
    print(f"verified: ok={v['ok']} checks={','.join(v['checks_run'])} "
          f"ops_scanned={v['ops_scanned']} "
          f"wall={v['wall_time_s'] * 1e3:.1f} ms")
    # the static dependence analyser rides the same compile (the deps
    # knob, on by default): happens-before DAG edge counts, the fusion
    # plan the jit_blocks executor would dispatch, and how much slack
    # each prefetch has before its consumer
    d = r["deps"]
    f = d["fusion"]
    print(f"deps: edges={d['edges']} "
          f"prefetch_slack_min={d['min_prefetch_slack_phases']} phases")
    print(f"fusion plan: {f['n_blocks']} blocks covering "
          f"{f['fused_computes']}/{f['n_computes']} computes "
          f"(largest {f['largest_block']}), dispatch_calls="
          f"{f['dispatch_calls']} vs {f['n_ops']} ops, "
          f"splits={f['splits']}")


def verify_demo() -> None:
    """The static verifier catching a forged corruption: drop one Prefetch
    from a lowered schedule and the use-before-resident checker names the
    tensor and phases in a structured Diagnostic, e.g.

        [error:use_before_resident] X:conv1: read at EO 11 while swapped
        out since EO 3 with no prefetch in between
    """
    from repro.core import MemoryPlanConfig, compile_plan
    from repro.core.plan import ExecutionSchedule, Prefetch
    from repro.core.verify import verify_schedule
    from repro.core.zoo import ZOO

    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                         min_idle_phases=3, min_bytes=1 << 12),
        batch=16)
    dropped = next(op for op in cp.lowered.ops if isinstance(op, Prefetch))
    forged = ExecutionSchedule(
        ops=tuple(op for op in cp.lowered.ops if op is not dropped))
    report = verify_schedule(cp.ordered, cp.schedule, cp.plan, forged)
    print("== verifier vs a forged schedule (one Prefetch dropped) ==")
    for d in report.errors()[:3]:
        print(f"  {d.render()}")
    assert not report.ok and "use_before_resident" in report.check_ids()


def async_exec_demo() -> None:
    """The async device-stream backend: the same compiled plan, but every
    SwapOut/Prefetch is a real jax.device_put against the device's host
    memory space, dispatched ahead of need and fenced at the consumer."""
    import jax
    import jax.numpy as jnp

    from repro.core import MemoryPlanConfig, compile_plan
    from repro.core.zoo import ZOO

    g = ZOO["lenet5"]()
    cp = compile_plan(
        g, MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12,
                            executor="async"),
        batch=16)
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(16) % 10, 10)
    loss, _, stats = cp.loss_and_grads(params, x, y)
    ex = cp.report()["exec"]      # the backend's post-run overlap report
    print(f"== lenet5 async executor (loss={float(loss):.3f}) ==")
    print(f"backend={ex['backend']} host_memory={ex['host_memory_kind']} "
          f"transfers={ex['swap_outs']}+{ex['prefetches']} "
          f"dma={ex['dma_bytes'] / 2**20:.2f} MiB")
    overlap = ex["achieved_overlap"]
    print(f"achieved_overlap="
          f"{'n/a' if overlap is None else format(overlap, '.2f')} "
          f"stalled_fences={ex['stalled_fences']} "
          f"inflight_high_water={ex['inflight_high_water'] / 2**20:.2f} MiB "
          f"(planned {ex['planned_peak_inflight_prefetch'] / 2**20:.2f} MiB)")
    assert stats.replayed_ops == cp.lowered.ops


def optim_offload_demo() -> None:
    """Planner-managed optimizer-state offload: the AdamW moments are
    first-class in the memory plan — tagged as ``O:<layer>`` slots in the
    EO graph, priced by the joint cost model, packed into their own
    device/host arenas, and lowered to typed OptPrefetch/OptSwapOut ops.
    The host copy is int8 block-scaled with error feedback, so the device
    keeps only a small rotating working region instead of the full fp32
    moment tree."""
    from repro.core import MemoryPlanConfig, compile_plan
    from repro.core.plan import OptPrefetch, OptSwapOut
    from repro.core.zoo import ZOO

    MIB = 2 ** 20
    cp = compile_plan(
        ZOO["vgg16"](),
        MemoryPlanConfig(optim_offload=True, min_idle_phases=3,
                         min_bytes=1 << 12),
        batch=4)
    s = cp.optim_plan.summary()
    print("== vgg16 optimizer-state offload (AdamW moments) ==")
    print(f"slots={s['n_slots']} "
          f"resident={s['resident_bytes'] / MIB:.1f} MiB -> "
          f"device working region {s['device_peak_bytes'] / MIB:.1f} MiB "
          f"({s['reduction_x']:.2f}x reduction)")
    print(f"host copies: int8+scales {s['host_pool_bytes'] / MIB:.1f} MiB "
          f"vs fp32 {s['host_fp32_bytes'] / MIB:.1f} MiB, "
          f"dma/step={s['dma_bytes_per_step'] / MIB:.1f} MiB "
          f"(est {s['est_dma_s_per_step'] * 1e3:.2f} ms)")
    n_pre = sum(isinstance(op, OptPrefetch) for op in cp.lowered.ops)
    n_out = sum(isinstance(op, OptSwapOut) for op in cp.lowered.ops)
    v = cp.report()["verify"]
    print(f"lowered: {n_pre} OptPrefetch + {n_out} OptSwapOut ops, "
          f"verified ok={v['ok']} "
          f"({len(v['checks_run'])} checks incl. optim_region)")
    assert cp.optim_plan.reduction_x >= 3.0
    assert v["ok"] and "optim_region" in v["checks_run"]


def serve_demo() -> None:
    """Serve N users: multi-tenant personalization over one device arena.
    Every user shares the frozen base tree and one compiled plan per batch
    bucket; admission control splits the arena between live sessions."""
    from repro.core.zoo import ZOO
    from repro.serve import PersonalizationService
    from repro.serve.buckets import dummy_batch

    g = ZOO["lenet5"]()
    svc = PersonalizationService(g, buckets=(8, 16), max_live_sessions=4)
    svc.warmup()
    print("== serving 4 users over 2 buckets (lenet5) ==")
    for u in range(4):
        n = 5 if u % 2 else 12        # short batches pad up to a bucket
        res = svc.submit(f"user{u}", *dummy_batch(g, n, seed=u))
        print(f"  user{u}: {res.status} bucket={res.bucket} "
              f"loss={res.loss:.3f} peak={res.peak_bytes} "
              f"share={res.arena_share_bytes}")
        assert res.ok and res.peak_bytes <= res.arena_share_bytes
    rep = svc.report()
    cache, adm = rep["plan_cache"], rep["admission"]
    print(f"plan cache: {cache['entries']} plans for "
          f"{adm['live_sessions']} sessions "
          f"(hits={cache['hits']} misses={cache['misses']}), "
          f"arena share={adm['arena_share_bytes']} B/session, "
          f"deadlocks={rep['serve']['deadlocks']}")


def concurrent_serve_demo() -> None:
    """Phase-interleaved concurrent serving: two QoS classes share the
    device over an emulated UFS-class bus.  The scheduler round-robins
    every live session's cursor at phase boundaries, so one tenant's
    swap/prefetch DMA streams while another tenant's compute runs — the
    report shows how much bus time that interleaving hid."""
    from repro.core import MemoryPlanConfig
    from repro.core.zoo import ZOO
    from repro.serve import PersonalizationService, QosClass
    from repro.serve.buckets import dummy_batch

    g = ZOO["lenet5"]()
    qos = (QosClass("premium", 2.0, slots=1),
           QosClass("standard", 1.0, slots=3))
    svc = PersonalizationService(
        g, buckets=(8, 16), max_live_sessions=4, qos=qos,
        interleave=True, bus_gbps=0.2, bus_latency_s=0.004,
        config=MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12))
    svc.warmup()
    print("== concurrent serving: 4 users, premium + standard QoS ==")
    reqs = [svc.enqueue(f"user{u}", *dummy_batch(g, 12, seed=u),
                        qos="premium" if u == 0 else "standard")
            for u in range(4)]
    svc.drain()                    # one interleaved stream, all sessions
    for u, req in enumerate(reqs):
        res = req.result
        print(f"  user{u} [{res.qos}]: {res.status} loss={res.loss:.3f} "
              f"share={res.arena_share_bytes} B "
              f"queue_wait={res.queue_wait_s * 1e3:.1f} ms")
        assert res.ok and res.peak_bytes <= res.arena_share_bytes
    rep = svc.report()
    sched = rep["scheduler"]
    hidden = sched["hidden_dma_s"] + sched["opt_hidden_dma_s"]
    exposed = sched["exposed_dma_s"] + sched["opt_exposed_dma_s"]
    print(f"hidden bus time: {hidden * 1e3:.1f} ms under compute "
          f"({sched['cross_hidden_dma_s'] * 1e3:.1f} ms under other "
          f"sessions'), exposed {exposed * 1e3:.1f} ms, "
          f"verify_errors={sched['verify_errors']}")
    for name, q in rep["serve"]["by_qos"].items():
        print(f"  qos {name}: completed={q['completed']} "
              f"bypassed_phases={q['bypassed_phases']}")


def main() -> None:
    # remat=True so the compiled memory plan has real keep/offload content
    cfg = reduce_config(ARCHS["llama3.2-3b"], n_layers=2, d_model=64,
                        vocab=512, remat=True)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        print(f"== training reduced {cfg.name} "
              f"({build_model(cfg) and cfg.n_layers}L d={cfg.d_model}) ==")
        out = quick_train(cfg, steps=30, seq_len=64, global_batch=8,
                          ckpt_dir=ckpt_dir)
        # the train step compiled its memory plan through compile_plan;
        # the report travels with the run result
        mp = out["memory_plan"]
        print(f"memory plan: peak={mp['peak_bytes'] / 2**20:.2f} MiB "
              f"decisions={mp.get('remat_decisions', {})} "
              f"dma={mp.get('dma_bytes', 0) / 2**20:.2f} MiB "
              f"recompute_flops/layer="
              f"{mp.get('recompute_flops_per_layer', 0.0):.3g}")
        first = out["history"][0]["loss"]
        print(f"loss: {first:.3f} -> {out['final_loss']:.3f}")
        assert out["final_loss"] < first, "training did not reduce loss"

        # resume from the checkpoint and keep training
        print("== resuming from checkpoint ==")
        out2 = quick_train(cfg, steps=40, seq_len=64, global_batch=8,
                           ckpt_dir=ckpt_dir)
        print(f"resumed loss: {out2['final_loss']:.3f}")

    graph_plan_demo()
    verify_demo()
    async_exec_demo()
    optim_offload_demo()
    serve_demo()
    concurrent_serve_demo()


if __name__ == "__main__":
    main()
