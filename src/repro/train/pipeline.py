"""GPipe-style pipeline parallelism over a mesh axis.

Layers are split into S contiguous stages along the ``stage`` mesh axis
(on the production mesh this is typically "pod" — stages map across pods,
with DP/TP inside each).  The global batch is split into M microbatches;
a fill-drain schedule runs T = M + S - 1 ticks, forwarding activations
between neighbouring stages with ``lax.ppermute`` each tick.

Differentiable end-to-end: the backward pass through ``ppermute`` is the
reverse permute, so ``jax.grad`` of a pipelined loss yields the classic
GPipe backward schedule automatically — no manual bwd plumbing.

Bubble fraction = (S-1) / (M + S - 1); the builder warns when M < 4*S.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_mb: jax.Array, *,
                   mesh: Mesh, axis: str = "stage") -> jax.Array:
    """Run microbatches through the pipeline.

    stage_fn: (params_for_stage, activation) -> activation
    stage_params: pytree with leading dim == n_stages (sharded over axis)
    x_mb: (M, mb_size, ...) microbatched input (replicated across stages)
    returns: (M, mb_size, ...) outputs (replicated; produced by last stage)
    """
    from jax.experimental.shard_map import shard_map

    n_stages = mesh.shape[axis]
    n_mb = x_mb.shape[0]
    ticks = n_mb + n_stages - 1

    pspec = jax.tree_util.tree_map(
        lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_rep=False)
    def run(params, xs):
        idx = jax.lax.axis_index(axis)
        local = jax.tree_util.tree_map(lambda a: a[0], params)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            incoming, outputs = carry
            # stage 0 ingests microbatch t (when available)
            mb_idx = jnp.clip(t, 0, n_mb - 1)
            mb = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            x_in = jnp.where(idx == 0, mb, incoming)
            y = stage_fn(local, x_in)
            # last stage banks its result for microbatch t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_mb - 1)
            bank = (idx == n_stages - 1) & (t >= n_stages - 1)
            upd = jnp.where(bank, y, jax.lax.dynamic_index_in_dim(
                outputs, out_idx, 0, keepdims=False))
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, upd, out_idx, 0)
            # forward the activation to the next stage
            nxt = jax.lax.ppermute(y, axis, fwd_perm)
            return (nxt, outputs), None

        init = (jnp.zeros_like(xs[0]),
                jnp.zeros((n_mb,) + xs.shape[1:], xs.dtype))
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
        # broadcast last stage's outputs to every stage (replicated out)
        outputs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outputs, 0), axis)
        return outputs

    return run(stage_params, x_mb)


def pipeline_loss(stage_fn: Callable, loss_fn: Callable, stage_params,
                  x_mb, y_mb, *, mesh: Mesh, axis: str = "stage"):
    """Mean loss over microbatches through the pipeline (differentiable)."""
    outs = pipeline_apply(stage_fn, stage_params, x_mb, mesh=mesh, axis=axis)
    return jnp.mean(jax.vmap(loss_fn)(outs, y_mb))


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def split_microbatches(x: jax.Array, n_mb: int) -> jax.Array:
    assert x.shape[0] % n_mb == 0
    return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])
