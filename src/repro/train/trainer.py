"""Training loop: step function + data pipeline + checkpoint + fault runtime.

Composes the substrates into the production loop:

    restore-or-init -> [train_step -> heartbeat -> watchdog -> ckpt]* -> final

The loop is host-local (each host feeds its DP slice); collectives inside
the jitted step do the cross-host work.  Works identically on the 1-device
CPU test mesh and the 512-chip production mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import BatchQueue, DataState, synthetic_lm_producer
from repro.models.model import Model, build_model
from repro.optim import Optimizer, make_optimizer
from repro.runtime.fault import Heartbeat, StepWatchdog
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    heartbeat_dir: Optional[str] = None
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, optimizer: Optimizer, mesh,
                 shape: ShapeConfig, tcfg: TrainerConfig, *,
                 producer=None, microbatches: int = 1):
        self.model = model
        self.optimizer = optimizer
        self.mesh = mesh
        self.shape = shape
        self.tcfg = tcfg
        self.bundle = make_train_step(model, optimizer, mesh, shape,
                                      microbatches=microbatches)
        self.step_fn = jax.jit(self.bundle.fn,
                               in_shardings=self.bundle.in_shardings,
                               out_shardings=self.bundle.out_shardings,
                               donate_argnums=self.bundle.donate_argnums)
        self.ckpt = CheckpointManager(
            tcfg.ckpt_dir, keep=tcfg.keep_ckpts, host_id=tcfg.host_id,
            n_hosts=tcfg.n_hosts) if tcfg.ckpt_dir else None
        self.hb = Heartbeat(tcfg.heartbeat_dir, tcfg.host_id) \
            if tcfg.heartbeat_dir else None
        self.watchdog = StepWatchdog()
        cfg = model.cfg
        self.producer = producer or synthetic_lm_producer(
            cfg.vocab, shape.seq_len)
        self.history: list = []

    # ------------------------------------------------------------------ run
    def init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = self.optimizer.init(params)
        return params, opt_state

    def run(self) -> Dict[str, Any]:
        tcfg = self.tcfg
        start_step = 0
        data_state = DataState()
        params = opt_state = None

        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            tmpl_p, tmpl_o = jax.eval_shape(self.init_state)
            (params, opt_state), ds = self.ckpt.restore(
                step, (tmpl_p, tmpl_o),
                (self.bundle.in_shardings[0], self.bundle.in_shardings[1]))
            if ds:
                data_state = DataState.from_dict(ds)
            start_step = step
        if params is None:
            params, opt_state = self.init_state()

        host_batch = self.shape.global_batch // tcfg.n_hosts
        queue = BatchQueue(self.producer, batch=host_batch,
                           state=data_state)
        try:
            loss = None
            for step in range(start_step, tcfg.steps):
                np_batch, data_state = queue.get()
                batch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
                t0 = time.time()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                self.watchdog.record(step, dt, slowest_host=tcfg.host_id)
                if self.hb:
                    self.hb.beat(step)
                if step % tcfg.log_every == 0:
                    self.history.append(
                        {"step": step, "loss": loss, "time_s": dt,
                         "grad_norm": float(metrics["grad_norm"])})
                    print(f"step {step:6d} loss {loss:9.4f} "
                          f"gnorm {float(metrics['grad_norm']):9.3f} "
                          f"{dt*1000:8.1f} ms", flush=True)
                if self.ckpt and step > start_step \
                        and step % tcfg.ckpt_every == 0:
                    self.ckpt.save(step, (params, opt_state),
                                   data_state.as_dict())
            if self.ckpt:
                self.ckpt.save(tcfg.steps, (params, opt_state),
                               data_state.as_dict(), blocking=True)
            return {"params": params, "opt_state": opt_state,
                    "final_loss": loss, "history": self.history,
                    "memory_plan": (self.bundle.memory_plan.report()
                                    if self.bundle.memory_plan else None)}
        finally:
            queue.close()


def quick_train(cfg: ModelConfig, *, steps: int = 20, seq_len: int = 32,
                global_batch: int = 8, ckpt_dir: Optional[str] = None,
                microbatches: int = 1, optimizer: str = "adamw") -> Dict:
    """Single-host convenience wrapper used by examples and tests."""
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh(model=1)
    model = build_model(cfg)
    opt = make_optimizer(optimizer) if optimizer != "sgd" \
        else make_optimizer("sgd")
    shape = ShapeConfig("custom", seq_len, global_batch, "train")
    tcfg = TrainerConfig(steps=steps, ckpt_every=max(steps // 2, 1),
                         ckpt_dir=ckpt_dir, log_every=max(steps // 10, 1))
    trainer = Trainer(model, opt, mesh, shape, tcfg,
                      microbatches=microbatches)
    return trainer.run()
