"""Sharded train / serve step builders.

``make_train_step``: jit-able (params, opt_state, batch) -> (params,
opt_state, metrics) with optional microbatched gradient accumulation
(a ``lax.scan`` over batch chunks — the distributed analogue of the paper's
Iteration-lifespan gradient tensors: one persistent gradient buffer,
updated once per iteration).

``make_serve_step``: prefill (batch -> logits) and decode (one token with a
KV/state cache) steps.

All shardings are assembled here from the logical-axis spec trees; the
functions are pure and lower cleanly under ``jax.jit(...).lower()`` for the
multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.core.deprecation import warn_once
from repro.core.plan import CompiledMemoryPlan, MemoryPlanConfig, compile_plan
from repro.core.remat_policy import RematPlan
from repro.models.model import Model, input_specs
from repro.optim import Optimizer
from repro.sharding import rules as R
from repro.sharding.api import (activation_rules, param_shardings,
                                tree_shardings)


@dataclasses.dataclass
class StepBundle:
    """Everything the launcher / dry-run needs for one (arch, shape) cell."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    abstract_args: Tuple[Any, ...]
    act_rules: Dict
    mesh: Mesh
    # The compiled memory plan whose ``.offload_policy`` the model's
    # checkpoint policy installs inside the jitted step (None for serve
    # steps).  Produced by ``repro.core.compile_plan`` — the single owner
    # of remat/offload decisions.
    memory_plan: Optional[CompiledMemoryPlan] = None

    @property
    def remat_plan(self) -> Optional[RematPlan]:
        """Deprecated alias for ``memory_plan.remat_plan`` (warns once per
        call site)."""
        warn_once(
            "StepBundle.remat_plan is deprecated; read "
            "StepBundle.memory_plan.remat_plan (the compiled "
            "CompiledMemoryPlan owns the remat/offload decisions)",
            DeprecationWarning, stacklevel=2)
        return self.memory_plan.remat_plan if self.memory_plan else None


def _batch_shardings(mesh: Mesh, specs, act_rules):
    def one(aval):
        if aval.ndim == 0:
            return NamedSharding(mesh, P())
        batch_axes = act_rules.get("batch")
        if batch_axes is None:
            return NamedSharding(mesh, P())
        size = 1
        for a in batch_axes:
            size *= mesh.shape[a]
        if aval.shape[0] % size != 0:
            return NamedSharding(mesh, P())
        spec = [tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]]
        spec += [None] * (aval.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, specs)


def opt_state_spec_tree(opt_state, param_spec_tree):
    """Logical specs for the optimizer state, mirroring the param tree.

    fp32/bf16 moments reuse the parameter's logical axes; int8-quantised
    moments get ("qblocks", None) — the flat block dim shards over
    (data, model) jointly (ZeRO across the whole mesh)."""
    def specs_for(mu_entry, pspec):
        def one_moment(m):
            if isinstance(m, dict):   # quantised {"q", "scale"}
                return {"q": ("qblocks", None), "scale": ("qblocks", None)}
            return tuple(pspec)
        return {k: one_moment(v) for k, v in mu_entry.items()}

    is_param_leaf = lambda v: isinstance(v, tuple)
    flat_p, tdef = jax.tree_util.tree_flatten(param_spec_tree,
                                              is_leaf=is_param_leaf)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    mu_specs = tdef.unflatten(
        [specs_for(mu, ps) for mu, ps in zip(flat_mu, flat_p)])
    out = {"mu": mu_specs}
    for k in opt_state:
        if k not in ("mu",):
            out[k] = ()
    return out


def make_train_step(model: Model, optimizer: Optimizer, mesh: Mesh,
                    shape: ShapeConfig, *, microbatches: int = 1,
                    plan_config: Optional[MemoryPlanConfig] = None
                    ) -> StepBundle:
    """Build the sharded train step for one (arch, shape) cell.

    Pod topology comes from ``mesh`` (a multi-pod mesh carries its own
    "pod" axis); there is no separate multi-pod switch here.  The memory
    plan is compiled from the ``ModelConfig`` remat/offload knobs — the
    same knobs the model's own checkpoint policy reads — so the reported
    ``memory_plan`` always matches what the jitted step installs.  With
    ``cfg.offload`` on, that plan is the joint keep/recompute/offload
    decision priced by ``cfg.dma_gbps``/``cfg.device_tflops``; its honest
    costs (``dma_bytes``, ``recompute_flops_per_layer``) travel with the
    bundle's ``memory_plan.report()``.  ``plan_config`` overrides
    individual :class:`MemoryPlanConfig` knobs (hardware cost model,
    budgets) without touching the ``ModelConfig`` — the remat/offload
    resolution order (explicit knob, else ``cfg``) is unchanged.  The
    ``plan_config.executor`` knob travels with the compiled plan (and is
    validated at compile time): model-path plans install a checkpoint
    policy rather than running the layer-basis executor, but a graph plan
    derived from the same config replays on the selected backend
    ("sim" | "async" — see ``repro.core.exec.backends``).
    """
    cfg = model.cfg
    act_rules = activation_rules(cfg, shape, mesh)
    act_rules["qblocks"] = ("data", "model")

    abstract_p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs()
    p_shard = param_shardings(mesh, cfg, p_specs, abstract_p, zero1=False)

    abstract_opt = jax.eval_shape(lambda: optimizer.init(abstract_p))
    o_specs = opt_state_spec_tree(abstract_opt, p_specs)
    o_shard = tree_shardings(
        mesh, o_specs,
        {**act_rules, "embed": ("data",), "qblocks": ("data", "model")},
        abstract_opt)

    batch_specs = input_specs(cfg, shape)
    b_shard = _batch_shardings(mesh, batch_specs, act_rules)

    def train_step(params, opt_state, batch):
        with R.use_mesh(mesh, act_rules):
            if microbatches > 1:
                def split(x):
                    return x.reshape((microbatches,
                                      x.shape[0] // microbatches)
                                     + x.shape[1:])
                mb = jax.tree_util.tree_map(split, batch)

                def accum(carry, mbatch):
                    gsum, lsum = carry
                    loss, g = jax.value_and_grad(model.loss_fn)(params, mbatch)
                    gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                    return (gsum, lsum + loss), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), mb)
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatches, gsum)
                loss = lsum / microbatches
            else:
                loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)))
            return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P())}
    micro_tokens = (shape.global_batch // max(microbatches, 1)) * shape.seq_len
    return StepBundle(
        fn=train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1),
        abstract_args=(abstract_p, abstract_opt, batch_specs),
        act_rules=act_rules,
        mesh=mesh,
        memory_plan=compile_plan(cfg, plan_config, batch_tokens=micro_tokens),
    )


def make_prefill_step(model: Model, mesh: Mesh,
                      shape: ShapeConfig) -> StepBundle:
    cfg = model.cfg
    act_rules = activation_rules(cfg, shape, mesh)
    abstract_p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs()
    p_shard = param_shardings(mesh, cfg, p_specs, abstract_p)
    batch_specs = input_specs(cfg, shape)
    b_shard = _batch_shardings(mesh, batch_specs, act_rules)

    def prefill(params, batch):
        with R.use_mesh(mesh, act_rules):
            return model.forward(params, batch)

    logits_spec = NamedSharding(
        mesh, P(act_rules["batch"] if act_rules["batch"] else None,
                None, "model"))
    return StepBundle(
        fn=prefill,
        in_shardings=(p_shard, b_shard),
        out_shardings=logits_spec,
        donate_argnums=(),
        abstract_args=(abstract_p, batch_specs),
        act_rules=act_rules,
        mesh=mesh,
    )


def make_decode_step(model: Model, mesh: Mesh,
                     shape: ShapeConfig) -> StepBundle:
    cfg = model.cfg
    act_rules = activation_rules(cfg, shape, mesh)
    abstract_p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs()
    p_shard = param_shardings(mesh, cfg, p_specs, abstract_p)

    abstract_state = jax.eval_shape(
        lambda: model.decode_init(shape.global_batch, shape.seq_len))
    s_specs = model.decode_specs()
    s_shard = tree_shardings(mesh, s_specs, act_rules, abstract_state)

    tok_specs = input_specs(cfg, shape)
    t_shard = _batch_shardings(mesh, tok_specs, act_rules)

    def decode(params, state, batch):
        with R.use_mesh(mesh, act_rules):
            return model.decode_fn(params, state, batch["tokens"],
                                   batch["cache_len"])

    logits_spec = NamedSharding(
        mesh, P(act_rules["batch"] if act_rules["batch"] else None, "model"))
    return StepBundle(
        fn=decode,
        in_shardings=(p_shard, s_shard, t_shard),
        out_shardings=(logits_spec, s_shard),
        donate_argnums=(1,),
        abstract_args=(abstract_p, abstract_state, tok_specs),
        act_rules=act_rules,
        mesh=mesh,
    )


def build_step(model: Model, optimizer: Optional[Optimizer], mesh: Mesh,
               shape: ShapeConfig, *, microbatches: int = 1,
               plan_config: Optional[MemoryPlanConfig] = None) -> StepBundle:
    if shape.kind == "train":
        assert optimizer is not None
        return make_train_step(model, optimizer, mesh, shape,
                               microbatches=microbatches,
                               plan_config=plan_config)
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, shape)
    return make_decode_step(model, mesh, shape)


def lower_step(bundle: StepBundle):
    """jit + lower against abstract args (no allocation)."""
    jitted = jax.jit(bundle.fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    with bundle.mesh:
        return jitted.lower(*bundle.abstract_args)
