import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a standalone process (``python -m repro.launch.dryrun``)
— the XLA_FLAGS line above runs before ANY other import so the host
platform exposes 512 placeholder devices before jax locks its device count.

For each cell we record into results/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis   — per-device argument/output/temp/peak bytes
  * cost_analysis     — HLO FLOPs / bytes accessed (per partition)
  * collective stats  — operand/result bytes per collective op (post-SPMD)
  * timing            — trace/lower/compile wall seconds

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system; the run records them with status=error for triage.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, shape_applicable   # noqa: E402
from repro.launch.hlo_analysis import analyze_collectives   # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.model import build_model                  # noqa: E402
from repro.optim import make_optimizer                      # noqa: E402
from repro.train.step import build_step, lower_step         # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# Per-arch microbatch counts for train_4k: keep per-microbatch per-device
# token counts (and MoE dispatch buffers) inside HBM.
TRAIN_MICROBATCHES = {
    "qwen3-moe-235b-a22b": 8,
    "granite-34b": 8,
    "llama-3.2-vision-11b": 4,
    "zamba2-7b": 4,
    "phi4-mini-3.8b": 2,
    "minitron-4b": 2,
    "llama3.2-3b": 2,
}

# optimizer-state dtype: int8 block-quantised for the giants (ZeRO + 8-bit
# Adam keeps master+moments inside 16 GiB/chip), fp32 elsewhere.
OPT_STATE_DTYPE = {
    "qwen3-moe-235b-a22b": "int8",
    "granite-34b": "int8",
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, *, force: bool = False,
             microbatches: int | None = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "skipped", "skip_reason": why,
    }
    if not ok:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        model = build_model(cfg)
        opt = make_optimizer(
            "adamw", state_dtype=OPT_STATE_DTYPE.get(arch, "float32")) \
            if shape.kind == "train" else None
        mb = microbatches if microbatches is not None \
            else (TRAIN_MICROBATCHES.get(arch, 1)
                  if shape.kind == "train" else 1)
        bundle = build_step(model, opt, mesh, shape, microbatches=mb)
        t1 = time.time()
        lowered = lower_step(bundle)
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes",
                          "peak_memory_in_bytes"):
                if hasattr(ma, field):
                    mem[field] = int(getattr(ma, field))
        except Exception as e:  # noqa: BLE001
            mem["error"] = str(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            for k in ("flops", "bytes accessed", "optimal_seconds",
                      "utilization operand 0 {}", "transcendentals"):
                if k in ca:
                    cost[k.replace(" ", "_")] = float(ca[k])
            # keep every numeric entry that looks aggregate
            for k, v in ca.items():
                if isinstance(v, (int, float)) and "{" not in k:
                    cost[k.replace(" ", "_")] = float(v)
        except Exception as e:  # noqa: BLE001
            cost["error"] = str(e)

        hlo = compiled.as_text()
        coll = analyze_collectives(hlo)

        # unrolled cost probe (single-pod only; the roofline table is
        # single-pod per the assignment) — accurate per-layer FLOP/byte/
        # collective extrapolation, since cost_analysis counts while-loop
        # bodies once
        probe = None
        if not multi_pod:
            try:
                from repro.launch.probe import run_probe
                probe = run_probe(cfg, shape, mesh, microbatches=mb)
            except Exception as e:  # noqa: BLE001
                probe = {"error": str(e),
                         "traceback": traceback.format_exc()[-2000:]}

        rec.update({
            "status": "ok",
            "chips": int(mesh.devices.size),
            "microbatches": mb,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
            "memory_analysis": mem,
            "cost_analysis": cost,
            "collectives": coll,
            "probe": probe,
            "hlo_bytes": len(hlo),
            "timing": {"build_s": t1 - t0, "lower_s": t2 - t1,
                       "compile_s": t3 - t2},
        })
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "error", "error": str(e),
                    "traceback": traceback.format_exc()[-4000:]})
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force,
                               microbatches=args.microbatches)
                tag = f"{arch:24s} {shape:12s} {'multipod' if mp else 'pod':8s}"
                if rec["status"] == "ok":
                    n_ok += 1
                    mem = rec["memory_analysis"]
                    peak = mem.get("peak_memory_in_bytes",
                                   mem.get("temp_size_in_bytes", 0))
                    print(f"OK    {tag} peak={peak/2**30:7.2f}GiB "
                          f"flops={rec['cost_analysis'].get('flops', 0):.3e} "
                          f"coll={rec['collectives']['collective_bytes'] / 2**30:8.3f}"
          "GiB "
                          f"compile={rec['timing']['compile_s']:6.1f}s",
                          flush=True)
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"SKIP  {tag} ({rec['skip_reason'][:60]})", flush=True)
                else:
                    n_err += 1
                    print(f"ERROR {tag} {rec['error'][:120]}", flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
