"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialisation and only then builds meshes.

Mesh shapes:
    single pod:  (16, 16)      axes ("data", "model")   — 256 chips
    multi pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

``pod`` is the DCN (inter-pod) axis: pure data parallelism with optional
gradient compression; ``data`` is within-pod DP / FSDP / sequence
parallelism; ``model`` is tensor/expert parallelism over ICI.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, *, model: int = 2):
    """Small mesh over however many (host) devices exist — for tests."""
    n = n_devices or len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (the roofline denominators)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
DCN_BW = 6.25e9                # bytes/s per host (~50 Gbit) for pod axis
HBM_BYTES = 16 * 1024**3       # 16 GiB per chip
