"""Post-SPMD HLO analysis: collective traffic, per-op tallies.

``compiled.as_text()`` is the post-partitioning module: every cross-device
transfer appears as an explicit all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.  We parse instruction
definitions into a name->shape table, then sum OPERAND bytes for every
collective (operand bytes ~ bytes leaving the device, the roofline-relevant
quantity; for all-gather the result is counted on the receive side and for
reduce-scatter the operand side — consistent with ring-algorithm traffic
within a factor of 2(n-1)/n).
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string, handling tuples of shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def analyze_collectives(hlo_text: str) -> Dict:
    """Sum collective operand bytes and per-op counts from post-SPMD HLO."""
    # name -> result type string
    result_types: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs starts with the result type, e.g. "bf16[8,128]{1,0} all-gather(..."
        tm = re.match(r"^(\([^)]*\)|[\w\[\]\{\},\.]+)", rhs)
        if tm:
            result_types[name] = tm.group(1)

    per_op: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
        for op in COLLECTIVE_OPS
    }
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            alt = f"{op}-start("
            if token not in line and alt not in line:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            tm = re.match(r"^(\([^)]*\)|[\w\[\]\{\},\.]+)", rhs)
            result_bytes = _shape_bytes(tm.group(1)) if tm else 0
            # operands: names inside the first (...) after the op token
            pidx = rhs.find(f"{op}(")
            if pidx < 0:
                pidx = rhs.find(f"{op}-start(")
            args_str = rhs[rhs.find("(", pidx) + 1:]
            depth = 1
            out = []
            for ch in args_str:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            args_str = "".join(out)
            operand_bytes = 0
            for arg in args_str.split(","):
                arg = arg.strip().lstrip("%")
                arg = arg.split(" ")[0]
                if arg in result_types:
                    operand_bytes += _shape_bytes(result_types[arg])
            d = per_op[op]
            d["count"] += 1
            d["operand_bytes"] += operand_bytes
            d["result_bytes"] += result_bytes
            break

    total_operand = sum(d["operand_bytes"] for d in per_op.values())
    total_result = sum(d["result_bytes"] for d in per_op.values())
    return {
        "per_op": per_op,
        "collective_operand_bytes": total_operand,
        "collective_result_bytes": total_result,
        "collective_bytes": max(total_operand, total_result),
    }


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
