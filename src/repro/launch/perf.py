import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Perf hillclimbing driver (§Perf): named variants of the three selected
cells, each lowered through the unrolled cost probe, with kernel-true
analytic accounting for the VMEM-resident tile math.

    python -m repro.launch.perf --variant A1 [--full-mem]

Variants (hypotheses recorded in EXPERIMENTS.md §Perf):

Cell A = granite-moe-1b-a400m x train_4k   (worst roofline fraction)
  A0  baseline (GShard one-hot dispatch, remat on)
  A1  moe_impl=gather        — kill the O(S*E*C*d) dispatch einsums
  A2  A1 + remat off         — HBM headroom (peak 0.65 GiB of 16)
  A3  A2 + kernel-true attention accounting (skip-diff + analytic)

Cell B = xlstm-1.3b x train_4k             (most collective-bound)
  B0  baseline (TP over d_inner -> per-layer psums)
  B1  pure-DP remap: batch over (data, model); params replicated per chip
      (int8 Adam states keep the optimizer inside HBM)
  B2  B1 + remat off
  B3  B2 + kernel-true mLSTM accounting

Cell C = granite-34b x train_4k            (memory-dominant; the paper's
                                            remat/planning lever)
  C0  baseline (ZeRO-3 FSDP + remat)
  C1  remat off              — HBM headroom (peak 2.5 GiB of 16)
  C2  C1 + ZeRO-1 instead of ZeRO-3 (params TP-only; int8 moments) — kill
      per-layer weight all-gathers
  C3  C2 + kernel-true attention accounting
"""

import argparse      # noqa: E402
import dataclasses  # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"


# ---------------------------------------------------------------------------
# Kernel-true analytic costs (per device, whole model, per step)
# ---------------------------------------------------------------------------

def kernel_true_attention(cfg, shape, chips: int) -> dict:
    """Flash-kernel FLOPs/HBM-bytes for all attention layers.

    The Pallas kernel keeps scores/probs in VMEM; HBM traffic is q,o once
    plus k,v streamed per q-block row.  Causal halves both the FLOPs and
    the kv streaming.  Train multiplies by 3.5 (dO recompute backward).
    """
    s = shape.seq_len
    dp = chips // 16                       # batch shards (data [x pod])
    b_l = max(shape.global_batch // dp, 1)
    h_l = cfg.n_heads / (16 if cfg.n_heads % 16 == 0 else 1)
    hkv_l = cfg.n_kv_heads / (16 if cfg.n_kv_heads % 16 == 0 else 1)
    hd = cfg.head_dim
    causal = 0.5
    mult = 3.5 if shape.kind == "train" else 1.0
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "audio":
        n_attn = cfg.n_layers * 2 + cfg.encoder_layers  # self+cross+enc
    if cfg.family == "vlm":
        n_attn = cfg.n_layers + cfg.n_layers // cfg.cross_attn_every
    flops = 4 * b_l * h_l * s * s * hd * causal * mult * n_attn
    nq = -(-s // cfg.block_q)
    bytes_ = ((2 * b_l * h_l * s * hd                  # q read + o write
               + 2 * b_l * hkv_l * s * hd * nq * causal) * 2  # k,v streams
              * mult * n_attn)
    return {"flops": float(flops), "bytes": float(bytes_)}


def kernel_true_mlp(cfg, shape, chips: int) -> dict:
    """Fused-SwiGLU kernel FLOPs/HBM-bytes for all MLP layers.

    The Pallas kernel streams x once for gate+up and writes the hidden h
    once (no g/u round trips); down-proj reads h once.  Per layer per
    device: flops = 6*t*d*f (3 matmuls), bytes = (t*d*2 + weights/16 +
    2*t*f) * dtype.  Train multiplies by 3.5.
    """
    s = shape.seq_len
    dp = chips // 16
    b_l = max(shape.global_batch // dp, 1)
    t = b_l * s
    d = cfg.d_model
    f = (cfg.d_ff // 16) if cfg.d_ff % 16 == 0 else cfg.d_ff   # TP-sharded
    mult = 3.5 if shape.kind == "train" else 1.0
    n_mlp = cfg.n_layers
    if cfg.family == "hybrid":
        n_mlp = cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "audio":
        n_mlp = cfg.n_layers + cfg.encoder_layers
    flops = 6 * t * d * f * mult * n_mlp
    bytes_ = (2 * t * d + 3 * d * f + 2 * t * f) * 2 * mult * n_mlp
    return {"flops": float(flops), "bytes": float(bytes_)}


def kernel_true_moe_ffn(cfg, shape, chips: int) -> dict:
    """Fused expert-FFN kernel (per-expert fused SwiGLU over capacity slots).

    Experts sharded over model (E/16 per chip); capacity slots per group
    C = S_g*k/E*cf.  Fused: expert_in streamed once, hidden in VMEM,
    expert_out written once."""
    s_g = min(shape.seq_len, 4096)
    groups_per_dev = max(shape.global_batch * (shape.seq_len // s_g)
                         // (chips // 16), 1)
    e_l = cfg.n_experts / 16 if cfg.n_experts % 16 == 0 else cfg.n_experts
    cap = int(-(-s_g * cfg.top_k * cfg.capacity_factor // cfg.n_experts))
    d, f = cfg.d_model, cfg.moe_d_ff
    mult = 3.5 if shape.kind == "train" else 1.0
    slots = groups_per_dev * e_l * cap
    flops = 6 * slots * d * f * mult * cfg.n_layers
    bytes_ = (2 * slots * d + 3 * d * f * e_l + 2 * slots * f) * 2 \
        * mult * cfg.n_layers
    return {"flops": float(flops), "bytes": float(bytes_)}


def kernel_true_mixer(cfg, shape, chips: int) -> dict:
    """SSD / mLSTM chunk-kernel FLOPs+HBM bytes for all mixer layers."""
    s = shape.seq_len
    dp = chips // 16
    b_l = max(shape.global_batch // dp, 1)
    mult = 3.5 if shape.kind == "train" else 1.0
    q = 256
    nc = -(-s // q)
    if cfg.family == "ssm":                      # mLSTM
        d = cfg.d_model
        di = 2 * d
        h = cfg.n_heads
        p = di // h / (16 if di % 16 == 0 else 1)  # p sharded via mlp dim
        per_chunk_flops = 2 * q * q * p * 2 + 2 * q * p * p + 2 * q * p
        per_chunk_bytes = (3 * q * p + 2 * q + q * p + p * p) * 4
        n_mixer = cfg.n_layers
    else:                                        # mamba2 (zamba)
        di = cfg.d_inner
        h = cfg.n_ssm_heads
        p = di // h
        n = cfg.ssm_state or 64
        per_chunk_flops = 2 * q * q * n + 2 * q * q * p + 2 * q * n * p
        per_chunk_bytes = (2 * q * p + 2 * q * n + n * p) * 4
        n_mixer = cfg.n_layers
        h = h / (16 if h % 16 == 0 else 1)
    flops = per_chunk_flops * nc * h * b_l * mult * n_mixer
    bytes_ = per_chunk_bytes * nc * h * b_l * mult * n_mixer
    return {"flops": float(flops), "bytes": float(bytes_)}


# ---------------------------------------------------------------------------
# Variants
# ---------------------------------------------------------------------------

def _variants():
    return {
        # --- Cell A: granite-moe x train_4k --------------------------------
        "A0": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={}, rules=None, fsdp=None, adjust=None),
        "A1": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"moe_impl": "gather"},
                   rules=None, fsdp=None, adjust=None),
        "A2": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"moe_impl": "gather", "remat": False},
                   rules=None, fsdp=None, adjust=None),
        "A3": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"moe_impl": "gather", "remat": False},
                   rules=None, fsdp=None, adjust="attention"),
        "A4": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"remat": False},   # einsum dispatch, no remat
                   rules=None, fsdp=None, adjust="attention"),
        "A5": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"moe_impl": "gather", "remat": False,
                              "capacity_factor": 1.0},
                   rules=None, fsdp=None, adjust="attention"),
        "A7": dict(arch="granite-moe-1b-a400m", shape="train_4k",
                   overrides={"remat": False},   # einsum dispatch
                   rules=None, fsdp=None, adjust="attention+moeffn"),
        # --- Cell B: xlstm x train_4k ---------------------------------------
        "B0": dict(arch="xlstm-1.3b", shape="train_4k",
                   overrides={}, rules=None, fsdp=None, adjust=None),
        "B1": dict(arch="xlstm-1.3b", shape="train_4k",
                   overrides={},
                   rules={"batch": ("data", "model"), "mlp": None,
                          "vocab": None, "qblocks": ("data", "model")},
                   fsdp=False, adjust=None),
        "B2": dict(arch="xlstm-1.3b", shape="train_4k",
                   overrides={"remat": False},
                   rules={"batch": ("data", "model"), "mlp": None,
                          "vocab": None, "qblocks": ("data", "model")},
                   fsdp=False, adjust=None),
        "B3": dict(arch="xlstm-1.3b", shape="train_4k",
                   overrides={"remat": False},
                   rules={"batch": ("data", "model"), "mlp": None,
                          "vocab": None, "qblocks": ("data", "model")},
                   fsdp=False, adjust="mixer"),
        # --- Cell C: granite-34b x train_4k ---------------------------------
        "C0": dict(arch="granite-34b", shape="train_4k",
                   overrides={}, rules=None, fsdp=None, adjust=None),
        "C1": dict(arch="granite-34b", shape="train_4k",
                   overrides={"remat": False}, rules=None, fsdp=None,
                   adjust=None),
        "C2": dict(arch="granite-34b", shape="train_4k",
                   overrides={"remat": False}, rules=None, fsdp=False,
                   adjust=None),
        "C3": dict(arch="granite-34b", shape="train_4k",
                   overrides={"remat": False}, rules=None, fsdp=False,
                   adjust="attention"),
        "C4": dict(arch="granite-34b", shape="train_4k",
                   overrides={"remat": False}, rules=None, fsdp=False,
                   adjust="attention+mlp"),
    }


def run_variant(name: str, spec: dict, *, full_mem: bool = False) -> dict:
    import jax  # noqa: F401  (after XLA_FLAGS)
    from repro.configs import ARCHS, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.probe import run_probe
    from repro.launch.dryrun import TRAIN_MICROBATCHES
    from repro.sharding.api import clear_overrides, set_overrides

    cfg = dataclasses.replace(ARCHS[spec["arch"]], **spec["overrides"])
    shape = SHAPES[spec["shape"]]
    mesh = make_production_mesh(multi_pod=False)
    mb = TRAIN_MICROBATCHES.get(spec["arch"], 1) if shape.kind == "train" else 1
    chips = int(mesh.devices.size)

    set_overrides(rules=spec["rules"], fsdp=spec["fsdp"])
    rec = {"variant": name, **{k: str(v) for k, v in spec.items()}}
    try:
        t0 = time.time()
        probe = run_probe(cfg, shape, mesh, microbatches=mb)
        rec["probe"] = {k: v for k, v in probe.items()
                        if not k.startswith("probe")}
        flops, bytes_ = probe["flops"], probe["bytes"]
        coll = probe["collective_bytes"]
        if spec["adjust"]:
            parts = spec["adjust"].split("+")
            skip_over = {}
            analytic = {"flops": 0.0, "bytes": 0.0}
            for part in parts:
                if part == "attention":
                    skip_over["attention_impl"] = "skip"
                    a = kernel_true_attention(cfg, shape, chips)
                elif part == "mixer":
                    skip_over["mixer_skip"] = True
                    a = kernel_true_mixer(cfg, shape, chips)
                elif part == "mlp":
                    skip_over["mlp_skip"] = True
                    a = kernel_true_mlp(cfg, shape, chips)
                elif part == "moeffn":
                    skip_over["moe_ffn_skip"] = True
                    a = kernel_true_moe_ffn(cfg, shape, chips)
                else:
                    raise ValueError(part)
                analytic = {k: analytic[k] + a[k] for k in analytic}
            skip_cfg = dataclasses.replace(cfg, **skip_over)
            probe_skip = run_probe(skip_cfg, shape, mesh, microbatches=mb)
            flops = probe_skip["flops"] + analytic["flops"]
            bytes_ = probe_skip["bytes"] + analytic["bytes"]
            # collectives from the FULL probe: the kernels keep tile math in
            # VMEM but do not remove TP psums (e.g. the row-parallel
            # down-proj all-reduce survives a fused MLP)
            coll = probe["collective_bytes"]
            rec["skip_probe"] = {"flops": probe_skip["flops"],
                                 "bytes": probe_skip["bytes"]}
            rec["analytic"] = analytic
        if full_mem:
            from repro.models.model import build_model
            from repro.optim import make_optimizer
            from repro.train.step import build_step, lower_step
            opt = make_optimizer("adamw", state_dtype="int8") \
                if spec["fsdp"] is False else make_optimizer("adamw")
            bundle = build_step(build_model(cfg), opt, mesh, shape,
                                microbatches=mb)
            compiled = lower_step(bundle).compile()
            ma = compiled.memory_analysis()
            rec["memory"] = {
                f: int(getattr(ma, f))
                for f in ("argument_size_in_bytes", "temp_size_in_bytes",
                          "output_size_in_bytes", "peak_memory_in_bytes")
                if hasattr(ma, f)}

        from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
        from repro.launch.roofline import model_flops_per_device
        t = {"compute": flops / PEAK_FLOPS_BF16,
             "memory": bytes_ / HBM_BW,
             "collective": coll / ICI_BW}
        dom = max(t, key=t.get)
        mf = model_flops_per_device(spec["arch"], spec["shape"], chips)
        rec.update({
            "flops": flops, "bytes": bytes_, "collective_bytes": coll,
            "t_compute_s": t["compute"], "t_memory_s": t["memory"],
            "t_collective_s": t["collective"], "dominant": dom,
            "model_flops_per_dev": mf,
            "useful_compute_ratio": mf / flops if flops else 0,
            "roofline_fraction": (mf / max(t.values())) / PEAK_FLOPS_BF16,
            "wall_s": time.time() - t0,
            "status": "ok",
        })
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "error", "error": str(e),
                    "traceback": traceback.format_exc()[-3000:]})
    finally:
        clear_overrides()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None,
                    help="variant name (default: all)")
    ap.add_argument("--full-mem", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    specs = _variants()
    names = [args.variant] if args.variant else list(specs)
    for name in names:
        out = RESULTS / f"{name}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
        else:
            rec = run_variant(name, specs[name], full_mem=args.full_mem)
            out.write_text(json.dumps(rec, indent=2))
        if rec["status"] == "ok":
            print(f"{name}: t_comp={rec['t_compute_s']:.3f}s "
                  f"t_mem={rec['t_memory_s']:.3f}s "
                  f"t_coll={rec['t_collective_s']:.3f}s "
                  f"dom={rec['dominant']} useful={rec['useful_compute_ratio']:.2%} "
                  f"roofline={rec['roofline_fraction']:.2%}", flush=True)
        else:
            print(f"{name}: ERROR {rec['error'][:150]}", flush=True)


if __name__ == "__main__":
    main()
