"""Roofline analysis from dry-run artifacts (§Roofline deliverable).

Per (arch, shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]

All three numerators come from the UNROLLED COST PROBE (extrapolated to
full depth — see probe.py; cost_analysis on the scanned module undercounts
while bodies).  Since probe numbers are per-device/per-partition, dividing
by per-chip peaks is identical to the global form
``HLO_FLOPs / (chips x peak)``.

Also reported: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per
device-shard, and the ratio MODEL_FLOPS / HLO_FLOPs — the "useful compute"
fraction that exposes remat recompute, dispatch overhead and attention
masking waste.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    """6*N(active)*tokens, sharded over all chips (per-device share)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens / chips


def analyze_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    probe = rec.get("probe") or {}
    if "flops" not in probe:
        return None
    chips = rec["chips"]
    flops = probe["flops"]
    bytes_ = probe["bytes"]
    coll = probe["collective_bytes"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    t_total = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_,
        "collective_bytes_per_dev": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_compute_ratio": mf / flops if flops else 0.0,
        # roofline fraction: useful FLOPs per second achievable at the
        # modelled bottleneck, as a fraction of peak
        "roofline_fraction": (mf / t_total) / PEAK_FLOPS_BF16
        if t_total else 0.0,
        "peak_bytes_per_dev": rec["memory_analysis"].get(
            "peak_memory_in_bytes",
            rec["memory_analysis"].get("temp_size_in_bytes", 0)),
    }


def load_all(results_dir: Path = RESULTS) -> List[Dict]:
    out = []
    for p in sorted(results_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        a = analyze_cell(rec)
        if a:
            out.append(a)
    return out


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s} {'peak(GiB)':>10s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_compute_ratio']:7.2%} {r['roofline_fraction']:9.2%} "
            f"{r['peak_bytes_per_dev']/2**30:10.2f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=str(RESULTS))
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(Path(args.results))
    print(format_table(rows))
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
