"""Cost probes: accurate FLOP/byte/collective accounting for scanned stacks.

XLA's ``cost_analysis`` tallies a ``while`` body ONCE, so any lax.scan over
layers (or KV blocks, or grad-accumulation microbatches) silently
undercounts.  The probe lowers two UNROLLED variants of each cell — one and
two "periods" deep (a period is the model's repeating unit: one block, one
cross-attn super-block, one shared-attn group, one sLSTM group, one
enc+dec layer pair) — at one gradient-accumulation microbatch, takes the
per-period delta, and extrapolates:

    total = microbatches * (fixed + per_period * n_periods)

where fixed = probe1 - per_period (embed/unembed/loss/optimizer — the
optimizer is over-counted (mb-1) times, negligible at <0.1% of FLOPs).

The probes run with the SAME mesh/shardings as the full cell so collective
traffic extrapolates the same way.  Known residual: the sLSTM time-step
recurrence is a true sequential scan even in probe mode; its per-step
``wh`` matmul is added analytically (see ``slstm_correction``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.hlo_analysis import analyze_collectives
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.train.step import build_step, lower_step


def probe_config(cfg: ModelConfig, periods: int, seq_len: int) -> ModelConfig:
    """Same-family config with ``periods`` repeating units, unrolled."""
    over = {"unroll_layers": True}
    if seq_len >= 32768:
        over.update(block_q=2048, block_kv=4096)
    if cfg.family == "vlm":
        over["n_layers"] = cfg.cross_attn_every * periods
    elif cfg.family == "hybrid":
        over["n_layers"] = cfg.shared_attn_every * periods
    elif cfg.family == "ssm" and cfg.slstm_every:
        over["n_layers"] = cfg.slstm_every * periods
    elif cfg.family == "audio":
        over["n_layers"] = periods
        over["encoder_layers"] = periods
    else:
        over["n_layers"] = periods
    return dataclasses.replace(cfg, **over)


def n_periods(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "hybrid":
        # tail layers counted fractionally (they are mamba blocks only)
        return cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.n_layers // cfg.slstm_every
    if cfg.family == "audio":
        return cfg.n_layers
    return cfg.n_layers


def slstm_correction(cfg: ModelConfig, shape: ShapeConfig,
                     chips: int) -> Dict[str, float]:
    """Analytic per-device FLOPs/bytes for the sLSTM time recurrence that
    even the unrolled probe cannot count (the scan over S time steps).

    Per step per layer: wh matvec 8*b*d^2 FLOPs + ~20*b*d elementwise;
    the probe counted one step, so (S-1) are missing; training backward
    multiplies by ~3.  Returned PER PERIOD (one sLSTM layer per period).
    """
    if cfg.family != "ssm" or not cfg.slstm_every:
        return {"flops": 0.0, "bytes": 0.0}
    d = cfg.d_model
    # batch per device: global batch / (pod*data) where model axis is 16
    b_local = max(shape.global_batch // max(chips // 16, 1), 1)
    s = shape.seq_len if shape.kind != "decode" else 1
    per_step = 8 * b_local * d * d + 20 * b_local * d
    mult = 3.0 if shape.kind == "train" else 1.0
    return {"flops": float((s - 1) * per_step * mult), "bytes": 0.0}


def _lower_and_cost(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    model = build_model(cfg)
    opt = make_optimizer("adamw", state_dtype="float32") \
        if shape.kind == "train" else None
    bundle = build_step(model, opt, mesh, shape, microbatches=1)
    lowered = lower_step(bundle)
    compiled = lowered.compile()
    cost = {}
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    cost["flops"] = float(ca.get("flops", 0.0))
    cost["bytes"] = float(ca.get("bytes accessed", 0.0))
    coll = analyze_collectives(compiled.as_text())
    cost["collective_bytes"] = float(coll["collective_bytes"])
    cost["collective_per_op"] = {
        k: dict(v) for k, v in coll["per_op"].items()}
    return cost


def run_probe(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
              microbatches: int = 1) -> Dict:
    """Extrapolated per-device cost for the full (cfg, shape) cell."""
    probe_shape = shape
    if shape.kind == "train" and microbatches > 1:
        probe_shape = dataclasses.replace(
            shape, global_batch=max(shape.global_batch // microbatches,
                                    mesh.shape.get("pod", 1)
                                    * mesh.shape["data"]))
    c1 = _lower_and_cost(probe_config(cfg, 1, shape.seq_len), probe_shape, mesh)
    c2 = _lower_and_cost(probe_config(cfg, 2, shape.seq_len), probe_shape, mesh)

    chips = int(mesh.devices.size)
    corr = slstm_correction(cfg, probe_shape, chips)
    L = n_periods(cfg)
    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        per_period = c2[key] - c1[key]
        if key == "flops":
            per_period += corr["flops"]
        fixed = max(c1[key] - per_period, 0.0)
        out[key] = microbatches * (fixed + per_period * L)
        out[f"{key}_per_period"] = per_period
        out[f"{key}_fixed"] = fixed
    # hybrid tail: cfg.n_layers % k extra mamba layers ~ (tail/k) of a period
    if cfg.family == "hybrid" and cfg.n_layers % cfg.shared_attn_every:
        frac = (cfg.n_layers % cfg.shared_attn_every) / cfg.shared_attn_every
        for key in ("flops", "bytes", "collective_bytes"):
            out[key] += microbatches * out[f"{key}_per_period"] * frac
    out["probe1"] = c1
    out["probe2"] = c2
    out["n_periods"] = L
    out["microbatches"] = microbatches
    return out
