"""Serving launcher: multi-tenant personalization + LM generation.

Two subcommands::

    # N simulated users fine-tuning a zoo model over bucketed traffic
    python -m repro.launch.serve personalize --model lenet5 \
        --users 8 --steps 3 --buckets 8,16 --max-live 8 --json stats.json

    # batched prefill + greedy decode on an LM arch
    python -m repro.launch.serve generate --arch llama3.2-3b --test-mesh \
        --requests 8 --gen-tokens 16

``personalize`` drives :class:`repro.serve.PersonalizationService`: every
user shares one frozen base tree and one compiled memory plan per batch
bucket; admission control splits the device arena between live sessions
and the stats dump shows the QoS counters (cache hit rate, per-session
peak bytes vs share, steps/sec, rejections).

``generate`` implements the standard two-phase server: requests are
batched, prefilled — one fused full-sequence forward filling the KV cache
(``model.prefill_fn``) when the family supports it, falling back to the
sequential per-token cache fill otherwise — then decoded token-by-token
with greedy sampling.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List


# ---------------------------------------------------------------------------
# personalize: the multi-tenant fine-tuning loop
# ---------------------------------------------------------------------------

def _parse_qos(spec: str):
    """Parse ``name:weight:slots,...`` into QosClass objects plus a
    flattened slot list used to deal users across classes in order."""
    from repro.serve import QosClass

    classes, deal = [], []
    for part in spec.split(","):
        fields = part.split(":")
        if not 1 <= len(fields) <= 3 or not fields[0]:
            raise SystemExit(f"bad --qos entry {part!r}; "
                             "expected name[:weight[:slots]]")
        name = fields[0]
        weight = float(fields[1]) if len(fields) > 1 else 1.0
        slots = int(fields[2]) if len(fields) > 2 else 1
        classes.append(QosClass(name, weight, slots=slots))
        deal.extend([name] * slots)
    return tuple(classes), deal


def run_personalize(args: argparse.Namespace) -> None:
    import numpy as np

    from repro.core import MemoryPlanConfig
    from repro.core.zoo import ZOO
    from repro.runtime.fault import FaultInjector
    from repro.serve import PersonalizationService
    from repro.serve.buckets import dummy_batch

    if args.model not in ZOO:
        raise SystemExit(f"unknown zoo model {args.model!r}; "
                         f"choose from {sorted(ZOO)}")
    graph = ZOO[args.model]()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    config = MemoryPlanConfig(executor=args.executor)
    injector = None
    if args.kill_user is not None:
        injector = FaultInjector()
        injector.arm_kill(f"session:u{args.kill_user}",
                          after=args.kill_after)

    qos_classes, qos_of = None, {}
    max_live = args.max_live
    if args.qos:
        qos_classes, deal = _parse_qos(args.qos)
        # deal users across the declared slots in order, wrapping so
        # --users larger than the slot total still gets a class label
        qos_of = {f"u{u}": deal[u % len(deal)] for u in range(args.users)}
        # admission requires the class slots to sum to the session cap
        max_live = len(deal)

    budget = args.device_budget_mb * (1 << 20) if args.device_budget_mb \
        else None
    svc = PersonalizationService(
        graph, buckets=buckets, max_live_sessions=max_live,
        device_budget_bytes=budget, config=config, lr=args.lr,
        qos=qos_classes, interleave=args.interleave,
        bus_gbps=args.bus_gbps if args.bus_gbps > 0 else None,
        bus_latency_s=args.bus_latency,
        injector=injector, seed=args.seed)
    t0 = time.time()
    svc.warmup()
    t_warm = time.time() - t0
    print(f"warmup: {len(svc.buckets)} buckets compiled + replayed in "
          f"{t_warm:.2f}s; arena share = "
          f"{svc.admission.arena_share_bytes} B/session")

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for step in range(args.steps):
        # enqueue the whole round, then drain once: in interleaved mode
        # the scheduler round-robins every user's cursor at phase
        # boundaries, hiding one tenant's DMA under another's compute;
        # with --no-interleave the same queue drains FIFO
        reqs = []
        for u in range(args.users):
            # bucketed traffic: odd users send short batches (padded up),
            # even users fill the largest bucket
            n = int(rng.integers(1, buckets[0] + 1)) if u % 2 \
                else buckets[-1]
            x, y = dummy_batch(graph, n, seed=step * args.users + u)
            reqs.append(svc.enqueue(f"u{u}", x, y,
                                    qos=qos_of.get(f"u{u}")))
        svc.drain()
        for u, req in enumerate(reqs):
            res = req.result
            tag = f"loss={res.loss:.4f} bucket={res.bucket}" \
                if res.ok else res.reason
            print(f"  step {step} u{u}: {res.status} {tag}")
    t_total = time.time() - t0

    rep = svc.report()
    rep["driver"] = {"users": args.users, "steps": args.steps,
                     "wall_time_s": round(t_total, 3)}
    sched = rep.get("scheduler")
    if args.interleave and sched:
        hidden = sched["hidden_dma_s"] + sched["opt_hidden_dma_s"]
        exposed = sched["exposed_dma_s"] + sched["opt_exposed_dma_s"]
        print(f"interleaved drain: {hidden*1e3:.1f} ms DMA hidden under "
              f"compute ({sched['cross_hidden_dma_s']*1e3:.1f} ms under "
              f"*other* sessions'), {exposed*1e3:.1f} ms exposed")
    print(json.dumps(rep, indent=2, default=str))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2, default=str)
        print(f"stats written to {args.json}")


# ---------------------------------------------------------------------------
# generate: batched prefill + greedy decode
# ---------------------------------------------------------------------------

def run_generate(args: argparse.Namespace) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ARCHS
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    from repro.models.model import build_model, reduce_config

    cfg = ARCHS[args.arch]
    if args.test_mesh:
        cfg = reduce_config(cfg)
        make_test_mesh(model=1)
    else:
        make_production_mesh()
    model = build_model(cfg)
    if model.decode_fn is None:
        raise SystemExit(f"{args.arch} has no decode path")

    params = model.init(jax.random.PRNGKey(0))
    b = args.requests
    max_seq = args.prompt_len + args.gen_tokens + 8

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(b, args.prompt_len),
                           dtype=np.int32)

    decode = jax.jit(model.decode_fn)
    state = model.decode_init(b, max_seq)
    tokens = jnp.asarray(prompts)

    # ---- prefill: one fused full-prompt forward when the family supports
    # it; sequential per-token cache fill as the fallback ------------------
    t0 = time.time()
    if model.prefill_fn is not None and not args.sequential_prefill:
        logits, state = jax.jit(model.prefill_fn)(params, state, tokens)
        mode = "batched"
    else:
        logits = None
        for t in range(args.prompt_len):
            logits, state = decode(params, state, tokens[:, t],
                                   jnp.full((b,), t, jnp.int32))
        mode = "sequential"
    t_prefill = time.time() - t0

    # ---- greedy decode ---------------------------------------------------
    out_tokens: List[np.ndarray] = []
    cur = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen_tokens):
        out_tokens.append(np.asarray(cur))
        logits, state = decode(
            params, state, cur,
            jnp.full((b,), args.prompt_len + i, jnp.int32))
        cur = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"prefill ({mode}): {t_prefill*1000:.1f} ms for "
          f"{b}x{args.prompt_len} tok")
    print(f"decode:  {t_decode*1000:.1f} ms for {b}x{args.gen_tokens} tok "
          f"({b*args.gen_tokens/max(t_decode,1e-9):.1f} tok/s)")
    print("generated token ids (first request):", gen[0].tolist())


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("personalize",
                       help="multi-tenant per-user fine-tuning")
    p.add_argument("--model", default="lenet5", help="zoo model name")
    p.add_argument("--users", type=int, default=8)
    p.add_argument("--steps", type=int, default=2,
                   help="fine-tune rounds per user")
    p.add_argument("--buckets", default="8,16",
                   help="comma-separated batch buckets")
    p.add_argument("--max-live", type=int, default=8)
    p.add_argument("--device-budget-mb", type=int, default=0,
                   help="arena budget (MiB); 0 derives it from the plans")
    p.add_argument("--executor", default="sim", choices=("sim", "async"))
    p.add_argument("--interleave", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="phase-interleave live sessions so one tenant's "
                        "DMA overlaps another's compute "
                        "(--no-interleave = synchronous FIFO drain)")
    p.add_argument("--qos", default="",
                   help="comma-separated QoS classes as "
                        "name[:weight[:slots]], e.g. "
                        "'premium:2.0:2,standard:1.0:6'; users are dealt "
                        "across the declared slots in order")
    p.add_argument("--bus-gbps", type=float, default=0.0,
                   help="emulated host<->device bus bandwidth (GB/s); "
                        "0 disables pacing")
    p.add_argument("--bus-latency", type=float, default=0.0,
                   help="emulated per-access bus latency (seconds); the "
                        "sync FIFO path pays it per transfer, the async "
                        "engine amortizes it across the queue")
    p.add_argument("--lr", type=float, default=0.05)
    p.add_argument("--kill-user", type=int, default=None,
                   help="arm a fault-injection kill for user uN")
    p.add_argument("--kill-after", type=int, default=0,
                   help="fire on the Nth request after arming")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="", help="write stats JSON here")
    p.set_defaults(fn=run_personalize)

    g = sub.add_parser("generate", help="batched prefill + greedy decode")
    g.add_argument("--arch", required=True)
    g.add_argument("--test-mesh", action="store_true")
    g.add_argument("--requests", type=int, default=4)
    g.add_argument("--prompt-len", type=int, default=16)
    g.add_argument("--gen-tokens", type=int, default=16)
    g.add_argument("--sequential-prefill", action="store_true",
                   help="force the per-token fallback prefill")
    g.set_defaults(fn=run_generate)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
