"""Serving launcher: batched prefill + decode with a request queue.

    python -m repro.launch.serve --arch llama3.2-3b --test-mesh \
        --requests 8 --gen-tokens 16

Implements the standard two-phase server: incoming requests are batched,
prefilled (full-sequence forward filling the KV cache), then decoded
token-by-token with greedy sampling.  On the production mesh the decode
step is the ``decode_32k``/``long_500k`` dry-run cell.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--test-mesh", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.models.model import build_model, reduce_config
    from repro.launch.mesh import make_production_mesh, make_test_mesh

    cfg = ARCHS[args.arch]
    if args.test_mesh:
        cfg = reduce_config(cfg)
        mesh = make_test_mesh(model=1)
    else:
        mesh = make_production_mesh()
    model = build_model(cfg)
    if model.decode_fn is None:
        raise SystemExit(f"{args.arch} has no decode path")

    params = model.init(jax.random.PRNGKey(0))
    b = args.requests
    max_seq = args.prompt_len + args.gen_tokens + 8

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(b, args.prompt_len),
                           dtype=np.int32)

    decode = jax.jit(model.decode_fn)
    state = model.decode_init(b, max_seq)

    # ---- prefill via sequential cache fill (exact; batched decode steps) --
    t0 = time.time()
    tokens = jnp.asarray(prompts)
    logits = None
    for t in range(args.prompt_len):
        logits, state = decode(params, state, tokens[:, t],
                               jnp.full((b,), t, jnp.int32))
    t_prefill = time.time() - t0

    # ---- greedy decode -----------------------------------------------------
    out_tokens: List[np.ndarray] = []
    cur = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen_tokens):
        out_tokens.append(np.asarray(cur))
        logits, state = decode(
            params, state, cur,
            jnp.full((b,), args.prompt_len + i, jnp.int32))
        cur = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"prefill: {t_prefill*1000:.1f} ms for {b}x{args.prompt_len} tok")
    print(f"decode:  {t_decode*1000:.1f} ms for {b}x{args.gen_tokens} tok "
          f"({b*args.gen_tokens/max(t_decode,1e-9):.1f} tok/s)")
    print("generated token ids (first request):", gen[0].tolist())


if __name__ == "__main__":
    main()
