"""Production training launcher.

    python -m repro.launch.train --arch llama3.2-3b --shape train_4k \
        --steps 1000 --ckpt-dir /ckpts/run1 [--multi-pod] [--dry-run]

On a real TPU pod each host runs this binary (jax.distributed initialises
from the TPU environment); in this CPU container ``--test-mesh`` runs a
reduced config end-to-end and ``--dry-run`` lowers the full config against
the production mesh without allocating.
"""

from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--heartbeat-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (sets 512 host devices)")
    ap.add_argument("--test-mesh", action="store_true",
                    help="reduced config on the local devices")
    ap.add_argument("--distributed", action="store_true",
                    help="initialise jax.distributed from the environment")
    args = ap.parse_args()

    if args.dry_run:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count"
                              "=512").strip()

    import jax
    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import ARCHS, SHAPES
    from repro.models.model import build_model, reduce_config
    from repro.optim import make_optimizer
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.launch.mesh import make_production_mesh, make_test_mesh

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    assert shape.kind == "train", "use repro.launch.serve for serving shapes"

    if args.dry_run:
        from repro.launch.dryrun import run_cell, RESULTS
        rec = run_cell(args.arch, args.shape, args.multi_pod, RESULTS,
                       force=True, microbatches=args.microbatches)
        print(rec["status"], rec.get("memory_analysis"))
        return

    if args.test_mesh:
        cfg = reduce_config(cfg)
        mesh = make_test_mesh(model=1)
        import dataclasses
        shape = dataclasses.replace(shape, seq_len=64, global_batch=8)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    model = build_model(cfg)
    opt = make_optimizer("adamw")
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        heartbeat_dir=args.heartbeat_dir,
        host_id=jax.process_index(), n_hosts=jax.process_count())
    trainer = Trainer(model, opt, mesh, shape, tcfg,
                      microbatches=args.microbatches)
    out = trainer.run()
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
