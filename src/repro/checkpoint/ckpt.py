"""Sharded, async, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_<n>/
        manifest.json        — tree structure, shapes, dtypes, step metadata
        shard_<host>.npz     — this host's param/opt leaves (addressable data)
        data_state.json      — data-stream position

Design points for thousand-node runs:

* per-host shard files: every host writes only its addressable shard slice,
  no cross-host traffic at save time;
* async: ``save`` snapshots leaves to host RAM (device_get) and a background
  thread does the file I/O — the training loop is blocked only for the
  device->host copy;
* atomic publish: writes go to ``step_<n>.tmp`` and are renamed after the
  manifest lands, so a crash mid-save never corrupts the latest checkpoint;
* elastic restore: the manifest records the GLOBAL logical shapes; on
  restore each leaf is re-sharded to the CURRENT mesh via
  ``jax.make_array_from_callback``, so a run checkpointed on N hosts can
  resume on M hosts (different DP degree) unchanged;
* garbage collection: keep the last ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: int = 0, n_hosts: int = 1):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, data_state: Optional[Dict] = None,
             *, blocking: bool = False) -> None:
        """Snapshot to host memory now; write files in the background."""
        self.wait()
        named = _flatten_with_paths(tree)
        # device -> host snapshot (addressable shard only)
        snap: List[Tuple[str, np.ndarray, Tuple[int, ...], str]] = []
        for name, leaf in named:
            if hasattr(leaf, "addressable_shards"):
                shard = leaf.addressable_shards[0]
                arr = np.asarray(shard.data)
                snap.append((name, arr, tuple(leaf.shape), str(leaf.dtype)))
            else:
                arr = np.asarray(leaf)
                snap.append((name, arr, tuple(arr.shape), str(arr.dtype)))
        treedef = jax.tree_util.tree_structure(tree)

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / f"shard_{self.host_id}.npz",
                     **{n: a for n, a, _, _ in snap})
            if self.host_id == 0:
                manifest = {
                    "step": step,
                    "time": time.time(),
                    "n_hosts": self.n_hosts,
                    "treedef": str(treedef),
                    "leaves": [
                        {"name": n, "global_shape": list(gs), "dtype": dt,
                         "shard_shape": list(a.shape)}
                        for n, a, gs, dt in snap
                    ],
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if data_state is not None:
                    (tmp / "data_state.json").write_text(
                        json.dumps(data_state))
            tmp.rename(final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") \
                    and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Any = None) -> Tuple[Any, Optional[Dict]]:
        """Restore into the CURRENT mesh layout (elastic re-shard).

        ``target_tree`` supplies the pytree structure and global shapes;
        ``shardings`` (matching tree of NamedShardings, optional) the
        destination layout.  Every host reads whichever saved shard files
        cover the slices it now owns; with npz whole-leaf shards this is a
        read of the global leaf followed by slicing — exact, if not
        bandwidth-optimal (sufficient for the npz backend).
        """
        cdir = self.dir / f"step_{step}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        n_saved = manifest["n_hosts"]
        shard_files = [np.load(cdir / f"shard_{h}.npz")
                       for h in range(n_saved)
                       if (cdir / f"shard_{h}.npz").exists()]

        def global_leaf(name: str, gshape, dtype):
            pieces = [sf[name] for sf in shard_files if name in sf.files]
            if not pieces:
                raise KeyError(f"{name} missing from checkpoint")
            if pieces[0].shape == tuple(gshape):
                return pieces[0].astype(dtype)
            # host-sharded along axis 0 at save time
            full = np.concatenate(pieces, axis=0)
            return full.reshape(gshape).astype(dtype)

        named_t = _flatten_with_paths(target_tree)
        flat_s = None
        if shardings is not None:
            flat_s = [leaf for _, leaf in _flatten_with_paths(shardings)]
        out_leaves = []
        for i, (name, tgt) in enumerate(named_t):
            arr = global_leaf(name, tgt.shape, tgt.dtype)
            if flat_s is not None and flat_s[i] is not None:
                sh = flat_s[i]
                leaf = jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx])
            else:
                leaf = jax.numpy.asarray(arr)
            out_leaves.append(leaf)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_tree), out_leaves)
        ds_path = cdir / "data_state.json"
        data_state = json.loads(ds_path.read_text()) if ds_path.exists() \
            else None
        return tree, data_state
