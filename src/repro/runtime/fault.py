"""Fault tolerance & straggler mitigation for long multi-pod runs.

Components:

* ``Heartbeat`` — per-host liveness file + monitor; a host missing
  ``timeout`` seconds of beats is declared dead, triggering restart from
  the latest checkpoint (the coordinator pattern; on Cloud TPU the restart
  itself is performed by the job scheduler — this module decides *when*
  and *from which step*).

* ``StepWatchdog`` — straggler mitigation: tracks a robust moving median
  of step times; a step exceeding ``factor`` x median flags the slow host.
  Remedies escalate: log -> exclude host from the next data round
  (shrink DP, elastic) -> request restart.  At dry-run scale we expose the
  detection + decision logic and unit-test it with synthetic timings.

* ``RestartPolicy`` — bounded exponential backoff with a failure budget
  (crash loops abort rather than burn the job's allocation).

* ``elastic_new_mesh`` — recompute the mesh after losing hosts: drops the
  data-parallel extent to the largest supported divisor and returns the
  re-shard plan (checkpoint restore handles the actual movement).

* ``FaultInjector`` — deterministic fault-injection hook for tests and
  chaos drills: arm a kill against a named target (a serving session, a
  host, a step) and the owning loop consults ``check(target)`` at its
  preemption points; the hook fires once after the armed number of checks.
  On-device training runs opportunistically (idle, charging) and gets
  killed constantly — the serving queue uses this hook to prove a session
  killed mid-queue releases its arena reservation.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple


# --------------------------------------------------------------------------
# Heartbeats
# --------------------------------------------------------------------------

class Heartbeat:
    def __init__(self, directory: str, host_id: int):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.path = self.dir / f"host_{host_id}.hb"

    def beat(self, step: int) -> None:
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"t": time.time(), "step": step}))
        tmp.rename(self.path)

    @staticmethod
    def dead_hosts(directory: str, n_hosts: int, *,
                   timeout: float = 120.0,
                   now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        dead = []
        d = Path(directory)
        for h in range(n_hosts):
            p = d / f"host_{h}.hb"
            if not p.exists():
                dead.append(h)
                continue
            try:
                t = json.loads(p.read_text())["t"]
            except Exception:  # noqa: BLE001
                dead.append(h)
                continue
            if now - t > timeout:
                dead.append(h)
        return dead


# --------------------------------------------------------------------------
# Straggler detection
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: Optional[int]
    step_time: float
    median: float
    action: str            # "log" | "exclude" | "restart"


class StepWatchdog:
    def __init__(self, *, window: int = 32, factor: float = 2.0,
                 exclude_after: int = 3, restart_after: int = 8):
        self.window = window
        self.factor = factor
        self.exclude_after = exclude_after
        self.restart_after = restart_after
        self._times: List[float] = []
        self._slow_counts: Dict[Optional[int], int] = {}
        self.events: List[StragglerEvent] = []

    def record(self, step: int, step_time: float,
               slowest_host: Optional[int] = None) -> Optional[StragglerEvent]:
        self._times.append(step_time)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 5:
            return None
        med = statistics.median(self._times)
        if step_time <= self.factor * med:
            self._slow_counts.pop(slowest_host, None)
            return None
        c = self._slow_counts.get(slowest_host, 0) + 1
        self._slow_counts[slowest_host] = c
        if c >= self.restart_after:
            action = "restart"
        elif c >= self.exclude_after:
            action = "exclude"
        else:
            action = "log"
        ev = StragglerEvent(step, slowest_host, step_time, med, action)
        self.events.append(ev)
        return ev


# --------------------------------------------------------------------------
# Restart policy
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 20
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0
    _count: int = 0

    def next_backoff(self) -> Optional[float]:
        """Seconds to wait before restart n, or None when budget exhausted."""
        if self._count >= self.max_restarts:
            return None
        b = min(self.base_backoff_s * (2 ** self._count), self.max_backoff_s)
        self._count += 1
        return b

    def reset(self) -> None:
        self._count = 0


# --------------------------------------------------------------------------
# Elastic rescale
# --------------------------------------------------------------------------

def elastic_new_mesh(n_hosts_alive: int, *, chips_per_host: int = 8,
                     model_par: int = 16) -> Tuple[Tuple[int, int], Dict]:
    """Largest (data, model) mesh on the surviving hosts.

    Model parallelism is pinned (weights are TP-sharded 16-way); the data
    axis shrinks to the largest extent the remaining chips support.  The
    global batch is preserved by raising gradient-accumulation microbatches
    proportionally (returned in the plan).
    """
    chips = n_hosts_alive * chips_per_host
    data = max(chips // model_par, 1)
    # data extent must divide the old extent for clean batch re-slicing
    while data > 1 and 16 % data not in (0,) and data * model_par > chips:
        data -= 1
    plan = {
        "data": data,
        "model": model_par,
        "microbatch_scale": max(16 // max(data, 1), 1),
    }
    return (data, model_par), plan


# --------------------------------------------------------------------------
# Deterministic fault injection
# --------------------------------------------------------------------------

class FaultInjector:
    """Arm kills against named targets; owning loops poll ``check``.

    ``arm_kill("session:alice", after=2)`` makes the third
    ``check("session:alice")`` return True (fire-once); earlier checks
    count down, unrelated targets are never disturbed.  Loops treat a True
    result exactly like an external preemption: tear the target down and
    release every resource it held.  Deterministic by construction — no
    clocks, no randomness — so tests can assert the precise step a session
    dies at.
    """

    def __init__(self) -> None:
        self._armed: Dict[str, int] = {}
        self.fired: List[str] = []

    def arm_kill(self, target: str, *, after: int = 0) -> None:
        """Fire on the ``after``-th subsequent check of ``target`` (0 = next)."""
        self._armed[target] = int(after)

    def check(self, target: str) -> bool:
        """Poll ``target``; True exactly once when its armed kill fires."""
        remaining = self._armed.get(target)
        if remaining is None:
            return False
        if remaining <= 0:
            del self._armed[target]
            self.fired.append(target)
            return True
        self._armed[target] = remaining - 1
        return False

    @property
    def armed(self) -> Tuple[str, ...]:
        return tuple(sorted(self._armed))
