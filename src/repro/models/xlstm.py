"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelisable)
and sLSTM (scalar memory, sequential scan).

mLSTM uses the stabilised chunkwise-parallel form (same schedule family as
the SSD scan): exponential input gates with a running maximiser m for
numerical stability, matrix memory C: (B, H, P, P) and normaliser n:
(B, H, P).  The ``kernels/mlstm_scan`` Pallas kernel implements the
intra-chunk part; this module is the lowering target for the dry-run and
the oracle for the kernel tests.

sLSTM keeps per-unit scalar state with a true recurrent dependency
(h feeds the next step's gates), so it lowers to a ``lax.scan`` over time —
inherently sequential, as in the paper.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d                      # xLSTM up-projection factor 2
    h = cfg.n_heads
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    return {
        "up_l": layers.dense_init(k1, d, di),       # gated branch
        "up_r": layers.dense_init(k2, d, di),       # skip branch
        "wq": layers.dense_init(k3, di, di),
        "wk": layers.dense_init(k4, di, di),
        "wv": layers.dense_init(k5, di, di),
        "w_if": jax.random.normal(k6, (di, 2 * h), jnp.float32) * 0.01,
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]),
        "norm": layers.rmsnorm_init(di),
        "down": layers.dense_init(jax.random.fold_in(rng, 7), di, d),
    }


def mlstm_specs():
    return {
        "up_l": layers.dense_specs("embed", "mlp"),
        "up_r": layers.dense_specs("embed", "mlp"),
        "wq": layers.dense_specs("mlp", "mlp"),
        "wk": layers.dense_specs("mlp", "mlp"),
        "wv": layers.dense_specs("mlp", "mlp"),
        "w_if": ("mlp", None),
        "b_if": (None,),
        "norm": {"scale": ("mlp",)},
        "down": layers.dense_specs("mlp", "embed"),
    }


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int = 256):
    """Stabilised chunkwise mLSTM.

    q,k,v: (b, s, h, p); i_gate,f_gate: (b, s, h) — raw (pre-activation).
    Returns (b, s, h, p).

    Per head: C_t = f_t C_{t-1} + i_t v_t k_t^T ; y_t = C_t q_t / max(|n_t q_t|,1)
    with log-space stabilisation (m running max), f in log-sigmoid space.
    """
    b, s, h, p = q.shape
    scale = 1.0 / math.sqrt(p)
    lf = jax.nn.log_sigmoid(f_gate)                 # (b,s,h)  log f_t
    li = i_gate                                     # log-space input gate

    qc = min(chunk, s)
    nc = -(-s // qc)
    pad = nc * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)

    qb = q.reshape(b, nc, qc, h, p) * scale
    kb = k.reshape(b, nc, qc, h, p)
    vb = v.reshape(b, nc, qc, h, p)
    lfb = lf.reshape(b, nc, qc, h)
    lib = li.reshape(b, nc, qc, h)

    lf_cum = jnp.cumsum(lfb, axis=2)                       # within-chunk
    # intra-chunk decay matrix: D[q,t] = sum_{t<j<=q} lf_j + li_t  (t<=q)
    seg = lf_cum[:, :, :, None, :] - lf_cum[:, :, None, :, :]   # (b,nc,q,t,h)
    dmat = seg + lib[:, :, None, :, :]
    tmask = jnp.tril(jnp.ones((qc, qc), bool))
    dmat = jnp.where(tmask[None, None, :, :, None], dmat, -jnp.inf)

    # stabiliser: running max across chunks of (total decay + gate mass)
    # chunk-local stabiliser keeps exp() bounded; cross-chunk handled via m.
    m_intra = jnp.max(dmat, axis=3)                        # (b,nc,q,h)

    scores = jnp.einsum("bcqhp,bcthp->bcqth", qb, kb)      # (b,nc,q,t,h)

    # ---- chunk summary state ---------------------------------------------
    decay_to_end = lf_cum[:, :, -1:, :] - lf_cum + lib     # (b,nc,q,h)
    m_state = jnp.max(decay_to_end, axis=2)                # (b,nc,h)
    sk = jnp.exp(decay_to_end - m_state[:, :, None, :])
    states = jnp.einsum("bcthp,bcth,bcthr->bchpr",
                        kb, sk, vb)                        # (b,nc,h,p,p)
    norms = jnp.einsum("bcthp,bcth->bchp", kb, sk)         # (b,nc,h,p)
    chunk_lf = lf_cum[:, :, -1, :]                         # (b,nc,h)

    # ---- inter-chunk recurrence (log-stabilised) ---------------------------
    def step(carry, inp):
        C, n, m = carry                                    # (b,h,p,p),(b,h,p),(b,h)
        st, nr, clf, mst = inp
        m_new = jnp.maximum(m + clf, mst)
        alpha = jnp.exp(m + clf - m_new)
        beta = jnp.exp(mst - m_new)
        C_new = C * alpha[..., None, None] + st * beta[..., None, None]
        n_new = n * alpha[..., None] + nr * beta[..., None]
        return (C_new, n_new, m_new), (C, n, m)            # emit previous

    C0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, (C_prev, n_prev, m_prev) = jax.lax.scan(
        step, (C0, n0, m0),
        (states.transpose(1, 0, 2, 3, 4), norms.transpose(1, 0, 2, 3),
         chunk_lf.transpose(1, 0, 2), m_state.transpose(1, 0, 2)))
    C_prev = C_prev.transpose(1, 0, 2, 3, 4)
    n_prev = n_prev.transpose(1, 0, 2, 3)
    m_prev = m_prev.transpose(1, 0, 2)

    # ---- combine intra + inter --------------------------------------------
    # decay from chunk start to position q: lf_cum[q]
    inter_decay = lf_cum + m_prev[:, :, None, :]           # (b,nc,q,h) log
    m_total = jnp.maximum(m_intra, inter_decay)
    w_intra = jnp.exp(dmat - m_total[:, :, :, None, :])    # (b,nc,q,t,h)
    w_inter = jnp.exp(inter_decay - m_total)               # (b,nc,q,h)

    y_intra = jnp.einsum("bcqth,bcqth,bcthr->bcqhr",
                         scores, w_intra, vb)
    y_inter = jnp.einsum("bcqhp,bchpr->bcqhr",
                         qb * w_inter[..., None], C_prev)
    n_intra = jnp.einsum("bcqth,bcqth->bcqh", scores, w_intra)
    n_inter = jnp.einsum("bcqhp,bchp->bcqh",
                         qb * w_inter[..., None], n_prev)
    denom = jnp.maximum(jnp.abs(n_intra + n_inter),
                        jnp.exp(-m_total))
    y = (y_intra + y_inter) / denom[..., None]
    return y.reshape(b, nc * qc, h, p)[:, :s]


def mlstm_forward(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    from repro.core.remat_policy import tag
    dt = layers._dtype(cfg.dtype)
    b, s, d = x.shape
    di = 2 * d
    h = cfg.n_heads
    p = di // h
    xl = layers.dense(params["up_l"], x, dt)
    xr = layers.dense(params["up_r"], x, dt)
    q = layers.dense(params["wq"], xl, dt).reshape(b, s, h, p)
    k = layers.dense(params["wk"], xl, dt).reshape(b, s, h, p)
    v = layers.dense(params["wv"], xl, dt).reshape(b, s, h, p)
    q = tag("qkv", q)
    gates = xl.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)          # (b,s,h) each
    if cfg.mixer_skip:
        y = (q + v).astype(jnp.float32)  # probe mode: kernel cost added analytically
    else:
        y = mlstm_chunked(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), i_gate, f_gate)
    y = y.reshape(b, s, di).astype(dt)
    y = tag("attn_out", y)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(xr)
    return layers.dense(params["down"], y, dt)


def init_mlstm_state(cfg: ModelConfig, batch: int, n_layers: int):
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    p = di // h
    return {
        "C": jnp.zeros((n_layers, batch, h, p, p), jnp.float32),
        "n": jnp.zeros((n_layers, batch, h, p), jnp.float32),
        "m": jnp.full((n_layers, batch, h), -1e30, jnp.float32),
    }


def mlstm_state_specs():
    return {"C": (None, "batch", None, "sp_seq", "state"),
            "n": (None, "batch", None, "sp_seq"),
            "m": (None, "batch", None)}


def mlstm_decode_step(cfg: ModelConfig, params, x, C, n, m):
    """O(1) mLSTM decode.  x: (B,1,d); C: (B,H,P,P); n: (B,H,P); m: (B,H)."""
    dt = layers._dtype(cfg.dtype)
    b = x.shape[0]
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    p = di // h
    xl = layers.dense(params["up_l"], x, dt)[:, 0]
    xr = layers.dense(params["up_r"], x, dt)[:, 0]
    q = layers.dense(params["wq"], xl[:, None], dt).reshape(b, h, p) \
        * (1.0 / math.sqrt(p))
    k = layers.dense(params["wk"], xl[:, None], dt).reshape(b, h, p)
    v = layers.dense(params["wv"], xl[:, None], dt).reshape(b, h, p)
    gates = xl.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    li, fg = jnp.split(gates, 2, axis=-1)                  # (b,h)
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + m, li)
    alpha = jnp.exp(lf + m - m_new)
    beta = jnp.exp(li - m_new)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = C * alpha[..., None, None] + beta[..., None, None] \
        * jnp.einsum("bhp,bhr->bhpr", kf, vf)
    n_new = n * alpha[..., None] + beta[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhp,bhpr->bhr", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_new)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, di).astype(dt)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(xr[:, None])
    return layers.dense(params["down"], y, dt), C_new, n_new, m_new


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "wx": layers.dense_init(k1, d, 4 * d),
        "wh": layers.dense_init(k2, d, 4 * d),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "norm": layers.rmsnorm_init(d),
        "proj": layers.dense_init(k3, d, d),
    }


def slstm_specs():
    return {
        "wx": layers.dense_specs("embed", "mlp"),
        "wh": layers.dense_specs("embed", "mlp"),
        "bias": ("mlp",),
        "norm": {"scale": ("embed",)},
        "proj": layers.dense_specs("embed", "embed"),
    }


def slstm_forward(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    """Sequential scan over time (true recurrence: h feeds next gates)."""
    dt = layers._dtype(cfg.dtype)
    b, s, d = x.shape
    gx = layers.dense(params["wx"], x, dt) + params["bias"].astype(dt)

    def step(carry, gxt):
        hprev, cprev, nprev, mprev = carry
        g = gxt + layers.dense(params["wh"], hprev, dt)
        zi, zf, zo, zz = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        lf = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(lf + mprev, zi)
        i = jnp.exp(zi - m_new)
        f = jnp.exp(lf + mprev - m_new)
        c_new = f * cprev + i * jnp.tanh(zz)
        n_new = f * nprev + i
        h_new = (jax.nn.sigmoid(zo) * c_new
                 / jnp.maximum(n_new, 1.0)).astype(dt)
        return (h_new, c_new, n_new, m_new), h_new

    h0 = jnp.zeros((b, d), dt)
    c0 = jnp.zeros((b, d), jnp.float32)
    n0 = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), -1e30, jnp.float32)
    _, ys = jax.lax.scan(step, (h0, c0, n0, m0), gx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    return layers.dense(params["proj"], y, dt)


def init_slstm_state(cfg: ModelConfig, batch: int, n_layers: int):
    d = cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, d), jnp.float32),
        "c": jnp.zeros((n_layers, batch, d), jnp.float32),
        "n": jnp.zeros((n_layers, batch, d), jnp.float32),
        "m": jnp.full((n_layers, batch, d), -1e30, jnp.float32),
    }


def slstm_decode_step(cfg: ModelConfig, params, x, h, c, n, m):
    dt = layers._dtype(cfg.dtype)
    g = layers.dense(params["wx"], x, dt)[:, 0] + params["bias"].astype(dt) \
        + layers.dense(params["wh"], h.astype(dt), dt)
    zi, zf, zo, zz = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    lf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(lf + m, zi)
    i = jnp.exp(zi - m_new)
    f = jnp.exp(lf + m - m_new)
    c_new = f * c + i * jnp.tanh(zz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
    y = layers.rmsnorm(params["norm"], h_new[:, None].astype(dt), cfg.norm_eps)
    return layers.dense(params["proj"], y, dt), h_new, c_new, n_new, m_new
