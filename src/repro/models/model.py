"""Unified Model API: family dispatch, abstract input specs, reduced configs.

``Model`` bundles the pure functions for one config:

    model.init(rng)                      -> params
    model.param_specs()                  -> logical-axis pytree
    model.loss_fn(params, batch)         -> scalar loss        (train)
    model.forward(params, batch)         -> logits             (prefill)
    model.decode_init(batch, max_seq)    -> decode state
    model.decode_specs()                 -> logical-axis pytree
    model.decode_fn(params, state, tokens, cache_len) -> (logits, state)

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for the dry-run —
weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import multimodal, transformer, zamba


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    param_specs: Callable
    loss_fn: Callable            # (params, batch) -> loss
    forward: Callable            # (params, batch) -> logits
    decode_init: Optional[Callable] = None
    decode_specs: Optional[Callable] = None
    decode_fn: Optional[Callable] = None
    # (params, state, tokens(B,S)) -> (last_logits, state): one fused
    # full-prompt forward filling the KV cache.  None for families whose
    # decode state is recurrent (ssm/hybrid) or cross-attentive — servers
    # fall back to sequential decode-step prefill.
    prefill_fn: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> Model:
    t = transformer
    if cfg.family in ("dense", "moe"):
        return Model(
            cfg=cfg,
            init=functools.partial(t.lm_init, cfg=cfg),
            param_specs=lambda: t.lm_specs(cfg),
            loss_fn=lambda p, b: t.lm_loss(cfg, p, b),
            forward=lambda p, b: t.lm_forward(cfg, p, b["tokens"])[0],
            decode_init=lambda batch, max_seq: t.lm_decode_init(cfg, batch, max_seq),
            decode_specs=lambda: t.lm_decode_specs(cfg),
            decode_fn=lambda p, s, tok, ln: t.lm_decode_step(cfg, p, s, tok, ln),
            prefill_fn=lambda p, s, tok: t.lm_prefill(cfg, p, s, tok),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=functools.partial(t.xlstm_init, cfg=cfg),
            param_specs=lambda: t.xlstm_specs(cfg),
            loss_fn=lambda p, b: t.xlstm_loss(cfg, p, b),
            forward=lambda p, b: t.xlstm_forward(cfg, p, b["tokens"])[0],
            decode_init=lambda batch, max_seq: t.xlstm_decode_init(cfg, batch, max_seq),
            decode_specs=lambda: t.xlstm_decode_specs(cfg),
            decode_fn=lambda p, s, tok, ln: t.xlstm_decode_step(cfg, p, s, tok, ln),
        )
    if cfg.family == "audio":
        m = multimodal
        return Model(
            cfg=cfg,
            init=functools.partial(m.encdec_init, cfg=cfg),
            param_specs=lambda: m.encdec_specs(cfg),
            loss_fn=lambda p, b: m.encdec_loss(cfg, p, b),
            forward=lambda p, b: m.encdec_forward(
                cfg, p, b["tokens"], b["enc_frames"])[0],
            decode_init=lambda batch, max_seq: m.encdec_decode_init(
            cfg, batch, max_seq),
            decode_specs=lambda: m.encdec_decode_specs(cfg),
            decode_fn=lambda p, s, tok, ln: m.encdec_decode_step(cfg, p, s, tok, ln),
        )
    if cfg.family == "vlm":
        m = multimodal
        return Model(
            cfg=cfg,
            init=functools.partial(m.vlm_init, cfg=cfg),
            param_specs=lambda: m.vlm_specs(cfg),
            loss_fn=lambda p, b: m.vlm_loss(cfg, p, b),
            forward=lambda p, b: m.vlm_forward(
                cfg, p, b["tokens"], b["image_embeds"])[0],
            decode_init=lambda batch, max_seq: m.vlm_decode_init(cfg, batch, max_seq),
            decode_specs=lambda: m.vlm_decode_specs(cfg),
            decode_fn=lambda p, s, tok, ln: m.vlm_decode_step(cfg, p, s, tok, ln),
        )
    if cfg.family == "hybrid":
        z = zamba
        return Model(
            cfg=cfg,
            init=functools.partial(z.zamba_init, cfg=cfg),
            param_specs=lambda: z.zamba_specs(cfg),
            loss_fn=lambda p, b: z.zamba_loss(cfg, p, b),
            forward=lambda p, b: z.zamba_forward(cfg, p, b["tokens"])[0],
            decode_init=lambda batch, max_seq: z.zamba_decode_init(cfg, batch, max_seq),
            decode_specs=lambda: z.zamba_decode_specs(cfg),
            decode_fn=lambda p, s, tok, ln: z.zamba_decode_step(cfg, p, s, tok, ln),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Abstract input specs (dry-run stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch, shape) cell.

    train/prefill: token batches (+ stubbed modality embeddings);
    decode: single-token batch + cache lengths (state comes from
    ``decode_state_specs``).
    """
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
        }
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "audio":
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.image_tokens, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token per sequence, KV/state cache of length seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "cache_len": jax.ShapeDtypeStruct((b,), i32),
    }


def abstract_params(model: Model):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_decode_state(model: Model, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: model.decode_init(batch, max_seq))


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family config: few layers, narrow widths, tiny vocab."""
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    red = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        attention_impl="naive",
        remat=False,
    )
    if cfg.is_moe:
        red.update(n_experts=4, top_k=2, moe_d_ff=32)
    if cfg.family in ("ssm",):
        red.update(slstm_every=2 if cfg.slstm_every else 0, n_layers=4)
    if cfg.family == "hybrid":
        red.update(shared_attn_every=2, n_layers=5, ssm_state=16,
                   ssm_heads=4)
    if cfg.family == "audio":
        red.update(encoder_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        red.update(cross_attn_every=2, n_layers=4, image_tokens=8)
    red.update(overrides)
    return dataclasses.replace(cfg, **red)
