"""Model assembly for all assigned architectures.

Families:
  dense   — decoder-only LM (phi4, minitron, llama3.2, granite-34b)
  moe     — decoder-only LM with MoE FFN (granite-moe, qwen3-moe)
  ssm     — xLSTM stack (mLSTM blocks + periodic sLSTM)
  audio   — whisper-style encoder-decoder (conv frontend stubbed:
            ``enc_frames`` are precomputed frame embeddings)
  vlm     — llama-vision: decoder with cross-attention layers every k
            (vision encoder stubbed: ``image_embeds`` precomputed)
  hybrid  — zamba2: mamba2 blocks + ONE shared attention block applied every
            k layers (weight sharing == the paper's Tensor-sharing mode E)

All stacks scan over layers with stacked parameters; the remat policy comes
from the core compile facade (``repro.core.compile_plan``) so the paper's
lifespan analysis decides, per tagged intermediate, whether it stays
resident in HBM, is recomputed in backward, or is offloaded to pinned host
memory — the joint keep/recompute/offload planner priced by the
``ModelConfig`` hardware knobs (``dma_gbps``, ``device_tflops``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.plan import compile_plan
from repro.models import attention as attn
from repro.models import layers, moe, xlstm
from repro.sharding.rules import constrain

VOCAB_PAD = 256


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# Decoder block (dense / moe)
# ---------------------------------------------------------------------------

def block_init(rng, cfg: ModelConfig, *, cross: bool = False):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "ln1": layers.rmsnorm_init(cfg.d_model),
        "attn": attn.attention_init(k1, cfg),
        "ln2": layers.rmsnorm_init(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_init(k2, cfg)
    elif cfg.d_ff:
        p["mlp"] = layers.swiglu_init(k2, cfg.d_model, cfg.d_ff)
    if cross:
        p["ln_x"] = layers.rmsnorm_init(cfg.d_model)
        p["xattn"] = attn.attention_init(k3, cfg)
        p["xgate"] = jnp.zeros((), jnp.float32)
    return p


def block_specs(cfg: ModelConfig, *, cross: bool = False):
    s = {
        "ln1": layers.rmsnorm_specs(),
        "attn": attn.attention_specs(),
        "ln2": layers.rmsnorm_specs(),
    }
    if cfg.is_moe:
        s["moe"] = moe.moe_specs()
    elif cfg.d_ff:
        s["mlp"] = layers.swiglu_specs()
    if cross:
        s["ln_x"] = layers.rmsnorm_specs()
        s["xattn"] = attn.attention_specs()
        s["xgate"] = ()
    return s


def block_forward(cfg: ModelConfig, p, x, positions, *,
                  kv_x: Optional[jax.Array] = None, causal: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm block; returns (y, moe_aux_loss)."""
    from repro.core.remat_policy import tag
    h = x + attn.attention_forward(
        cfg, p["attn"], layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
        positions=positions, causal=causal)
    if "xattn" in p:
        xa = attn.attention_forward(
            cfg, p["xattn"], layers.rmsnorm(p["ln_x"], h, cfg.norm_eps),
            positions=positions, kv_x=kv_x, causal=False, use_rope=False)
        h = h + jnp.tanh(p["xgate"]).astype(xa.dtype) * xa
    aux = jnp.zeros((), jnp.float32)
    hn = layers.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if cfg.is_moe:
        mo, aux = moe.moe_forward(cfg, p["moe"], hn)
        h = h + tag("mlp_out", mo)
    elif cfg.d_ff:
        h = h + tag("mlp_out", layers.swiglu(p["mlp"], hn,
                                             layers._dtype(cfg.dtype),
                                             skip=cfg.mlp_skip))
    h = tag("block_out", h)
    h = constrain(h, "batch", "seq", "embed")
    return h, aux


def maybe_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked xs, or a python unroll in cost-probe mode.

    Mirrors scan semantics: returns (carry, stacked_ys) where ys pytrees are
    stacked along a new leading axis (or None when body emits None).
    """
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys)
    else:
        stacked = None
    return carry, stacked


def _remat_policy(cfg: ModelConfig, batch_tokens: int):
    # default MemoryPlanConfig: every remat/offload/hardware knob follows
    # cfg, so the installed policy always matches the plan make_train_step
    # reports for the same config
    return compile_plan(cfg, batch_tokens=batch_tokens).offload_policy


def _scan_blocks(cfg: ModelConfig, stacked_params, x, positions, *,
                 kv_x=None, causal=True, n_layers=None):
    """Scan over stacked per-layer params with planner-driven remat."""
    policy = _remat_policy(cfg, x.shape[0] * x.shape[1])

    def body(carry, p):
        h, aux = carry
        h, a = block_forward(cfg, p, h, positions, kv_x=kv_x, causal=causal)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=policy, prevent_cse=True)
    (x, aux), _ = maybe_scan(cfg, body, (x, jnp.zeros((), jnp.float32)),
                             stacked_params)
    return x, aux


def _stack_init(rng, n: int, init_fn):
    rngs = jax.random.split(rng, n)
    return jax.vmap(init_fn)(rngs)


# ---------------------------------------------------------------------------
# Decoder-only LM (dense + moe)
# ---------------------------------------------------------------------------

def lm_init(rng, cfg: ModelConfig):
    k_emb, k_blocks, k_out = jax.random.split(rng, 3)
    pv = padded_vocab(cfg)
    p = {
        "embed": layers.embedding_init(k_emb, pv, cfg.d_model),
        "blocks": _stack_init(k_blocks, cfg.n_layers,
                              lambda r: block_init(r, cfg)),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(k_out, cfg.d_model, pv)
    return p


def lm_specs(cfg: ModelConfig):
    s = {
        "embed": layers.embedding_specs(),
        "blocks": jax.tree_util.tree_map(
            lambda ax: (None,) + tuple(ax),
            block_specs(cfg), is_leaf=lambda v: isinstance(v, tuple)),
        "ln_f": layers.rmsnorm_specs(),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = layers.dense_specs("embed", "vocab")
    return s


def lm_logits(cfg: ModelConfig, params, x):
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x, layers._dtype(cfg.dtype))
    else:
        logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def lm_forward(cfg: ModelConfig, params, tokens):
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, aux = _scan_blocks(cfg, params["blocks"], x, positions)
    return lm_logits(cfg, params, x), aux


def softmax_xent(cfg: ModelConfig, logits, targets):
    """Cross-entropy with padded-vocab masking, fp32 accumulation."""
    pv = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if pv > cfg.vocab:
        neg = jnp.full((pv - cfg.vocab,), -1e30, jnp.float32)
        lf = lf.at[..., cfg.vocab:].set(neg)  # mask padded ids
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(cfg: ModelConfig, params, batch):
    logits, aux = lm_forward(cfg, params, batch["tokens"])
    loss = softmax_xent(cfg, logits, batch["targets"])
    if cfg.is_moe:
        loss = loss + 0.01 * aux
    return loss


# ---- decode ----------------------------------------------------------------

def lm_decode_init(cfg: ModelConfig, batch: int, max_seq: int):
    return attn.init_kv_cache(cfg, batch, max_seq, cfg.n_layers,
                              layers._dtype(cfg.dtype))


def lm_decode_specs(cfg: ModelConfig):
    return attn.kv_cache_specs()


def lm_decode_step(cfg: ModelConfig, params, cache, tokens, cache_len):
    """tokens: (B,) new token ids; cache_len: (B,) current lengths."""
    b = tokens.shape[0]
    x = layers.embed(params["embed"], tokens[:, None],
                     layers._dtype(cfg.dtype))
    x = constrain(x, "batch", None, "embed")

    def body(h, inp):
        p, ck, cv = inp
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        ao, ck, cv = attn.decode_attention(cfg, p["attn"], hn, ck, cv,
                                           cache_len=cache_len)
        h = h + ao
        hn = layers.rmsnorm(p["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            mo, _ = moe.moe_forward(cfg, p["moe"], hn)
            h = h + mo
        elif cfg.d_ff:
            h = h + layers.swiglu(p["mlp"], hn, layers._dtype(cfg.dtype))
        return h, (ck, cv)

    x, (new_k, new_v) = maybe_scan(
        cfg, body, x, (params["blocks"], cache["k"], cache["v"]))
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"k": new_k, "v": new_v}


def lm_prefill(cfg: ModelConfig, params, cache, tokens):
    """Batched prefill: one full-sequence causal forward that fills the KV
    cache, replacing ``S`` sequential :func:`lm_decode_step` calls.

    tokens: (B, S) prompt ids into an empty cache.  Returns
    ``(last_logits, cache)`` where ``last_logits`` is (B, padded_vocab) for
    the final prompt position — exactly what greedy decode samples from —
    and the cache holds all S positions, ready for ``lm_decode_step`` at
    ``cache_len = S``.
    """
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")

    def body(h, inp):
        p, ck, cv = inp
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        ao, ck, cv = attn.prefill_attention(cfg, p["attn"], hn, ck, cv)
        h = h + ao
        hn = layers.rmsnorm(p["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            mo, _ = moe.moe_forward(cfg, p["moe"], hn)
            h = h + mo
        elif cfg.d_ff:
            h = h + layers.swiglu(p["mlp"], hn, layers._dtype(cfg.dtype))
        return h, (ck, cv)

    x, (new_k, new_v) = maybe_scan(
        cfg, body, x, (params["blocks"], cache["k"], cache["v"]))
    logits = lm_logits(cfg, params, x)[:, -1]
    return logits, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# xLSTM stack (family: ssm)
# ---------------------------------------------------------------------------

def xlstm_init(rng, cfg: ModelConfig):
    k_emb, k_m, k_s, k_out = jax.random.split(rng, 4)
    pv = padded_vocab(cfg)
    n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
    n_m = cfg.n_layers - n_s
    p = {
        "embed": layers.embedding_init(k_emb, pv, cfg.d_model),
        "mblocks": _stack_init(k_m, n_m, lambda r: {
            "ln": layers.rmsnorm_init(cfg.d_model),
            "mlstm": xlstm.mlstm_init(r, cfg)}),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
        "unembed": layers.dense_init(k_out, cfg.d_model, pv),
    }
    if n_s:
        p["sblocks"] = _stack_init(k_s, n_s, lambda r: {
            "ln": layers.rmsnorm_init(cfg.d_model),
            "slstm": xlstm.slstm_init(r, cfg)})
    return p


def xlstm_specs(cfg: ModelConfig):
    stack = lambda tree: jax.tree_util.tree_map(
        lambda ax: (None,) + tuple(ax), tree,
        is_leaf=lambda v: isinstance(v, tuple))
    s = {
        "embed": layers.embedding_specs(),
        "mblocks": stack({"ln": layers.rmsnorm_specs(),
                          "mlstm": xlstm.mlstm_specs()}),
        "ln_f": layers.rmsnorm_specs(),
        "unembed": layers.dense_specs("embed", "vocab"),
    }
    if cfg.slstm_every:
        s["sblocks"] = stack({"ln": layers.rmsnorm_specs(),
                              "slstm": xlstm.slstm_specs()})
    return s


def xlstm_forward(cfg: ModelConfig, params, tokens):
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")

    def mbody(h, p):
        h = h + xlstm.mlstm_forward(
            cfg, p["mlstm"], layers.rmsnorm(p["ln"], h, cfg.norm_eps))
        return constrain(h, "batch", "seq", "embed"), None

    def sbody(h, p):
        h = h + xlstm.slstm_forward(
            cfg, p["slstm"], layers.rmsnorm(p["ln"], h, cfg.norm_eps))
        return constrain(h, "batch", "seq", "embed"), None

    if cfg.remat:
        mbody = jax.checkpoint(mbody, prevent_cse=True)
        sbody = jax.checkpoint(sbody, prevent_cse=True)
    # interleave: scan mLSTM groups between each sLSTM layer
    if cfg.slstm_every and "sblocks" in params:
        n_s = cfg.n_layers // cfg.slstm_every
        per = (cfg.n_layers - n_s) // n_s
        m = jax.tree_util.tree_map(
            lambda a: a.reshape((n_s, per) + a.shape[1:]), params["mblocks"])

        def group(h, inp):
            mg, sg = inp
            h, _ = maybe_scan(cfg, mbody, h, mg)
            h, _ = sbody(h, sg)
            return h, None

        x, _ = maybe_scan(cfg, group, x, (m, params["sblocks"]))
    else:
        x, _ = maybe_scan(cfg, mbody, x, params["mblocks"])
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def xlstm_loss(cfg: ModelConfig, params, batch):
    logits, _ = xlstm_forward(cfg, params, batch["tokens"])
    return softmax_xent(cfg, logits, batch["targets"])


def xlstm_decode_init(cfg: ModelConfig, batch: int, max_seq: int):
    n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
    n_m = cfg.n_layers - n_s
    st = {"m": xlstm.init_mlstm_state(cfg, batch, n_m)}
    if n_s:
        st["s"] = xlstm.init_slstm_state(cfg, batch, n_s)
    return st


def xlstm_decode_specs(cfg: ModelConfig):
    s = {"m": xlstm.mlstm_state_specs()}
    if cfg.slstm_every:
        s["s"] = {"h": (None, "batch", None), "c": (None, "batch", None),
                  "n": (None, "batch", None), "m": (None, "batch", None)}
    return s


def xlstm_decode_step(cfg: ModelConfig, params, state, tokens, cache_len):
    x = layers.embed(params["embed"], tokens[:, None],
                     layers._dtype(cfg.dtype))

    def mbody(h, inp):
        p, C, n, m = inp
        y, C2, n2, m2 = xlstm.mlstm_decode_step(
            cfg, p["mlstm"], layers.rmsnorm(p["ln"], h, cfg.norm_eps),
            C, n, m)
        return h + y, (C2, n2, m2)

    ms = state["m"]
    if cfg.slstm_every and "s" in state:
        n_s = cfg.n_layers // cfg.slstm_every
        n_m = cfg.n_layers - n_s
        per = n_m // n_s
        mp = jax.tree_util.tree_map(
            lambda a: a.reshape((n_s, per) + a.shape[1:]), params["mblocks"])
        mst = jax.tree_util.tree_map(
            lambda a: a.reshape((n_s, per) + a.shape[1:]), ms)

        def group(h, inp):
            p_m, st_m, p_s, st_s = inp
            h, new_m = maybe_scan(
                cfg, mbody, h, (p_m, st_m["C"], st_m["n"], st_m["m"]))
            y, hh, cc, nn, mm = xlstm.slstm_decode_step(
                cfg, p_s["slstm"],
                layers.rmsnorm(p_s["ln"], h, cfg.norm_eps),
                st_s["h"], st_s["c"], st_s["n"], st_s["m"])
            return h + y, (new_m, (hh, cc, nn, mm))

        x, (new_ms, new_ss) = maybe_scan(
            cfg, group, x, (mp, mst, params["sblocks"], state["s"]))
        new_m = {
            "C": new_ms[0].reshape(ms["C"].shape),
            "n": new_ms[1].reshape(ms["n"].shape),
            "m": new_ms[2].reshape(ms["m"].shape),
        }
        new_state = {"m": new_m, "s": {
            "h": new_ss[0], "c": new_ss[1], "n": new_ss[2], "m": new_ss[3]}}
    else:
        x, new = maybe_scan(cfg, mbody, x, (params["mblocks"], ms["C"],
                                            ms["n"], ms["m"]))
        new_state = {"m": {"C": new[0], "n": new[1], "m": new[2]}}
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))[:, 0]
    return logits, new_state
