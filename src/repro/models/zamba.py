"""Zamba2-style hybrid (family: hybrid): mamba2 backbone with ONE shared
attention block applied every ``shared_attn_every`` layers.

The shared block's weights are reused at every application — the model-level
realisation of NNTrainer's Tensor-sharing mode ``E`` (time-unrolled weight
sharing, §5.2): one parameter set, many execution sites, gradients
accumulated across applications by autodiff exactly as the paper's
Iteration-lifespan gradient tensors accumulate across unrolled steps.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, ssm
from repro.models.transformer import (_remat_policy, _stack_init,
                                      block_forward, block_init, block_specs,
                                      maybe_scan, padded_vocab, softmax_xent)
from repro.sharding.rules import constrain


def _stack_specs(tree):
    return jax.tree_util.tree_map(lambda ax: (None,) + tuple(ax), tree,
                                  is_leaf=lambda v: isinstance(v, tuple))


def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, tail): n_groups full groups of ``shared_attn_every`` mamba
    layers + shared-attn application; remaining mamba layers as tail."""
    k = cfg.shared_attn_every
    return cfg.n_layers // k, cfg.n_layers % k


def zamba_init(rng, cfg: ModelConfig):
    k_e, k_m, k_s, k_t, k_o = jax.random.split(rng, 5)
    pv = padded_vocab(cfg)
    n_groups, tail = _layout(cfg)
    k = cfg.shared_attn_every
    p = {
        "embed": layers.embedding_init(k_e, pv, cfg.d_model),
        "mblocks": _stack_init(k_m, n_groups * k, lambda r: {
            "ln": layers.rmsnorm_init(cfg.d_model),
            "ssm": ssm.ssm_init(r, cfg)}),
        # ONE shared attention block (E-shared across all applications)
        "shared": block_init(k_s, cfg),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
        "unembed": layers.dense_init(k_o, cfg.d_model, pv),
    }
    if tail:
        p["tail"] = _stack_init(k_t, tail, lambda r: {
            "ln": layers.rmsnorm_init(cfg.d_model),
            "ssm": ssm.ssm_init(r, cfg)})
    return p


def zamba_specs(cfg: ModelConfig):
    _, tail = _layout(cfg)
    s = {
        "embed": layers.embedding_specs(),
        "mblocks": _stack_specs({"ln": layers.rmsnorm_specs(),
                                 "ssm": ssm.ssm_specs(cfg)}),
        "shared": block_specs(cfg),
        "ln_f": layers.rmsnorm_specs(),
        "unembed": layers.dense_specs("embed", "vocab"),
    }
    if tail:
        s["tail"] = _stack_specs({"ln": layers.rmsnorm_specs(),
                                  "ssm": ssm.ssm_specs(cfg)})
    return s


def zamba_forward(cfg: ModelConfig, params, tokens):
    b, s = tokens.shape
    n_groups, tail = _layout(cfg)
    k = cfg.shared_attn_every
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def mbody(h, p):
        h = h + ssm.ssm_forward(
            cfg, p["ssm"], layers.rmsnorm(p["ln"], h, cfg.norm_eps))
        return constrain(h, "batch", "seq", "embed"), None

    if cfg.remat:
        mbody = jax.checkpoint(mbody, prevent_cse=True)

    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["mblocks"])
    policy = _remat_policy(cfg, b * s)

    def group_body(h, mg):
        h, _ = maybe_scan(cfg, mbody, h, mg)
        # shared attention block: same params every application (mode E)
        h, _ = block_forward(cfg, params["shared"], h, positions)
        return h, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body, policy=policy,
                                    prevent_cse=True)
    x, _ = maybe_scan(cfg, group_body, x, grouped)
    if tail:
        x, _ = maybe_scan(cfg, mbody, x, params["tail"])
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def zamba_loss(cfg: ModelConfig, params, batch):
    logits, _ = zamba_forward(cfg, params, batch["tokens"])
    return softmax_xent(cfg, logits, batch["targets"])


def zamba_decode_init(cfg: ModelConfig, batch: int, max_seq: int):
    n_groups, tail = _layout(cfg)
    k = cfg.shared_attn_every
    st = {
        "ssm": ssm.init_ssm_state(cfg, batch, n_groups * k),
        "attn": attn.init_kv_cache(cfg, batch, max_seq, n_groups,
                                   layers._dtype(cfg.dtype)),
    }
    if tail:
        st["tail"] = ssm.init_ssm_state(cfg, batch, tail)
    return st


def zamba_decode_specs(cfg: ModelConfig):
    _, tail = _layout(cfg)
    s = {"ssm": ssm.ssm_state_specs(), "attn": attn.kv_cache_specs()}
    if tail:
        s["tail"] = ssm.ssm_state_specs()
    return s


def zamba_decode_step(cfg: ModelConfig, params, state, tokens, cache_len):
    n_groups, tail = _layout(cfg)
    k = cfg.shared_attn_every
    dt = layers._dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens[:, None], dt)

    def mstep(h, inp):
        p, sh, sc = inp
        y, sh2, sc2 = ssm.ssm_decode_step(
            cfg, p["ssm"], layers.rmsnorm(p["ln"], h, cfg.norm_eps), sh, sc)
        return h + y, (sh2, sc2)

    grouped_p = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["mblocks"])
    grouped_s = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), state["ssm"])

    def group_step(h, inp):
        mp, mst, ck, cv = inp
        h, new_m = maybe_scan(cfg, mstep, h, (mp, mst["h"], mst["conv"]))
        hn = layers.rmsnorm(params["shared"]["ln1"], h, cfg.norm_eps)
        ao, ck, cv = attn.decode_attention(cfg, params["shared"]["attn"],
                                           hn, ck, cv, cache_len=cache_len)
        h = h + ao
        hn = layers.rmsnorm(params["shared"]["ln2"], h, cfg.norm_eps)
        h = h + layers.swiglu(params["shared"]["mlp"], hn, dt)
        return h, (new_m, ck, cv)

    x, (new_m, nk, nv) = maybe_scan(
        cfg, group_step, x,
        (grouped_p, grouped_s, state["attn"]["k"], state["attn"]["v"]))
    new_state = {
        "ssm": {"h": new_m[0].reshape(state["ssm"]["h"].shape),
                "conv": new_m[1].reshape(state["ssm"]["conv"].shape)},
        "attn": {"k": nk, "v": nv},
    }
    if tail:
        x, new_t = maybe_scan(
            cfg, mstep, x, (params["tail"], state["tail"]["h"],
                            state["tail"]["conv"]))
        new_state["tail"] = {"h": new_t[0], "conv": new_t[1]}
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, dt)[:, 0]
    return logits, new_state
