"""Shared neural-net layers (pure JAX, logical-axis-annotated).

Parameters are plain nested dicts; each initializer has a matching
``*_specs`` helper returning logical axes for the sharding rules.  All
matmuls cast to the config compute dtype (bf16 on TPU) with fp32 params —
the standard mixed-precision recipe.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import constrain


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_specs():
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_specs():
    return {"scale": ("embed",), "bias": ("embed",)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, *, bias: bool = False):
    p = {"kernel": jax.random.normal(rng, (d_in, d_out), jnp.float32)
         * (1.0 / math.sqrt(d_in))}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_specs(in_axis: Optional[str], out_axis: Optional[str],
                *, bias: bool = False):
    p = {"kernel": (in_axis, out_axis)}
    if bias:
        p["bias"] = (out_axis,)
    return p


def dense(params, x, compute_dtype=jnp.bfloat16):
    y = x.astype(compute_dtype) @ params["kernel"].astype(compute_dtype)
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def embedding_init(rng, vocab: int, d: int):
    return {"table": jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02}


def embedding_specs():
    return {"table": ("vocab", "embed")}


def embed(params, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(params["table"], tokens, axis=0).astype(compute_dtype)


def unembed(params, x, compute_dtype=jnp.bfloat16):
    """Logits projection (tied or untied table, (V, d) layout)."""
    return x.astype(compute_dtype) @ params["table"].astype(compute_dtype).T


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                   # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,s,hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (...,s,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(rng, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "gate": dense_init(k1, d, d_ff),
        "up": dense_init(k2, d, d_ff),
        "down": dense_init(k3, d_ff, d),
    }


def swiglu_specs():
    return {
        "gate": dense_specs("embed", "mlp"),
        "up": dense_specs("embed", "mlp"),
        "down": dense_specs("mlp", "embed"),
    }


def swiglu(params, x, compute_dtype=jnp.bfloat16, *, skip: bool = False):
    from repro.core.remat_policy import tag
    if skip:
        return x  # probe mode: fused-kernel cost added analytically
    g = dense(params["gate"], x, compute_dtype)
    u = dense(params["up"], x, compute_dtype)
    h = jax.nn.silu(g) * u
    h = tag("mlp_hidden", h)
    h = constrain(h, "batch", "seq", "mlp")
    return dense(params["down"], h, compute_dtype)


def gelu_mlp_init(rng, d: int, d_ff: int, *, bias: bool = True):
    k1, k2 = jax.random.split(rng)
    return {"up": dense_init(k1, d, d_ff, bias=bias),
            "down": dense_init(k2, d_ff, d, bias=bias)}


def gelu_mlp_specs(*, bias: bool = True):
    return {"up": dense_specs("embed", "mlp", bias=bias),
            "down": dense_specs("mlp", "embed", bias=bias)}


def gelu_mlp(params, x, compute_dtype=jnp.bfloat16):
    from repro.core.remat_policy import tag
    h = jax.nn.gelu(dense(params["up"], x, compute_dtype))
    h = tag("mlp_hidden", h)
    h = constrain(h, "batch", "seq", "mlp")
    return dense(params["down"], h, compute_dtype)
