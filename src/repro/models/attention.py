"""GQA attention: naive, blockwise (memory-efficient online softmax), and
Pallas flash-attention backends, plus KV-cache decode.

The blockwise implementation is the compile-target for large sequences (the
Pallas kernel targets real TPUs; ``interpret=True`` validates it on CPU).
Both share the same math as ``kernels/flash_attention/ref.py``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init / specs
# ---------------------------------------------------------------------------

def attention_init(rng, cfg: ModelConfig, *, d_q_in: int = 0, d_kv_in: int = 0):
    d = cfg.d_model
    d_q_in = d_q_in or d
    d_kv_in = d_kv_in or d
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "wq": layers.dense_init(k1, d_q_in, cfg.n_heads * hd),
        "wk": layers.dense_init(k2, d_kv_in, cfg.n_kv_heads * hd),
        "wv": layers.dense_init(k3, d_kv_in, cfg.n_kv_heads * hd),
        "wo": layers.dense_init(k4, cfg.n_heads * hd, d),
    }


def attention_specs():
    # Weight out-dims use the "qkv" logical axis (H*hd, always divisible by
    # the model axis); "heads"/"kv_heads" are ACTIVATION axes that fall back
    # to replicated when the head count is not divisible (GSPMD then
    # gathers the weight or the activation — both are semantics-preserving).
    return {
        "wq": layers.dense_specs("embed", "qkv"),
        "wk": layers.dense_specs("embed", "qkv"),
        "wv": layers.dense_specs("embed", "qkv"),
        "wo": layers.dense_specs("qkv", "embed"),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B,S,KV,hd) -> (B,S,KV*groups,hd) by repeating each kv head."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)) \
              .reshape(b, s, kv * groups, hd)


def naive_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd).  O(Sq*Sk) memory — small seq only."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(qi >= ki, scores, NEG_INF)
    if kv_len is not None:
        mask = jnp.arange(sk)[None, None, None, :] < kv_len[:, None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_attention(q, k, v, *, causal: bool, block_q: int = 512,
                        block_kv: int = 1024, q_offset: int = 0,
                        unroll: bool = False):
    """Flash-style online-softmax attention in pure jnp, scanning KV blocks.

    Memory: O(Sq * block_kv) instead of O(Sq * Sk).  This is what the
    dry-run lowers for 32k/500k sequences; the Pallas kernel implements the
    same schedule with explicit VMEM tiling for real TPUs.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    n_q = -(-sq // block_q)
    n_kv = -(-sk // block_kv)
    # pad to block multiples
    pq = n_q * block_q - sq
    pkv = n_kv * block_kv - sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))

    qb = q.reshape(b, n_q, block_q, h, hd)
    kb = k.reshape(b, n_kv, block_kv, h, hd)
    vb = v.reshape(b, n_kv, block_kv, h, hd)

    def per_qblock(qi, q_blk):
        # q_blk: (b, block_q, h, hd)
        q_pos = qi * block_q + jnp.arange(block_q) + q_offset

        def kv_step(carry, kv_idx):
            acc, m, l = carry
            k_blk = kb[:, kv_idx]
            v_blk = vb[:, kv_idx]
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk
                           ).astype(jnp.float32) * scale
            k_pos = kv_idx * block_kv + jnp.arange(block_kv)
            valid = k_pos[None, :] < sk
            if causal:
                valid = valid & (q_pos[:, None] >= k_pos[None, :])
            s = jnp.where(valid[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), v_blk).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        # scan all kv blocks; masked blocks contribute nothing but keep the
        # schedule static (needed for lowering); causal skipping happens in
        # the Pallas kernel on real hardware.  ``unroll`` flattens the loop
        # for cost-probe lowering (XLA counts while bodies once).
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(n_kv),
                                      unroll=n_kv if unroll else 1)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (b, block_q, h, hd)

    def q_step(_, i):
        return None, per_qblock(i, qb[:, i])

    _, outs = jax.lax.scan(q_step, None, jnp.arange(n_q),
                           unroll=n_q if unroll else 1)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_q * block_q, h, hd)
    return out[:, :sq]


def attention_forward(cfg: ModelConfig, params, x, *, positions,
                      kv_x: Optional[jax.Array] = None,
                      causal: bool = True,
                      use_rope: bool = True) -> jax.Array:
    """Full attention sub-layer: proj -> rope -> attend -> out-proj.

    ``kv_x`` switches to cross-attention (keys/values from the encoder /
    image embeddings)."""
    from repro.core.remat_policy import tag
    dt = layers._dtype(cfg.dtype)
    b, s, _ = x.shape
    kv_src = x if kv_x is None else kv_x
    q = layers.dense(params["wq"], x, dt).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = layers.dense(params["wk"], kv_src, dt).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(params["wv"], kv_src, dt).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    if use_rope and kv_x is None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = tag("qkv", q)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if cfg.attention_impl == "skip":
        # cost-probe differencing mode: bypass the S^2 mixing entirely so
        # the probe isolates non-attention FLOPs/bytes; the kernel-true
        # attention cost is added back analytically (launch/adjust.py)
        o = q + v
    elif cfg.attention_impl == "naive" or s <= cfg.block_q:
        o = naive_attention(q, k, v, causal=causal and kv_x is None)
    elif cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal=causal and kv_x is None,
                            block_q=cfg.block_q, block_kv=cfg.block_kv)
    else:
        o = blockwise_attention(q, k, v, causal=causal and kv_x is None,
                                block_q=cfg.block_q, block_kv=cfg.block_kv,
                                unroll=cfg.unroll_layers)
    o = tag("attn_out", o)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return layers.dense(params["wo"], o, dt)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
    }


def kv_cache_specs():
    return {"k": (None, "batch", "kv_seq", "kv_heads", None),
            "v": (None, "batch", "kv_seq", "kv_heads", None)}


def prefill_attention(cfg: ModelConfig, params, x, cache_k, cache_v
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched prefill: project/rope the whole prompt at once, write it
    into ``cache[:, :S]``, attend causally.

    One fused full-sequence forward replaces S sequential
    :func:`decode_attention` steps — same math (rope at positions 0..S-1,
    K/V stored in the cache dtype, attention over the stored values), so
    the filled cache and the last-position logits match the sequential
    fill to float tolerance.

    x: (B, S, d); cache_k/v: (B, max_seq, KV, hd), assumed empty (the
    prompt starts at position 0).  Returns (out, new_k, new_v).
    """
    dt = layers._dtype(cfg.dtype)
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = layers.dense(params["wq"], x, dt).reshape(b, s, cfg.n_heads, hd)
    k = layers.dense(params["wk"], x, dt).reshape(b, s, cfg.n_kv_heads, hd)
    v = layers.dense(params["wv"], x, dt).reshape(b, s, cfg.n_kv_heads, hd)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    cache_k = cache_k.at[:, :s].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[:, :s].set(v.astype(cache_v.dtype))

    groups = cfg.n_heads // cfg.n_kv_heads
    # attend over the *stored* K/V so dtype rounding matches decode exactly
    kk = _repeat_kv(cache_k[:, :s], groups)
    vv = _repeat_kv(cache_v[:, :s], groups)
    o = naive_attention(q, kk, vv, causal=True)
    o = constrain(o, "batch", "seq", "heads", None)
    o = o.reshape(b, s, cfg.n_heads * hd)
    return layers.dense(params["wo"], o, dt), cache_k, cache_v


def decode_attention(cfg: ModelConfig, params, x, cache_k, cache_v, *,
                     cache_len: jax.Array, layer_idx: int = 0
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: append to cache, attend over the prefix.

    x: (B, 1, d); cache_k/v: (B, max_seq, KV, hd); cache_len: (B,) current
    lengths.  Returns (out, new_k, new_v).
    """
    dt = layers._dtype(cfg.dtype)
    b = x.shape[0]
    hd = cfg.head_dim
    q = layers.dense(params["wq"], x, dt).reshape(b, 1, cfg.n_heads, hd)
    k = layers.dense(params["wk"], x, dt).reshape(b, 1, cfg.n_kv_heads, hd)
    v = layers.dense(params["wv"], x, dt).reshape(b, 1, cfg.n_kv_heads, hd)
    pos = cache_len[:, None]
    q = layers.apply_rope(q, pos, cfg.rope_theta)
    k = layers.apply_rope(k, pos, cfg.rope_theta)

    # scatter the new K/V at position cache_len
    oh = jax.nn.one_hot(cache_len, cache_k.shape[1], dtype=dt)   # (B, max_seq)
    cache_k = cache_k * (1 - oh)[:, :, None, None] + \
        oh[:, :, None, None] * k.astype(cache_k.dtype)
    cache_v = cache_v * (1 - oh)[:, :, None, None] + \
        oh[:, :, None, None] * v.astype(cache_v.dtype)

    groups = cfg.n_heads // cfg.n_kv_heads
    kk = _repeat_kv(cache_k, groups)
    vv = _repeat_kv(cache_v, groups)
    o = naive_attention(q, kk, vv, causal=False, kv_len=cache_len + 1)
    o = constrain(o, "batch", None, "heads", None)
    o = o.reshape(b, 1, cfg.n_heads * hd)
    return layers.dense(params["wo"], o, dt), cache_k, cache_v
