"""Mamba2 (SSD) block: chunked training scan + O(1) decode state update.

Training uses the chunked state-space-dual formulation (intra-chunk
quadratic attention-like term + inter-chunk recurrent state passing) — the
TPU-friendly layout: all chunk math is batched einsums over hardware-aligned
tiles, the only sequential dependency is a length-S/Q ``lax.scan`` over
chunk states.  ``kernels/ssm_scan`` implements the same schedule as a
Pallas kernel.

Decode maintains per-head state h: (B, H, P, N) with the classic update
    h <- exp(dt*A) * h + dt * (B ⊗ x);   y = (C · h) + D*x
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constrain


def ssm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state or 64
    h = cfg.n_ssm_heads
    p = di // h
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    return {
        # fused in-proj: [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": layers.dense_init(k1, d, 2 * di + 2 * n + h),
        "conv": jax.random.normal(k2, (cfg.ssm_conv, di + 2 * n),
                                  jnp.float32) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": layers.rmsnorm_init(di),
        "out_proj": layers.dense_init(k3, di, d),
    }


def ssm_specs(cfg: ModelConfig):
    return {
        "in_proj": layers.dense_specs("embed", "mlp"),
        "conv": (None, "mlp"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": ("mlp",)},
        "out_proj": layers.dense_specs("mlp", "embed"),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k].

    Lower-triangular cumulative log-decay matrix used by the SSD dual form.
    """
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int = 256):
    """Chunked SSD scan.

    x: (b, s, h, p)    per-head inputs
    dt: (b, s, h)      softplus'd timestep
    A: (h,)            negative decay rate
    B, C: (b, s, n)    input/output projections (single group)
    returns y: (b, s, h, p)
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)

    dA = dtc * (-jnp.exp(A))[None, None, None, :]        # (b,nc,q,h) log-decay
    dA_cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum

    # ---- intra-chunk (quadratic within q) --------------------------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,nc,h,q,q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)       # (b,nc,q,q)
    y_diag = jnp.einsum("bcqk,bchqk,bckh,bckhp->bcqhp",
                        scores, L, dtc, xc)

    # ---- chunk states -----------------------------------------------------
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        Bc, dtc * decay_to_end, xc)          # (b,nc,h,n,p)

    # ---- inter-chunk recurrence (the only sequential part) ---------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,nc,h)

    def step(carry, inp):
        st, dec = inp                                        # (b,h,n,p),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit PREVIOUS

    init = jnp.zeros((b, h, n, p), x.dtype)
    _, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,n,p)

    # ---- inter-chunk contribution -----------------------------------------
    state_decay = jnp.exp(dA_cum)                            # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       Cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, nc * q, h, p)
    return y[:, :s]


def ssm_forward(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Training/prefill path."""
    from repro.core.remat_policy import tag
    dt_ = layers._dtype(cfg.dtype)
    b, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state or 64, cfg.n_ssm_heads
    p = di // h

    zxbcdt = layers.dense(params["in_proj"], x, dt_)
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    # depthwise causal conv over (x, B, C)
    xbc = jnp.concatenate([xin, B, C], axis=-1)
    w = params["conv"].astype(dt_)                    # (K, di+2n)
    kk = w.shape[0]
    xbc_pad = jnp.pad(xbc, ((0, 0), (kk - 1, 0), (0, 0)))
    xbc = sum(xbc_pad[:, i:i + s] * w[i] for i in range(kk))
    xbc = jax.nn.silu(xbc)
    xin, B, C = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])      # (b,s,h)
    xh = xin.reshape(b, s, h, p)
    xh = tag("ssm_in", xh)
    xh = constrain(xh, "batch", "seq", "heads", None)
    if cfg.mixer_skip:
        y = xh.astype(jnp.float32)    # probe mode: kernel cost added analytically
    else:
        y = ssd_chunked(xh.astype(jnp.float32), dt, params["A_log"],
                        B.astype(jnp.float32), C.astype(jnp.float32))
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(dt_)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return layers.dense(params["out_proj"], y, dt_)


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32):
    h, n = cfg.n_ssm_heads, cfg.ssm_state or 64
    p = cfg.d_inner // h
    return {
        "h": jnp.zeros((n_layers, batch, h, n, p), dtype),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1,
                           cfg.d_inner + 2 * n), dtype),
    }


def ssm_state_specs():
    return {"h": (None, "batch", None, "state", None),
            "conv": (None, "batch", None, "mlp")}


def ssm_decode_step(cfg: ModelConfig, params, x, state_h, state_conv
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token state update.  x: (B,1,d); state_h: (B,H,N,P)."""
    dt_ = layers._dtype(cfg.dtype)
    b = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state or 64, cfg.n_ssm_heads
    p = di // h

    zxbcdt = layers.dense(params["in_proj"], x, dt_)[:, 0]
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    # rolling conv buffer
    xbc_new = jnp.concatenate([xin, B, C], axis=-1)            # (B, di+2n)
    w = params["conv"].astype(dt_)
    window = jnp.concatenate([state_conv.astype(dt_),
                              xbc_new[:, None]], axis=1)       # (B,K,di+2n)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    new_conv = window[:, 1:]
    xin, B, C = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    dA = jnp.exp(dt * (-jnp.exp(params["A_log"]))[None])              # (B,h)
    xh = xin.reshape(b, h, p).astype(jnp.float32)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", B.astype(jnp.float32), dt, xh)
    new_h = state_h * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), new_h)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(dt_)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z[:, None]),
                       cfg.norm_eps)
    return layers.dense(params["out_proj"], y, dt_), new_h, new_conv
