"""Mixture-of-Experts with expert parallelism (GShard-style dispatch).

Top-k token-choice routing with capacity: tokens are grouped (one group per
sequence), each group dispatches at most ``capacity`` tokens per expert via
one-hot combine/dispatch einsums — the formulation GSPMD shards cleanly
with experts on the ``model`` mesh axis (expert parallelism) and groups on
``data``.  Overflowed tokens are dropped (their output falls back to the
residual stream), underflow is padding — standard Switch/GShard semantics.

The router runs in fp32 (standard practice for numerical stability of the
softmax over experts).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constrain


def moe_init(rng, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": layers.dense_init(k1, d, e),
        "gate": jax.random.normal(k2, (e, d, f), jnp.float32) * s,
        "up": jax.random.normal(k3, (e, d, f), jnp.float32) * s,
        "down": jax.random.normal(k4, (e, f, d), jnp.float32)
        * (1.0 / math.sqrt(f)),
    }


def moe_specs():
    return {
        "router": layers.dense_specs("embed", None),
        "gate": ("expert", "embed", "mlp"),
        "up": ("expert", "embed", "mlp"),
        "down": ("expert", "mlp", "embed"),
    }


def _top_k_mask(router_probs: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """(G,S,E) probs -> (G,S,E) selection mask and renormalised weights."""
    topv, topi = jax.lax.top_k(router_probs, k)                # (G,S,k)
    mask = jax.nn.one_hot(topi, router_probs.shape[-1],
                          dtype=router_probs.dtype).sum(axis=-2)  # (G,S,E)
    weights = router_probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return mask, weights


MAX_GROUP = 4096  # tokens per dispatch group: bounds capacity-buffer size


def moe_forward(cfg: ModelConfig, params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (B, S, d), aux-loss scalar.

    Dispatch groups are sub-sequences of at most MAX_GROUP tokens: the
    (G, S_g, E, C) one-hot buffers scale with S_g * C ~ S_g^2 * k / E, so
    long sequences are regrouped before routing (routing is per-token, so
    this is exact).
    """
    from repro.core.remat_policy import tag
    dt = layers._dtype(cfg.dtype)
    b0, s0, d = x.shape
    if s0 > MAX_GROUP:
        assert s0 % MAX_GROUP == 0
        x = x.reshape(b0 * (s0 // MAX_GROUP), MAX_GROUP, d)
    g, s, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = int(math.ceil(s * k / e * cfg.capacity_factor))
    capacity = max(capacity, 1)

    router_logits = (x.astype(jnp.float32)
                     @ params["router"]["kernel"].astype(jnp.float32))  # (G,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    mask, weights = _top_k_mask(probs, k)

    # load-balancing auxiliary loss (Switch): E * sum(f_e * p_e)
    frac_tokens = mask.mean(axis=(0, 1))          # (E,)
    frac_probs = probs.mean(axis=(0, 1))          # (E,)
    aux_loss = e * jnp.sum(frac_tokens * frac_probs)

    # position of each token within its expert's capacity buffer
    pos_in_expert = jnp.cumsum(mask, axis=1) * mask - 1.0        # (G,S,E)
    in_capacity = (pos_in_expert < capacity) & (mask > 0)
    pos_clipped = jnp.clip(pos_in_expert, 0, capacity - 1).astype(jnp.int32)
    # dispatch: (G,S,E,C) one-hot over capacity slots
    dispatch = jax.nn.one_hot(pos_clipped, capacity, dtype=dt) \
        * in_capacity[..., None].astype(dt)
    combine = dispatch * weights[..., None].astype(dt)

    if cfg.moe_impl == "gather":
        # ----- gather/scatter dispatch (beyond-paper perf iteration) -------
        # The one-hot einsum dispatch costs 2*S*E*C*d FLOPs per group —
        # for small experts it dwarfs the expert FFN itself.  Here tokens
        # are routed with take_along_axis gathers (O(E*C*d) bytes, no
        # dispatch FLOPs) and combined with a top-k weighted gather.
        # slot_token[g,e,c] = index of the token in slot c of expert e
        order = jnp.argsort(
            jnp.where(in_capacity, pos_clipped, s + 1), axis=1)  # (G,S,E)
        slot_token = order[:, :capacity, :].transpose(0, 2, 1)    # (G,E,C)
        token_valid = (jnp.take_along_axis(
            in_capacity.transpose(0, 2, 1), slot_token, axis=2))  # (G,E,C)
        expert_in = jnp.take_along_axis(
            x.astype(dt)[:, None], slot_token[..., None], axis=2)  # (G,E,C,d)
        expert_in = expert_in * token_valid[..., None].astype(dt)
        expert_in = tag("expert_in", expert_in)
        expert_in = constrain(expert_in, "batch", "expert", None, None)

        gate = jnp.einsum("gecd,edf->gecf", expert_in,
                          params["gate"].astype(dt))
        up = jnp.einsum("gecd,edf->gecf", expert_in, params["up"].astype(dt))
        hidden = tag("mlp_hidden", jax.nn.silu(gate) * up)
        hidden = constrain(hidden, "batch", "expert", None, "mlp")
        expert_out = jnp.einsum("gecf,efd->gecd", hidden,
                                params["down"].astype(dt))
        expert_out = constrain(expert_out, "batch", "expert", None, None)

        # combine: for each token, gather its top-k expert outputs
        topv, topi = jax.lax.top_k(weights, k)                    # (G,S,k)
        tok_pos = jnp.take_along_axis(pos_clipped, topi, axis=2)  # (G,S,k)
        tok_ok = jnp.take_along_axis(
            in_capacity, topi, axis=2)                            # (G,S,k)
        flat = expert_out.reshape(g, e * capacity, d)             # (G,EC,d)
        gather_idx = topi * capacity + tok_pos                    # (G,S,k)
        picked = jnp.take_along_axis(
            flat[:, None], gather_idx.transpose(0, 2, 1)[..., None],
            axis=2)                                               # (G,k,S,d)
        picked = picked.transpose(0, 2, 1, 3)                     # (G,S,k,d)
        out = jnp.sum(picked * (topv * tok_ok).astype(dt)[..., None],
                      axis=2)
        return out.reshape(b0, s0, d).astype(dt), aux_loss.astype(jnp.float32)

    dispatch = constrain(dispatch, "batch", "seq", "expert", None)
    # gather expert inputs: (G,E,C,d)
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, x.astype(dt))
    expert_in = tag("expert_in", expert_in)
    expert_in = constrain(expert_in, "batch", "expert", None, None)

    if cfg.moe_ffn_skip:
        # probe mode: fused expert-FFN kernel cost added analytically
        expert_out = expert_in
    else:
        # expert FFN (SwiGLU), experts sharded on 'model'
        gate = jnp.einsum("gecd,edf->gecf", expert_in,
                          params["gate"].astype(dt))
        up = jnp.einsum("gecd,edf->gecf", expert_in, params["up"].astype(dt))
        hidden = tag("mlp_hidden", jax.nn.silu(gate) * up)
        hidden = constrain(hidden, "batch", "expert", None, "mlp")
        expert_out = jnp.einsum("gecf,efd->gecd", hidden,
                                params["down"].astype(dt))
        expert_out = constrain(expert_out, "batch", "expert", None, None)

    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    return out.reshape(b0, s0, d).astype(dt), aux_loss.astype(jnp.float32)
