"""Encoder-decoder (whisper-style) and VLM (llama-vision-style) backbones.

Modality frontends are STUBS per the assignment: ``input_specs`` provides
precomputed frame embeddings (audio) / patch embeddings (vision); only the
transformer backbone is modelled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers
from repro.models.transformer import (_remat_policy, _scan_blocks,
                                      _stack_init, block_forward, block_init,
                                      block_specs, maybe_scan, padded_vocab,
                                      softmax_xent)
from repro.sharding.rules import constrain


def _stack_specs(tree):
    return jax.tree_util.tree_map(lambda ax: (None,) + tuple(ax), tree,
                                  is_leaf=lambda v: isinstance(v, tuple))


# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder (family: audio)
# ---------------------------------------------------------------------------

def encdec_init(rng, cfg: ModelConfig):
    k_e, k_enc, k_dec, k_out = jax.random.split(rng, 4)
    pv = padded_vocab(cfg)
    return {
        "embed": layers.embedding_init(k_e, pv, cfg.d_model),
        "enc_blocks": _stack_init(k_enc, cfg.encoder_layers,
                                  lambda r: block_init(r, cfg)),
        "enc_ln": layers.rmsnorm_init(cfg.d_model),
        "dec_blocks": _stack_init(k_dec, cfg.n_layers,
                                  lambda r: block_init(r, cfg, cross=True)),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
        "unembed": layers.dense_init(k_out, cfg.d_model, pv),
    }


def encdec_specs(cfg: ModelConfig):
    return {
        "embed": layers.embedding_specs(),
        "enc_blocks": _stack_specs(block_specs(cfg)),
        "enc_ln": layers.rmsnorm_specs(),
        "dec_blocks": _stack_specs(block_specs(cfg, cross=True)),
        "ln_f": layers.rmsnorm_specs(),
        "unembed": layers.dense_specs("embed", "vocab"),
    }


def encdec_encode(cfg: ModelConfig, params, enc_frames):
    """enc_frames: (B, T_enc, d) precomputed frame embeddings (conv stub)."""
    b, t, _ = enc_frames.shape
    x = enc_frames.astype(layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    x, _ = _scan_blocks(cfg, params["enc_blocks"], x, positions,
                        causal=False)
    return layers.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def encdec_forward(cfg: ModelConfig, params, tokens, enc_frames):
    enc = encdec_encode(cfg, params, enc_frames)
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, aux = _scan_blocks(cfg, params["dec_blocks"], x, positions,
                          kv_x=enc, causal=True)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab"), aux


def encdec_loss(cfg: ModelConfig, params, batch):
    logits, _ = encdec_forward(cfg, params, batch["tokens"],
                               batch["enc_frames"])
    return softmax_xent(cfg, logits, batch["targets"])


def encdec_decode_init(cfg: ModelConfig, batch: int, max_seq: int):
    cache = attn.init_kv_cache(cfg, batch, max_seq, cfg.n_layers,
                               layers._dtype(cfg.dtype))
    # cross-attention K/V are computed once from the encoder output and
    # cached per decode session
    hd = cfg.head_dim
    cache["xk"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                             cfg.n_kv_heads, hd), layers._dtype(cfg.dtype))
    cache["xv"] = jnp.zeros_like(cache["xk"])
    return cache


def encdec_decode_specs(cfg: ModelConfig):
    s = attn.kv_cache_specs()
    s["xk"] = (None, "batch", None, "kv_heads", None)
    s["xv"] = (None, "batch", None, "kv_heads", None)
    return s


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens, cache_len):
    b = tokens.shape[0]
    dt = layers._dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens[:, None], dt)

    def body(h, inp):
        p, ck, cv, xk, xv = inp
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        ao, ck, cv = attn.decode_attention(cfg, p["attn"], hn, ck, cv,
                                           cache_len=cache_len)
        h = h + ao
        # cross-attention against the precomputed encoder K/V
        hn = layers.rmsnorm(p["ln_x"], h, cfg.norm_eps)
        q = layers.dense(p["xattn"]["wq"], hn, dt).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        groups = cfg.n_heads // cfg.n_kv_heads
        xo = attn.naive_attention(q, attn._repeat_kv(xk, groups),
                                  attn._repeat_kv(xv, groups), causal=False)
        xo = layers.dense(p["xattn"]["wo"],
                          xo.reshape(b, 1, cfg.n_heads * cfg.head_dim), dt)
        h = h + jnp.tanh(p["xgate"]).astype(dt) * xo
        hn = layers.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + layers.swiglu(p["mlp"], hn, dt)
        return h, (ck, cv)

    x, (nk, nv) = maybe_scan(
        cfg, body, x, (params["dec_blocks"], cache["k"], cache["v"],
                       cache["xk"], cache["xv"]))
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, dt)[:, 0]
    return logits, {**cache, "k": nk, "v": nv}


# ---------------------------------------------------------------------------
# VLM: decoder with cross-attention super-blocks (family: vlm)
# ---------------------------------------------------------------------------

def vlm_init(rng, cfg: ModelConfig):
    k_e, k_b, k_o = jax.random.split(rng, 3)
    pv = padded_vocab(cfg)
    k = cfg.cross_attn_every
    n_super = cfg.n_layers // k
    return {
        "embed": layers.embedding_init(k_e, pv, cfg.d_model),
        # each super-block: (k-1) self-attn blocks + 1 cross-attn block
        "self_blocks": _stack_init(
            k_b, n_super * (k - 1), lambda r: block_init(r, cfg)),
        "cross_blocks": _stack_init(
            jax.random.fold_in(k_b, 1), n_super,
            lambda r: block_init(r, cfg, cross=True)),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
        "unembed": layers.dense_init(k_o, cfg.d_model, pv),
    }


def vlm_specs(cfg: ModelConfig):
    return {
        "embed": layers.embedding_specs(),
        "self_blocks": _stack_specs(block_specs(cfg)),
        "cross_blocks": _stack_specs(block_specs(cfg, cross=True)),
        "ln_f": layers.rmsnorm_specs(),
        "unembed": layers.dense_specs("embed", "vocab"),
    }


def vlm_forward(cfg: ModelConfig, params, tokens, image_embeds):
    """image_embeds: (B, n_img, d) precomputed patch embeddings (stub)."""
    b, s = tokens.shape
    k = cfg.cross_attn_every
    n_super = cfg.n_layers // k
    x = layers.embed(params["embed"], tokens, layers._dtype(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    img = image_embeds.astype(layers._dtype(cfg.dtype))

    selfp = jax.tree_util.tree_map(
        lambda a: a.reshape((n_super, k - 1) + a.shape[1:]),
        params["self_blocks"])
    policy = _remat_policy(cfg, b * s)

    def super_body(carry, p):
        h, aux = carry
        sp, cp = p

        def inner(c2, p2):
            h2, a2 = c2
            h2, a = block_forward(cfg, p2, h2, positions)
            return (h2, a2 + a), None

        (h, aux), _ = maybe_scan(cfg, inner, (h, aux), sp)
        h, a = block_forward(cfg, cp, h, positions, kv_x=img)
        return (h, aux + a), None

    if cfg.remat:
        super_body = jax.checkpoint(super_body, policy=policy,
                                    prevent_cse=True)
    (x, aux), _ = maybe_scan(cfg, super_body,
                             (x, jnp.zeros((), jnp.float32)),
                             (selfp, params["cross_blocks"]))
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, layers._dtype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab"), aux


def vlm_loss(cfg: ModelConfig, params, batch):
    logits, _ = vlm_forward(cfg, params, batch["tokens"],
                            batch["image_embeds"])
    return softmax_xent(cfg, logits, batch["targets"])


def vlm_decode_init(cfg: ModelConfig, batch: int, max_seq: int):
    k = cfg.cross_attn_every
    n_super = cfg.n_layers // k
    dt = layers._dtype(cfg.dtype)
    cache = {
        "k": jnp.zeros((n_super * (k - 1), batch, max_seq, cfg.n_kv_heads,
                        cfg.head_dim), dt),
        "v": jnp.zeros((n_super * (k - 1), batch, max_seq, cfg.n_kv_heads,
                        cfg.head_dim), dt),
        "ck": jnp.zeros((n_super, batch, max_seq, cfg.n_kv_heads,
                         cfg.head_dim), dt),
        "cv": jnp.zeros((n_super, batch, max_seq, cfg.n_kv_heads,
                         cfg.head_dim), dt),
        "xk": jnp.zeros((n_super, batch, cfg.image_tokens, cfg.n_kv_heads,
                         cfg.head_dim), dt),
        "xv": jnp.zeros((n_super, batch, cfg.image_tokens, cfg.n_kv_heads,
                         cfg.head_dim), dt),
    }
    return cache


def vlm_decode_specs(cfg: ModelConfig):
    base = (None, "batch", "kv_seq", "kv_heads", None)
    return {n: base for n in ("k", "v", "ck", "cv")} | {
        "xk": (None, "batch", None, "kv_heads", None),
        "xv": (None, "batch", None, "kv_heads", None)}


def vlm_decode_step(cfg: ModelConfig, params, cache, tokens, cache_len):
    b = tokens.shape[0]
    k = cfg.cross_attn_every
    n_super = cfg.n_layers // k
    dt = layers._dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens[:, None], dt)
    selfp = jax.tree_util.tree_map(
        lambda a: a.reshape((n_super, k - 1) + a.shape[1:]),
        params["self_blocks"])
    sk = cache["k"].reshape((n_super, k - 1) + cache["k"].shape[1:])
    sv = cache["v"].reshape((n_super, k - 1) + cache["v"].shape[1:])

    def self_body(h, inp):
        p, ck, cv = inp
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        ao, ck, cv = attn.decode_attention(cfg, p["attn"], hn, ck, cv,
                                           cache_len=cache_len)
        h = h + ao
        hn = layers.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + layers.swiglu(p["mlp"], hn, dt)
        return h, (ck, cv)

    def super_body(h, inp):
        sp, cp, skk, svv, cck, ccv, xk, xv = inp
        h, (nk, nv) = maybe_scan(cfg, self_body, h, (sp, skk, svv))
        hn = layers.rmsnorm(cp["ln1"], h, cfg.norm_eps)
        ao, cck, ccv = attn.decode_attention(cfg, cp["attn"], hn, cck, ccv,
                                             cache_len=cache_len)
        h = h + ao
        hn = layers.rmsnorm(cp["ln_x"], h, cfg.norm_eps)
        q = layers.dense(cp["xattn"]["wq"], hn, dt).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        groups = cfg.n_heads // cfg.n_kv_heads
        xo = attn.naive_attention(q, attn._repeat_kv(xk, groups),
                                  attn._repeat_kv(xv, groups), causal=False)
        xo = layers.dense(cp["xattn"]["wo"],
                          xo.reshape(b, 1, cfg.n_heads * cfg.head_dim), dt)
        h = h + jnp.tanh(cp["xgate"]).astype(dt) * xo
        hn = layers.rmsnorm(cp["ln2"], h, cfg.norm_eps)
        h = h + layers.swiglu(cp["mlp"], hn, dt)
        return h, (nk, nv, cck, ccv)

    x, (nk, nv, nck, ncv) = maybe_scan(
        cfg, super_body, x,
        (selfp, params["cross_blocks"], sk, sv, cache["ck"], cache["cv"],
         cache["xk"], cache["xv"]))
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.dense(params["unembed"], x, dt)[:, 0]
    new_cache = dict(cache)
    new_cache["k"] = nk.reshape(cache["k"].shape)
    new_cache["v"] = nv.reshape(cache["v"].shape)
    new_cache["ck"], new_cache["cv"] = nck, ncv
    return logits, new_cache
