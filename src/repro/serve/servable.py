"""Servable model: one shared base tree, many per-user fine-tune sessions.

The paper's personalization examples all share one structure: a backbone
pre-trained in the cloud stays frozen on device, and the per-user state is
the small trainable slice (the transfer head, the adapter) plus its
optimizer moments.  ``ServablePersonalizer`` materialises exactly that
split: ``base_params`` is initialised once and *never written* — every
session's forward pass reads it by reference — while each
:class:`Session` owns a private copy of only the trainable owners'
entries.  Memory per extra tenant is therefore the trainable slice + its
momentum, not the model.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import CompiledMemoryPlan
from repro.core.exec.layers import init_params
from repro.core.exec.store import SwapExecStats
from repro.core.graph import WEIGHTED_KINDS, LayerGraph

Params = Dict[str, Dict[str, jax.Array]]


def trainable_owners(graph: LayerGraph) -> Tuple[str, ...]:
    """Storage-owning layer names whose weights train (E-shared unrolled
    copies collapse onto the first copy, matching the executor's grads)."""
    owners = []
    for l in graph.layers:
        if l.shares_weights_with:
            continue
        if l.kind in WEIGHTED_KINDS and l.trainable and l.weight_shapes():
            owners.append(l.name)
    return tuple(owners)


@dataclasses.dataclass
class Session:
    """One user's live fine-tune state."""
    user: str
    arena_share_bytes: int
    params: Params                          # trainable owners only
    velocity: Optional[Params] = None       # momentum moments, lazy-init
    step: int = 0


class ServablePersonalizer:
    """Wrap a zoo graph for multi-tenant per-user fine-tuning.

    All sessions share ``base_params`` (frozen, read-only by convention —
    jax arrays are immutable so a buggy tenant cannot corrupt it) and the
    compiled plans (owned by the service's :class:`~repro.serve.buckets.
    PlanCache`).  ``train_step`` runs one planned iteration on the merged
    tree and applies momentum SGD to the session's private slice only.
    """

    def __init__(self, graph: LayerGraph, *, lr: float = 0.05,
                 momentum: float = 0.9, seed: int = 0) -> None:
        self.graph = graph
        self.lr = lr
        self.momentum = momentum
        self.base_params: Params = init_params(graph, jax.random.PRNGKey(seed))
        self.trainable_owners: Tuple[str, ...] = trainable_owners(graph)
        self.sessions: Dict[str, Session] = {}

    def open_session(self, user: str, arena_share_bytes: int) -> Session:
        if user in self.sessions:
            raise ValueError(f"session {user!r} already open")
        personal = {o: dict(self.base_params[o])
                    for o in self.trainable_owners}
        sess = Session(user, arena_share_bytes, personal)
        self.sessions[user] = sess
        return sess

    def close_session(self, user: str) -> bool:
        return self.sessions.pop(user, None) is not None

    def merged_params(self, sess: Session) -> Params:
        """Shared frozen tree overlaid with the session's trainable slice."""
        return {**self.base_params, **sess.params}

    def personal_bytes(self, sess: Session) -> int:
        total = 0
        for entry in sess.params.values():
            total += sum(int(w.size) * w.dtype.itemsize
                         for w in entry.values())
        if sess.velocity is not None:
            total *= 2
        return total

    def train_step(self, sess: Session, cp: CompiledMemoryPlan,
                   x: jax.Array, y: jax.Array, *,
                   mask: Optional[jax.Array] = None,
                   engine=None,
                   ) -> Tuple[float, SwapExecStats]:
        """One planned fine-tune step: replay the plan on the merged tree,
        then momentum-SGD the session's private slice.  ``engine``
        optionally injects a transfer engine (e.g. bus-paced) into the
        replay."""
        loss, grads, stats = cp.loss_and_grads(
            self.merged_params(sess), x, y, mask=mask, engine=engine)
        self.apply_update(sess, grads)
        return float(loss), stats

    def apply_update(self, sess: Session, grads: Params) -> None:
        """Momentum-SGD the session's private slice with ``grads``.

        Split from :meth:`train_step` so the phase-interleaved scheduler
        (which drives the replay itself through a
        :class:`~repro.core.exec.ScheduleCursor`) applies the identical
        update when a cursor finishes.
        """
        if sess.velocity is None:
            sess.velocity = {o: {k: jnp.zeros_like(w)
                                 for k, w in entry.items()}
                             for o, entry in sess.params.items()}
        for owner, gentry in grads.items():
            if owner not in sess.params:
                continue
            ventry = sess.velocity[owner]
            pentry = sess.params[owner]
            for k, g in gentry.items():
                v = self.momentum * ventry[k] + g
                ventry[k] = v
                pentry[k] = pentry[k] - self.lr * v
        sess.step += 1
