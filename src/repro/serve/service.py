"""The serving request loop: FIFO queue, admission, graceful rejection.

``PersonalizationService`` is the tenant-facing surface.  One call does
everything: ``submit(user, x, y)`` enqueues a fine-tune request, drains
the FIFO queue synchronously, and returns that request's
:class:`StepResult` — status ``ok`` with the loss and QoS numbers, or
``rejected``/``killed`` with a reason string, never an exception for
traffic-shaped failures (oversize batch, full box, unpackable budget).
Benchmark drivers use ``enqueue``/``drain`` directly to build queue depth.

Warm-up (lazy on first enqueue, or explicit via ``warmup()``) compiles one
plan per bucket and replays it on dummy data, so live traffic never pays
jit-compile latency.  When ``device_budget_bytes`` is omitted the budget
is *derived*: share = the largest bucket's packed peak plus the session's
optimizer tenancy (the packed working region under
``config.optim_offload``, zero extra otherwise), budget = share x
``max_live_sessions`` — i.e. "exactly enough arena for every slot to
train the biggest bucket".  With offloaded moments the share shrinks vs
the all-resident counterfactual, so the same physical arena admits more
sessions (``report()["optim_offload"]["sessions_per_arena_x"]``).  Passing a smaller
budget squeezes tenants: plans re-pack down the swap escalation ladder,
and sessions whose plans cannot fit are rejected, not overcommitted.

The fault-injection hook (:class:`repro.runtime.fault.FaultInjector`) is
consulted once per dequeued request — the service's preemption point.  A
fired kill tears the session down and releases its arena reservation
before the request is looked at, modelling the OS reclaiming an
opportunistic on-device training job.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax

from repro.core import (ArenaBudgetError, MemoryPlanConfig, compile_plan)
from repro.core.graph import LayerGraph
from repro.runtime.fault import FaultInjector
from repro.serve.admission import AdmissionController, ServeStats
from repro.serve.buckets import (PlanCache, choose_bucket, dummy_batch,
                                 pad_to_bucket)
from repro.serve.servable import ServablePersonalizer


@dataclasses.dataclass(eq=False)
class Request:
    user: str
    x: jax.Array
    y: jax.Array
    result: Optional["StepResult"] = None


@dataclasses.dataclass
class StepResult:
    """Outcome of one submitted fine-tune request."""
    user: str
    status: str                      # "ok" | "rejected" | "killed"
    reason: str = ""
    bucket: Optional[int] = None
    loss: float = float("nan")
    step: int = 0
    arena_share_bytes: int = 0
    peak_bytes: int = 0              # measured HBM high water for this step
    wall_time_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class PersonalizationService:
    """Multi-tenant personalization over one shared device arena."""

    def __init__(self, graph: LayerGraph, *,
                 buckets: Sequence[int] = (8, 16),
                 max_live_sessions: int = 4,
                 device_budget_bytes: Optional[int] = None,
                 config: Optional[MemoryPlanConfig] = None,
                 lr: float = 0.05, momentum: float = 0.9,
                 injector: Optional[FaultInjector] = None,
                 seed: int = 0) -> None:
        if not buckets:
            raise ValueError("need at least one batch bucket")
        self.graph = graph
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.config = config or MemoryPlanConfig()
        self.servable = ServablePersonalizer(
            graph, lr=lr, momentum=momentum, seed=seed)
        self.cache = PlanCache()
        self.injector = injector
        self.stats = ServeStats()
        self.admission: Optional[AdmissionController] = None
        self._max_live_sessions = max_live_sessions
        self._device_budget_bytes = device_budget_bytes
        self._queue: Deque[Request] = deque()
        self._warm = False
        # populated by warmup() when the budget is derived and the plans
        # carry an optimizer-offload plan (config.optim_offload)
        self._optim_accounting: Optional[Dict[str, Any]] = None

    # -- warm-up ----------------------------------------------------------

    def warmup(self) -> None:
        """Compile + dummy-replay every bucket; derive the budget if unset.

        Idempotent.  With an explicit ``device_budget_bytes`` this raises
        :class:`~repro.core.ArenaBudgetError` when even one bucket cannot
        pack inside a share — a configuration error, unlike per-request
        budget failures which reject gracefully.
        """
        if self._warm:
            return
        plans = {}
        if self._device_budget_bytes is None:
            probes = {b: compile_plan(self.graph, self.config, batch=b)
                      for b in self.buckets}
            # A session's device footprint is its activation arena peak
            # plus its optimizer tenancy.  Under optim_offload that
            # tenancy is the packed working region (optim_device_bytes),
            # not the all-resident moments — the share shrinks and the
            # same physical arena admits more sessions.
            share = max(cp.peak_bytes + cp.optim_device_bytes
                        for cp in probes.values())
            self._optim_accounting = self._derive_optim_accounting(
                probes, share)
            self.admission = AdmissionController(
                max_live_sessions=self._max_live_sessions,
                device_budget_bytes=share * self._max_live_sessions)
            share = self.admission.arena_share_bytes
            for b, cp in probes.items():
                self.cache.seed(self.graph, b, self.config, share, cp)
            plans = probes
        else:
            self.admission = AdmissionController(
                max_live_sessions=self._max_live_sessions,
                device_budget_bytes=self._device_budget_bytes)
            share = self.admission.arena_share_bytes
            for b in self.buckets:
                plans[b] = self.cache.get_or_compile(
                    self.graph, self.config, bucket=b,
                    arena_budget_bytes=share)
        for b, cp in plans.items():
            x, y = dummy_batch(self.graph, b)
            cp.loss_and_grads(self.servable.base_params, x, y)
        self._warm = True

    def _derive_optim_accounting(self, probes, share: int
                                 ) -> Optional[Dict[str, Any]]:
        """How much arena the optimizer offload bought back per session.

        ``share_resident`` is the counterfactual share with the moments
        fully device-resident; ``sessions_per_arena_x`` is how many more
        sessions the same physical arena (``share_resident x slots``)
        admits at the offloaded share."""
        opts = [cp.optim_plan for cp in probes.values()
                if cp.optim_plan is not None]
        if not opts:
            return None
        resident = max(op.resident_bytes for op in opts)
        share_resident = max(cp.peak_bytes for cp in probes.values()) \
            + resident
        arena = share_resident * self._max_live_sessions
        return {
            "share_bytes": share,
            "share_resident_bytes": share_resident,
            "optim_device_bytes": max(op.device_peak_bytes for op in opts),
            "optim_resident_bytes": resident,
            "sessions_in_resident_arena": arena // max(1, share),
            "sessions_per_arena_x": (arena // max(1, share))
            / self._max_live_sessions,
        }

    # -- the request loop -------------------------------------------------

    def submit(self, user: str, x: jax.Array, y: jax.Array) -> StepResult:
        """Enqueue one fine-tune request and drain the queue; returns this
        request's result (earlier queued requests are processed first)."""
        req = self.enqueue(user, x, y)
        self.drain()
        assert req.result is not None
        return req.result

    def enqueue(self, user: str, x: jax.Array, y: jax.Array) -> Request:
        self.warmup()
        req = Request(user, x, y)
        self._queue.append(req)
        self.stats.submitted += 1
        self.stats.queue_depth_high_water = max(
            self.stats.queue_depth_high_water, len(self._queue))
        return req

    def drain(self) -> List[StepResult]:
        """Process the queue FIFO until empty; every request gets exactly
        one result (progress is guaranteed — nothing is ever requeued)."""
        out: List[StepResult] = []
        while self._queue:
            req = self._queue.popleft()
            req.result = self._process(req)
            out.append(req.result)
        return out

    def end_session(self, user: str) -> bool:
        """Client is done: free the slot and the arena reservation."""
        released = self.admission.release(user) if self.admission else False
        closed = self.servable.close_session(user)
        return released or closed

    # -- internals --------------------------------------------------------

    def _process(self, req: Request) -> StepResult:
        user = req.user
        # Preemption point: the injector models the OS killing an
        # opportunistic training job.  Reservation and state are released
        # *before* the request is looked at — nothing leaks.
        if self.injector is not None \
                and self.injector.check(f"session:{user}"):
            released = self.admission.release(user)
            self.servable.close_session(user)
            self.stats.killed += 1
            return StepResult(
                user=user, status="killed",
                reason="fault injection"
                       + (" (arena reservation released)" if released
                          else " (no reservation held)"))
        n = int(req.x.shape[0])
        bucket = choose_bucket(n, self.buckets)
        if bucket is None:
            self.stats.rejected_bucket += 1
            return StepResult(
                user=user, status="rejected",
                reason=f"batch of {n} exceeds largest bucket "
                       f"{self.buckets[-1]}")
        sess = self.servable.sessions.get(user)
        if sess is None:
            share = self.admission.try_admit(user)
            if share is None:
                if not self.admission.live:
                    # a full box with zero live sessions can't drain itself
                    self.stats.deadlocks += 1
                self.stats.rejected_admission += 1
                return StepResult(
                    user=user, status="rejected",
                    reason=f"no live-session slot "
                           f"({self.admission.max_live_sessions} live)")
            sess = self.servable.open_session(user, share)
        try:
            cp = self.cache.get_or_compile(
                self.graph, self.config, bucket=bucket,
                arena_budget_bytes=sess.arena_share_bytes)
        except ArenaBudgetError as e:
            self.admission.release(user)
            self.servable.close_session(user)
            self.stats.rejected_budget += 1
            return StepResult(
                user=user, status="rejected",
                reason=f"bucket {bucket} plan peak {e.best_peak_bytes} "
                       f"exceeds arena share {e.arena_budget_bytes}")
        xp, yp, mask = pad_to_bucket(req.x, req.y, bucket)
        loss, exec_stats = self.servable.train_step(
            sess, cp, xp, yp, mask=mask)
        ss = self.stats.session(user, sess.arena_share_bytes)
        ss.steps += 1
        ss.last_loss = loss
        ss.peak_bytes = max(ss.peak_bytes, exec_stats.hbm_high_water)
        ss.wall_time_s += exec_stats.wall_time_s
        self.stats.completed += 1
        return StepResult(
            user=user, status="ok", bucket=bucket, loss=loss,
            step=sess.step, arena_share_bytes=sess.arena_share_bytes,
            peak_bytes=exec_stats.hbm_high_water,
            wall_time_s=exec_stats.wall_time_s)

    # -- reporting --------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        rep = {
            "model": self.graph.name,
            "buckets": list(self.buckets),
            "plan_cache": self.cache.report(),
            "serve": self.stats.report(),
        }
        if self.admission is not None:
            rep["admission"] = self.admission.report()
        if self._optim_accounting is not None:
            rep["optim_offload"] = dict(self._optim_accounting)
        return rep
