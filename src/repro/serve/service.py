"""The serving request loop: admission, interleaved execution, rejection.

``PersonalizationService`` is the tenant-facing surface.  One call does
everything: ``submit(user, x, y, qos=...)`` enqueues a fine-tune request,
drains the queue synchronously, and returns that request's
:class:`StepResult` — status ``ok`` with the loss and QoS numbers, or
``rejected``/``killed`` with a reason string, never an exception for
traffic-shaped failures (oversize batch, full class, unpackable budget).
Benchmark drivers use ``enqueue``/``drain`` directly to build queue depth.

Draining is *phase-interleaved* by default (``interleave=True``): each
drain wave takes one pending request per user, admits them, and hands the
admitted sessions to :class:`repro.serve.scheduler.StepScheduler`, which
round-robins their schedule cursors at phase boundaries through one
shared async device stream — session A's DMA hides under session B's
compute (the measured ``cross_hidden_dma_s``).  Same-user requests
serialize across waves, so every step still trains on its predecessor's
params.  ``interleave=False`` restores the synchronous FIFO loop (PR 7),
which doubles as the speedup baseline.

Warm-up (lazy on first enqueue, or explicit via ``warmup()``) compiles one
plan per bucket and replays it on dummy data, so live traffic never pays
jit-compile latency.  When ``device_budget_bytes`` is omitted the budget
is *derived*: the smallest QoS class's share = the largest bucket's packed
peak plus the session's optimizer tenancy, and the budget scales the
other classes' shares weight-proportionally from there — i.e. "exactly
enough arena for every slot to train the biggest bucket".  With offloaded
moments the share shrinks vs the all-resident counterfactual, so the same
physical arena admits more sessions
(``report()["optim_offload"]["sessions_per_arena_x"]``).  Passing a
smaller budget squeezes tenants: plans re-pack down the swap escalation
ladder, and sessions whose plans cannot fit are rejected, not
overcommitted.

The fault-injection hook (:class:`repro.runtime.fault.FaultInjector`) is
consulted once per dequeued request — and, under interleaving, once per
session per scheduler round, so a kill can land *mid-step at a phase
boundary*.  Either way the session is torn down and its arena
reservation released before anything else happens, modelling the OS
reclaiming an opportunistic on-device training job.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax

from repro.core import (ArenaBudgetError, MemoryPlanConfig, compile_plan)
from repro.core.graph import LayerGraph
from repro.runtime.fault import FaultInjector
from repro.serve.admission import (AdmissionController, QosClass, ServeStats)
from repro.serve.buckets import (PlanCache, choose_bucket, dummy_batch,
                                 pad_to_bucket)
from repro.serve.scheduler import SessionWork, StepScheduler
from repro.serve.servable import ServablePersonalizer


@dataclasses.dataclass(eq=False)
class Request:
    user: str
    x: jax.Array
    y: jax.Array
    qos: Optional[str] = None
    arrival: int = 0                 # global submission sequence number
    enqueued_at: float = 0.0
    result: Optional["StepResult"] = None


@dataclasses.dataclass
class StepResult:
    """Outcome of one submitted fine-tune request."""
    user: str
    status: str                      # "ok" | "rejected" | "killed"
    reason: str = ""
    bucket: Optional[int] = None
    loss: float = float("nan")
    step: int = 0
    arena_share_bytes: int = 0
    peak_bytes: int = 0              # measured HBM high water for this step
    wall_time_s: float = 0.0
    qos: str = "standard"
    queue_wait_s: float = 0.0        # enqueue -> processing start

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class PersonalizationService:
    """Multi-tenant personalization over one shared device arena."""

    def __init__(self, graph: LayerGraph, *,
                 buckets: Sequence[int] = (8, 16),
                 max_live_sessions: int = 4,
                 device_budget_bytes: Optional[int] = None,
                 config: Optional[MemoryPlanConfig] = None,
                 qos: Optional[Sequence[QosClass]] = None,
                 interleave: bool = True,
                 bus_gbps: Optional[float] = None,
                 bus_latency_s: float = 0.0,
                 lr: float = 0.05, momentum: float = 0.9,
                 injector: Optional[FaultInjector] = None,
                 seed: int = 0) -> None:
        if not buckets:
            raise ValueError("need at least one batch bucket")
        self.graph = graph
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.config = config or MemoryPlanConfig()
        self.servable = ServablePersonalizer(
            graph, lr=lr, momentum=momentum, seed=seed)
        self.cache = PlanCache()
        self.injector = injector
        self.stats = ServeStats()
        self.admission: Optional[AdmissionController] = None
        self.interleave = bool(interleave)
        self.bus_gbps = bus_gbps       # emulated bus pacing (None = off)
        self.bus_latency_s = float(bus_latency_s)
        self._qos = tuple(qos) if qos is not None else None
        self._max_live_sessions = max_live_sessions
        self._device_budget_bytes = device_budget_bytes
        self._queue: Deque[Request] = deque()
        self._arrivals = 0
        self._warm = False
        self._scheduler: Optional[StepScheduler] = None
        # populated by warmup() when the budget is derived and the plans
        # carry an optimizer-offload plan (config.optim_offload)
        self._optim_accounting: Optional[Dict[str, Any]] = None

    # -- warm-up ----------------------------------------------------------

    def warmup(self) -> None:
        """Compile + dummy-replay every bucket; derive the budget if unset.

        Idempotent.  With an explicit ``device_budget_bytes`` this raises
        :class:`~repro.core.ArenaBudgetError` when even one bucket cannot
        pack inside the smallest class's share — a configuration error,
        unlike per-request budget failures which reject gracefully.
        """
        if self._warm:
            return
        plans = {}
        if self._device_budget_bytes is None:
            probes = {b: compile_plan(self.graph, self.config, batch=b)
                      for b in self.buckets}
            # A session's device footprint is its activation arena peak
            # plus its optimizer tenancy.  Under optim_offload that
            # tenancy is the packed working region (optim_device_bytes),
            # not the all-resident moments — the share shrinks and the
            # same physical arena admits more sessions.
            needed = max(cp.peak_bytes + cp.optim_device_bytes
                         for cp in probes.values())
            self._optim_accounting = self._derive_optim_accounting(
                probes, needed)
            self.admission = self._make_admission(
                self._derive_budget(needed))
            for b, cp in probes.items():
                self.cache.seed(self.graph, b, self.config,
                                self.admission.arena_share_bytes, cp)
            plans = probes
        else:
            self.admission = self._make_admission(self._device_budget_bytes)
            smallest = min(self.admission.share_for(c.name)
                           for c in self.admission.qos)
            for b in self.buckets:
                plans[b] = self.cache.get_or_compile(
                    self.graph, self.config, bucket=b,
                    arena_budget_bytes=smallest)
        for b, cp in plans.items():
            x, y = dummy_batch(self.graph, b)
            cp.loss_and_grads(self.servable.base_params, x, y)
        self._warm = True

    def _make_admission(self, budget: int) -> AdmissionController:
        return AdmissionController(
            max_live_sessions=self._max_live_sessions,
            device_budget_bytes=budget, qos=self._qos)

    def _derive_budget(self, needed: int) -> int:
        """The smallest budget whose *minimum* class share fits ``needed``
        bytes (single default class: exactly ``needed x max_live``, the
        historical derived budget)."""
        classes = self._qos or (QosClass("standard", 1.0,
                                         slots=self._max_live_sessions),)
        weight_units = sum(c.weight * c.slots for c in classes)
        min_weight = min(c.weight for c in classes)
        budget = int(math.ceil(needed * weight_units / min_weight))
        # integer floors can shave a byte off a share: nudge until the
        # smallest class share actually fits the probe peak
        while int(budget * min_weight / weight_units) < needed:
            budget += self._max_live_sessions
        return budget

    def _derive_optim_accounting(self, probes, share: int
                                 ) -> Optional[Dict[str, Any]]:
        """How much arena the optimizer offload bought back per session.

        ``share_resident`` is the counterfactual share with the moments
        fully device-resident; ``sessions_per_arena_x`` is how many more
        sessions the same physical arena (``share_resident x slots``)
        admits at the offloaded share."""
        opts = [cp.optim_plan for cp in probes.values()
                if cp.optim_plan is not None]
        if not opts:
            return None
        resident = max(op.resident_bytes for op in opts)
        share_resident = max(cp.peak_bytes for cp in probes.values()) \
            + resident
        arena = share_resident * self._max_live_sessions
        return {
            "share_bytes": share,
            "share_resident_bytes": share_resident,
            "optim_device_bytes": max(op.device_peak_bytes for op in opts),
            "optim_resident_bytes": resident,
            "sessions_in_resident_arena": arena // max(1, share),
            "sessions_per_arena_x": (arena // max(1, share))
            / self._max_live_sessions,
        }

    # -- the request loop -------------------------------------------------

    def submit(self, user: str, x: jax.Array, y: jax.Array, *,
               qos: Optional[str] = None) -> StepResult:
        """Enqueue one fine-tune request and drain the queue; returns this
        request's result (earlier queued requests are processed first)."""
        req = self.enqueue(user, x, y, qos=qos)
        self.drain()
        assert req.result is not None
        return req.result

    def enqueue(self, user: str, x: jax.Array, y: jax.Array, *,
                qos: Optional[str] = None) -> Request:
        self.warmup()
        if qos is not None:
            self.admission.qos_class(qos)     # unknown class: raise early
        self._arrivals += 1
        req = Request(user, x, y, qos=qos, arrival=self._arrivals,
                      enqueued_at=time.perf_counter())
        self._queue.append(req)
        self.stats.submitted += 1
        self.stats.queue_depth_high_water = max(
            self.stats.queue_depth_high_water, len(self._queue))
        return req

    def drain(self) -> List[StepResult]:
        """Process the queue until empty; every request gets exactly one
        result (progress is guaranteed — nothing is ever requeued).

        Interleaved mode drains as one continuous stream: each user's
        first pending request opens a schedule cursor, and the moment a
        user's step completes the scheduler's ``follow_up`` refill opens
        that user's next request (after the update is applied) — so
        concurrency never dwindles through an end-of-queue convoy.
        Results come back in arrival order either way.
        """
        if not self.interleave:
            out: List[StepResult] = []
            while self._queue:
                req = self._queue.popleft()
                req.result = self._process(req)
                out.append(req.result)
            return out
        pending: Dict[str, Deque[Request]] = {}
        while self._queue:
            req = self._queue.popleft()
            pending.setdefault(req.user, deque()).append(req)
        done = self._run_stream(pending)
        done.sort(key=lambda p: p[0])
        return [r for _, r in done]

    def end_session(self, user: str) -> bool:
        """Client is done: free the slot and the arena reservation."""
        released = self.admission.release(user) if self.admission else False
        closed = self.servable.close_session(user)
        return released or closed

    # -- internals --------------------------------------------------------

    def _prepare(self, req: Request) -> Union[StepResult, Tuple]:
        """Everything up to execution: kill point, bucket, admission,
        plan compile.  Returns the terminal :class:`StepResult` for
        traffic-shaped failures, else ``(sess, cp, bucket, xp, yp,
        mask, qos, queue_wait_s)``."""
        user = req.user
        queue_wait_s = time.perf_counter() - req.enqueued_at
        # Preemption point: the injector models the OS killing an
        # opportunistic training job.  Reservation and state are released
        # *before* the request is looked at — nothing leaks.
        if self.injector is not None \
                and self.injector.check(f"session:{user}"):
            released = self.admission.release(user)
            self.servable.close_session(user)
            self.stats.killed += 1
            self.stats.note_queue_wait(
                req.qos or self.admission.default_qos, queue_wait_s)
            return StepResult(
                user=user, status="killed",
                reason="fault injection"
                       + (" (arena reservation released)" if released
                          else " (no reservation held)"),
                qos=req.qos or self.admission.default_qos,
                queue_wait_s=queue_wait_s)
        n = int(req.x.shape[0])
        bucket = choose_bucket(n, self.buckets)
        if bucket is None:
            self.stats.rejected_bucket += 1
            self.stats.note_queue_wait(
                req.qos or self.admission.default_qos, queue_wait_s)
            return StepResult(
                user=user, status="rejected",
                reason=f"batch of {n} exceeds largest bucket "
                       f"{self.buckets[-1]}",
                qos=req.qos or self.admission.default_qos,
                queue_wait_s=queue_wait_s)
        sess = self.servable.sessions.get(user)
        if sess is None:
            share = self.admission.try_admit(user, qos=req.qos)
            if share is None:
                if not self.admission.live:
                    # a full box with zero live sessions can't drain itself
                    self.stats.deadlocks += 1
                self.stats.rejected_admission += 1
                qos = req.qos or self.admission.default_qos
                self.stats.note_queue_wait(qos, queue_wait_s)
                return StepResult(
                    user=user, status="rejected",
                    reason=f"no live-session slot in class {qos!r} "
                           f"({self.admission.max_live_sessions} live)",
                    qos=qos, queue_wait_s=queue_wait_s)
            sess = self.servable.open_session(user, share)
        qos = self.admission.qos_of(user)
        try:
            cp = self.cache.get_or_compile(
                self.graph, self.config, bucket=bucket,
                arena_budget_bytes=sess.arena_share_bytes)
        except ArenaBudgetError as e:
            self.admission.release(user)
            self.servable.close_session(user)
            self.stats.rejected_budget += 1
            self.stats.note_queue_wait(qos, queue_wait_s)
            return StepResult(
                user=user, status="rejected",
                reason=f"bucket {bucket} plan peak {e.best_peak_bytes} "
                       f"exceeds arena share {e.arena_budget_bytes}",
                qos=qos, queue_wait_s=queue_wait_s)
        xp, yp, mask = pad_to_bucket(req.x, req.y, bucket)
        # queue wait for the successful path is noted at execution start:
        # _process notes it here, the interleaved wave notes it when the
        # cursor opens (the scheduler measures it from enqueued_at)
        return sess, cp, bucket, xp, yp, mask, qos, queue_wait_s

    def _process(self, req: Request) -> StepResult:
        """The synchronous FIFO path (PR 7 semantics, the baseline).

        Under emulated-bus pacing (``bus_gbps``) this path pays every
        transfer's bus time synchronously — a blocking engine exposes the
        full cost the interleaved scheduler exists to hide."""
        prepared = self._prepare(req)
        if isinstance(prepared, StepResult):
            return prepared
        sess, cp, bucket, xp, yp, mask, qos, queue_wait_s = prepared
        self.stats.note_queue_wait(qos, queue_wait_s)
        engine = None
        if self.bus_gbps is not None:
            from repro.core.exec import SyncHostEngine
            engine = SyncHostEngine(bus_gbps=self.bus_gbps,
                                    bus_latency_s=self.bus_latency_s)
        loss, exec_stats = self.servable.train_step(
            sess, cp, xp, yp, mask=mask, engine=engine)
        return self._complete(req.user, sess, bucket, loss, exec_stats,
                              qos, queue_wait_s)

    def _complete(self, user: str, sess, bucket: Optional[int],
                  loss: float, exec_stats, qos: str,
                  queue_wait_s: float) -> StepResult:
        ss = self.stats.session(user, sess.arena_share_bytes, qos)
        ss.steps += 1
        ss.last_loss = loss
        ss.peak_bytes = max(ss.peak_bytes, exec_stats.hbm_high_water)
        ss.wall_time_s += exec_stats.wall_time_s
        self.stats.completed += 1
        self.stats.qos_stats(qos).completed += 1
        return StepResult(
            user=user, status="ok", bucket=bucket, loss=loss,
            step=sess.step, arena_share_bytes=sess.arena_share_bytes,
            peak_bytes=exec_stats.hbm_high_water,
            wall_time_s=exec_stats.wall_time_s, qos=qos,
            queue_wait_s=queue_wait_s)

    # -- interleaved draining ---------------------------------------------

    def _get_scheduler(self) -> StepScheduler:
        if self._scheduler is None:
            from repro.core.exec import DeviceStreamEngine
            engine = (DeviceStreamEngine(bus_gbps=self.bus_gbps,
                                         bus_latency_s=self.bus_latency_s)
                      if self.bus_gbps is not None else None)
            self._scheduler = StepScheduler(engine=engine,
                                            injector=self.injector)
        return self._scheduler

    def _run_stream(self, pending: Dict[str, Deque[Request]]
                    ) -> List[Tuple[int, StepResult]]:
        """Interleave every queued request as one continuous stream.

        Each user's first preparable request opens a cursor; whenever a
        session finishes, the outcome is folded (update applied, result
        recorded) and the scheduler's ``follow_up`` refill immediately
        opens that user's next queued request — same-user requests still
        serialize (each step trains on the previous step's params), but
        different users' later requests never wait for a wave barrier."""
        done: List[Tuple[int, StepResult]] = []
        ctx: Dict[int, Tuple] = {}       # arrival -> (req, sess, bucket)

        def next_work(user: str) -> Optional[SessionWork]:
            q = pending.get(user)
            while q:
                req = q.popleft()
                prepared = self._prepare(req)
                if isinstance(prepared, StepResult):
                    req.result = prepared
                    done.append((req.arrival, prepared))
                    continue           # terminal result; try the next one
                sess, cp, bucket, xp, yp, mask, qos, _ = prepared
                ctx[req.arrival] = (req, sess, bucket)
                return SessionWork(
                    user=req.user, arrival=req.arrival, qos=qos,
                    weight=self.admission.qos_class(qos).weight,
                    base_offset=self.admission.base_offset(req.user),
                    share_bytes=sess.arena_share_bytes, cp=cp, x=xp, y=yp,
                    mask=mask,
                    params_fn=(lambda s=sess:
                               self.servable.merged_params(s)),
                    enqueued_at=req.enqueued_at)
            return None

        def fold(oc) -> None:
            req, sess, bucket = ctx[oc.arrival]
            if oc.status == "killed":
                released = self.admission.release(oc.user)
                self.servable.close_session(oc.user)
                self.stats.killed += 1
                req.result = StepResult(
                    user=oc.user, status="killed",
                    reason=oc.reason
                           + (" (arena reservation released)" if released
                              else " (no reservation held)"),
                    qos=oc.qos, queue_wait_s=oc.queue_wait_s)
            else:
                self.servable.apply_update(sess, oc.grads)
                req.result = self._complete(
                    oc.user, sess, bucket, oc.loss, oc.stats, oc.qos,
                    oc.queue_wait_s)
            done.append((req.arrival, req.result))

        def follow_up(oc) -> Optional[SessionWork]:
            fold(oc)
            return next_work(oc.user)

        works = [w for w in (next_work(u) for u in list(pending))
                 if w is not None]
        if works:
            self._get_scheduler().run(works, self.stats,
                                      follow_up=follow_up)
        return done

    # -- reporting --------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        rep = {
            "model": self.graph.name,
            "buckets": list(self.buckets),
            "interleave": self.interleave,
            "plan_cache": self.cache.report(),
            "serve": self.stats.report(),
        }
        if self.admission is not None:
            rep["admission"] = self.admission.report()
        if self._scheduler is not None and self._scheduler.last_report:
            rep["scheduler"] = self._scheduler.report()
        if self._optim_accounting is not None:
            rep["optim_offload"] = dict(self._optim_accounting)
        return rep
