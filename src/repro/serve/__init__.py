"""Multi-tenant personalization serving (paper §"Personalization examples").

On-device personalization is a *serving* problem as much as a training
problem: one box hosts a shared pre-trained backbone and many users'
lightweight fine-tune state, training opportunistically as user data
arrives.  This package turns :func:`repro.core.compile_plan` into that
serving stack:

* :mod:`repro.serve.buckets`   — sorted batch-size buckets, pad-to-bucket
  batching (exact numerics via sample masks), and the
  ``(model, bucket, config, budget) -> CompiledMemoryPlan`` compile cache.
* :mod:`repro.serve.admission` — admission control: ``max_live_sessions``
  tenants split one device-arena byte budget; the memory planner is the
  QoS lever (each session's plans must pack inside its share).
* :mod:`repro.serve.servable`  — ``ServablePersonalizer``: one frozen base
  parameter tree shared by every session + per-user trainable deltas and
  optimizer state.
* :mod:`repro.serve.service`   — ``PersonalizationService``: the request
  loop (``submit(user, x, y, qos=...) -> StepResult``) with graceful
  rejection and fault-injection kill points.
* :mod:`repro.serve.scheduler` — ``StepScheduler``: phase-interleaved
  multi-session execution — N sessions' schedule cursors round-robin over
  one shared device stream, so one tenant's DMA hides under another's
  compute (``drain`` default; ``interleave=False`` restores FIFO).

Quick start::

    from repro.core.zoo import ZOO
    from repro.serve import PersonalizationService

    svc = PersonalizationService(ZOO["lenet5"](), buckets=(8, 16),
                                 max_live_sessions=4)
    res = svc.submit("alice", x, y)       # x: (n<=16, 3, 32, 32)
    print(res.status, res.loss, svc.report())
"""

from repro.serve.admission import (AdmissionController, QosClass,
                                   QosClassStats, ServeStats, SessionStats)
from repro.serve.buckets import (PlanCache, choose_bucket, dummy_batch,
                                 pad_to_bucket)
from repro.serve.scheduler import SessionWork, StepOutcome, StepScheduler
from repro.serve.servable import ServablePersonalizer, Session
from repro.serve.service import PersonalizationService, StepResult

__all__ = [
    "PersonalizationService", "StepResult",
    "ServablePersonalizer", "Session",
    "AdmissionController", "QosClass", "QosClassStats",
    "ServeStats", "SessionStats",
    "StepScheduler", "SessionWork", "StepOutcome",
    "PlanCache", "choose_bucket", "pad_to_bucket", "dummy_batch",
]
