"""Phase-interleaved multi-session execution.

The FIFO service (PR 7) trains one session at a time, so the transfer
engine idles whenever the single live session computes and vice versa.
:class:`StepScheduler` fixes that at the schedule level: it holds N
admitted sessions' in-flight :class:`~repro.core.exec.ScheduleCursor`\\ s
and round-robins them *at phase boundaries* through one shared
:class:`~repro.core.exec.AsyncDeviceBackend` /
:class:`~repro.core.exec.DeviceStreamEngine`.  A phase boundary is the
natural preemption point the lowered ``ExecutionSchedule`` already
defines: all of a phase's DMA has been issued but need not be fenced
until a later phase computes — so while session A's ``SwapOut`` /
``Prefetch`` / ``OptPrefetch`` copies are on the bus, the scheduler
advances session B's ``Compute`` phases, and A's DMA hides under B's
compute.  That cross-session overlap is measured, not asserted: every
second one session spends computing while another session's transfers
are in flight is credited to the waiting session's
``SwapExecStats.cross_hidden_dma_s``.

Safety before speed, in the house style (prove-then-run):

* admission: cursors only come from ``backend.start(...)``, which runs
  the verified-schedule admission gate, and the scheduler re-checks
  :func:`~repro.core.verify.is_verified` per cursor;
* aliasing: before any cursor advances,
  :func:`~repro.core.verify.verify_interleaving` proves the admitted
  sessions' arena shares pairwise disjoint and every plan peak inside
  its share (the ``cross_session_arena`` check, mutation class 12);
* equivalence: each completed session's replayed stream must equal the
  compiled op list — positionally, or failing that by
  :func:`~repro.core.verify.schedules_equivalent` — before its result
  is released.

QoS weighting: each round a session receives one phase advance per whole
unit of its class weight, so a premium (weight-2) tenant progresses two
phases per round while standard tenants take one.  Every extra advance
increments the *waiting* sessions' classes' ``bypassed_phases`` counter,
making the policy's starvation observable (``ServeStats.by_qos``).  Ties
are broken deterministically by global arrival sequence number.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

from repro.core.exec import (AsyncDeviceBackend, DeviceStreamEngine,
                             SessionScopedEngine)
from repro.core.verify import (is_verified, schedules_equivalent,
                               verify_interleaving)
from repro.runtime.fault import FaultInjector
from repro.serve.admission import ServeStats


@dataclasses.dataclass(eq=False)
class SessionWork:
    """One admitted request, ready to interleave (at most one per user
    per :meth:`StepScheduler.run` wave — same-user requests serialize
    across waves so each step trains on the previous step's params)."""

    user: str
    arrival: int                 # global submission sequence — the tie-break
    qos: str
    weight: float
    base_offset: int             # the session's share in the physical arena
    share_bytes: int
    cp: Any                      # CompiledMemoryPlan for the user's bucket
    x: Any
    y: Any
    mask: Any
    # evaluated when the cursor opens, so a chained request sees the
    # params produced by the user's previous completed step
    params_fn: Callable[[], Any]
    enqueued_at: Optional[float] = None


@dataclasses.dataclass
class StepOutcome:
    """What one interleaved step produced (the service folds this into a
    :class:`~repro.serve.service.StepResult` and applies the update)."""

    user: str
    arrival: int
    qos: str
    status: str                  # "ok" | "killed"
    reason: str = ""
    loss: float = float("nan")
    grads: Optional[Dict[str, Any]] = None
    stats: Any = None            # SwapExecStats, None when killed
    queue_wait_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class _Live:
    """One session's in-flight cursor inside a wave."""

    def __init__(self, work: SessionWork, cursor,
                 start_after: int = 0) -> None:
        self.work = work
        self.cursor = cursor
        self.alive = True
        self.queue_wait_s = 0.0
        # software-pipeline prologue: this session holds at phase 0 until
        # the wave's global advance counter reaches start_after, so the
        # initial sessions de-phase instead of marching in lock-step
        # (lock-step means every session hits the plan's transfer-heavy
        # regions at once — the bus bursts then idles)
        self.start_after = start_after


class StepScheduler:
    """Round-robin N sessions' schedule cursors over one device stream."""

    def __init__(self, *, backend: Optional[AsyncDeviceBackend] = None,
                 engine: Optional[DeviceStreamEngine] = None,
                 injector: Optional[FaultInjector] = None) -> None:
        self.backend = backend if backend is not None else AsyncDeviceBackend()
        self.engine = engine if engine is not None else DeviceStreamEngine()
        self.injector = injector
        self.last_report: Dict[str, Any] = {}

    # ------------------------------------------------------------- admission
    def _check_interleaving(self, works: Sequence[SessionWork]) -> None:
        """Prove the wave's arena shares disjoint and every plan in-share
        before a single phase executes (cross_session_arena)."""
        from repro.core.verify import SessionArenaSlice
        slices = [SessionArenaSlice(
            session=w.user, qos=w.qos, base_offset=w.base_offset,
            share_bytes=w.share_bytes,
            peak_bytes=w.cp.peak_bytes + w.cp.optim_device_bytes)
            for w in works]
        verify_interleaving(slices).raise_if_errors()

    def _open(self, work: SessionWork) -> _Live:
        """Admit one work item: verified backend.start over a
        session-scoped view of the shared engine."""
        cp = work.cp
        scoped = SessionScopedEngine(self.engine,
                                     f"{work.user}#{work.arrival}")
        cursor = self.backend.start(
            cp.graph, work.params_fn(), work.x, work.y,
            schedule=cp.schedule, ordered=cp.ordered, plan=cp.plan,
            lowered=cp.lowered, mask=work.mask, engine=scoped,
            tag=work.user)
        # defense in depth: start() verifies unverified plan-backed
        # schedules on admission; a cursor for an unverified schedule
        # must be impossible here
        assert is_verified(cp.lowered), \
            f"unverified schedule admitted for {work.user!r}"
        return _Live(work, cursor)

    @staticmethod
    def _stagger_stride(works: Sequence[SessionWork]) -> int:
        """Global-advance stride between consecutive sessions' starts:
        one plan's phase-group count spread over the wave (a phase group
        is a run of lowered ops sharing one EO — what one
        ``ScheduleCursor.advance`` executes)."""
        if len(works) < 2:
            return 0

        def groups(cp) -> int:
            n, cur = 0, None
            for op in cp.lowered.ops:
                if cur is None or op.eo != cur:
                    n, cur = n + 1, op.eo
            return n

        phases = min(groups(w.cp) for w in works)
        return max(1, phases // len(works)) if phases else 0

    def _prove_replay(self, live: _Live, proved: Set[int]) -> None:
        """The completed session's replayed stream must be the compiled op
        list (or a proven-equivalent stream).  Proofs are memoized per
        lowered schedule per wave — every session of one bucket replays
        the same plan, so one proof covers the fleet."""
        cp = live.work.cp
        stats = live.cursor.stats
        if stats.replayed_ops == cp.lowered.ops:
            return                     # positionally identical — trivially ok
        key = id(cp.lowered)
        if key in proved:
            return
        schedules_equivalent(cp.lowered, stats.replayed_ops,
                             ordered=cp.ordered,
                             plan=cp.plan).raise_if_errors()
        proved.add(key)

    # ------------------------------------------------------------------ run
    def run(self, works: Sequence[SessionWork],
            stats: Optional[ServeStats] = None,
            follow_up: Optional[Callable[[StepOutcome],
                                         Optional[SessionWork]]] = None,
            ) -> List[StepOutcome]:
        """Interleave one wave of sessions to completion.

        Weighted round-robin at phase boundaries in arrival order; fault
        injection is consulted per session per round (the phase boundary
        is the kill point); returns one :class:`StepOutcome` per work
        item, in arrival order.

        ``follow_up`` makes the wave a continuous stream: it is called
        with each session's outcome the moment that session finishes and
        may return the *next* :class:`SessionWork` to open (typically the
        same user's next queued request, after the caller applied the
        update) — so the bus never idles through an end-of-wave convoy
        while stragglers drain.  The refilled work joins the round-robin
        immediately and is re-proven against the still-active sessions'
        arena shares before its first phase executes.
        """
        works = sorted(works, key=lambda w: w.arrival)
        users = [w.user for w in works]
        if len(set(users)) != len(users):
            raise ValueError(
                f"one work item per user per wave, got {users}")
        self._check_interleaving(works)
        all_works: List[SessionWork] = list(works)
        outcomes: Dict[int, StepOutcome] = {}
        proved: Set[int] = set()
        active: List[_Live] = []
        t_wave0 = time.perf_counter()

        def open_live(w: SessionWork, start_after: int = 0) -> None:
            live = self._open(w)
            live.start_after = start_after
            active.append(live)
            if w.enqueued_at is not None and stats is not None:
                wait = time.perf_counter() - w.enqueued_at
                stats.note_queue_wait(w.qos, wait)
                live.queue_wait_s = wait

        # prologue: stagger session i by i * (phases/N) global advances so
        # the wave starts de-phased — session 0's transfer-heavy regions
        # land under sessions 1..N-1's compute and vice versa.  Refilled
        # follow-up work needs no stagger: it opens at a completion, which
        # is already de-phased.
        stride = self._stagger_stride(works)
        for i, w in enumerate(works):
            open_live(w, start_after=i * stride)
        rounds = 0
        phase_advances = 0

        def refill(outcome: StepOutcome) -> None:
            if follow_up is None:
                return
            nxt = follow_up(outcome)
            if nxt is None:
                return
            survivors = [s.work for s in active if s.alive]
            if any(s.user == nxt.user for s in survivors):
                raise ValueError(
                    f"follow-up work for {nxt.user!r} while that user is "
                    f"still active")
            self._check_interleaving(survivors + [nxt])
            all_works.append(nxt)
            open_live(nxt)

        def finish(live: _Live, status: str, reason: str = "") -> None:
            w = live.work
            if status == "ok":
                loss, grads, st = live.cursor.result()
                self._prove_replay(live, proved)
                outcomes[w.arrival] = StepOutcome(
                    user=w.user, arrival=w.arrival, qos=w.qos, status="ok",
                    loss=float(loss), grads=grads, stats=st,
                    queue_wait_s=getattr(live, "queue_wait_s", 0.0))
            else:
                outcomes[w.arrival] = StepOutcome(
                    user=w.user, arrival=w.arrival, qos=w.qos,
                    status="killed", reason=reason,
                    queue_wait_s=getattr(live, "queue_wait_s", 0.0))
            live.alive = False
            refill(outcomes[w.arrival])

        while active:
            rounds += 1
            advanced_any = False
            # stall-aware round order: a session whose in-flight transfers
            # are all complete cannot stall on a fence, so it runs first;
            # a session still waiting on the bus runs last — by its turn
            # the clock has moved under the others' compute.  sort() is
            # stable and every key is 0.0 without pacing, so the order
            # degrades to the deterministic arrival order.
            now = time.perf_counter()
            order = sorted(
                active,
                key=lambda s: max(0.0, getattr(s.cursor.engine,
                                               "next_ready_at", 0.0) - now))
            for live in order:
                w = live.work
                if advanced_any and (
                        phase_advances < live.start_after
                        or getattr(live.cursor.engine, "next_ready_at", 0.0)
                        > time.perf_counter()):
                    # hold: still in the prologue, or this session's bus
                    # transfers aren't complete yet — let ready sessions'
                    # compute run the clock past its completion instead
                    # of sleeping in its fence.  The round's first (least
                    # at-risk) session always advances, so the wave can
                    # never stall collectively.
                    continue
                # the phase boundary is the preemption point: a kill here
                # models the OS reclaiming the job mid-step
                if self.injector is not None \
                        and self.injector.check(f"session:{w.user}"):
                    live.cursor.abort()
                    finish(live, "killed",
                           "fault injection at phase boundary "
                           f"{live.cursor.phases_done}/"
                           f"{live.cursor.phases_total}")
                    continue
                credits = max(1, int(live.work.weight))
                for i in range(credits):
                    more = live.cursor.advance()
                    phase_advances += 1
                    advanced_any = True
                    dt = live.cursor.last_advance_s
                    # cross-session overlap, measured: while this session
                    # computed for dt, every *other* session with DMA in
                    # flight had that DMA hidden under foreign compute
                    for other in active:
                        if other is not live and other.alive \
                                and other.cursor.has_inflight_dma:
                            other.cursor.stats.cross_hidden_dma_s += dt
                    # fairness, observable: an extra (weight-funded)
                    # advance bypasses every other runnable session
                    if i > 0 and stats is not None:
                        for other in active:
                            if other is not live and other.alive:
                                stats.qos_stats(
                                    other.work.qos).bypassed_phases += 1
                    if not more:
                        finish(live, "ok")
                        break
            active = [s for s in active if s.alive]

        done = [outcomes[w.arrival]
                for w in sorted(all_works, key=lambda w: w.arrival)]
        ok = [o for o in done if o.ok]
        agg = {
            "sessions": len(all_works),
            "completed": len(ok),
            "killed": len(done) - len(ok),
            "rounds": rounds,
            "phase_advances": phase_advances,
            "wall_time_s": time.perf_counter() - t_wave0,
            "equivalence_proofs": len(proved),
            "verify_errors": 0,        # raise-on-error above, so 0 here
            "cross_hidden_dma_s": sum(o.stats.cross_hidden_dma_s
                                      for o in ok),
            "hidden_dma_s": sum(o.stats.hidden_dma_s for o in ok),
            "exposed_dma_s": sum(o.stats.exposed_dma_s for o in ok),
            "opt_hidden_dma_s": sum(o.stats.opt_hidden_dma_s for o in ok),
            "opt_exposed_dma_s": sum(o.stats.opt_exposed_dma_s
                                     for o in ok),
        }
        self.last_report = agg
        return done

    def report(self) -> Dict[str, Any]:
        return dict(self.last_report)
