"""Admission control and serving statistics.

The device arena is the scarce resource: every live session's training
steps replay a memory plan whose packed peak must stay inside that
session's *share* of the arena.  Admission is therefore a byte-budget
problem, and the memory planner is the QoS lever — a tenant is admitted
iff (a) a live-session slot is free and (b)
:func:`repro.core.compile_plan_under_budget` can pack its bucket plans
inside ``device_budget_bytes // max_live_sessions``.  Sessions that die
(or are killed by fault injection) release their reservation immediately,
so the arena can never leak.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple


class AdmissionController:
    """Fixed-share admission: N slots over one device-arena byte budget.

    Equal shares keep the policy deterministic and the compile cache hot
    (every tenant compiles against the same budget, so plans are shared
    across the whole fleet); weighted shares would work identically but
    fragment the cache per weight class.
    """

    def __init__(self, *, max_live_sessions: int,
                 device_budget_bytes: int) -> None:
        if max_live_sessions <= 0:
            raise ValueError("max_live_sessions must be positive")
        if device_budget_bytes <= 0:
            raise ValueError("device_budget_bytes must be positive")
        self.max_live_sessions = max_live_sessions
        self.device_budget_bytes = device_budget_bytes
        self._live: Dict[str, int] = {}     # user -> reserved bytes
        self.rejections = 0

    @property
    def arena_share_bytes(self) -> int:
        return self.device_budget_bytes // self.max_live_sessions

    @property
    def live(self) -> Tuple[str, ...]:
        return tuple(sorted(self._live))

    @property
    def reserved_bytes(self) -> int:
        return sum(self._live.values())

    def try_admit(self, user: str) -> Optional[int]:
        """Reserve a slot + share for ``user``; None when the box is full.

        Idempotent for already-live users (their existing share is
        returned, nothing double-reserved).
        """
        existing = self._live.get(user)
        if existing is not None:
            return existing
        if len(self._live) >= self.max_live_sessions:
            self.rejections += 1
            return None
        share = self.arena_share_bytes
        self._live[user] = share
        return share

    def release(self, user: str) -> bool:
        """Return ``user``'s reservation to the pool; False if not live."""
        return self._live.pop(user, None) is not None

    def report(self) -> Dict[str, Any]:
        return {
            "max_live_sessions": self.max_live_sessions,
            "device_budget_bytes": self.device_budget_bytes,
            "arena_share_bytes": self.arena_share_bytes,
            "live_sessions": len(self._live),
            "reserved_bytes": self.reserved_bytes,
            "rejections": self.rejections,
        }


@dataclasses.dataclass
class SessionStats:
    """Per-tenant QoS counters, updated on every completed step."""
    user: str
    arena_share_bytes: int
    steps: int = 0
    last_loss: float = float("nan")
    peak_bytes: int = 0          # max measured HBM high water across steps
    wall_time_s: float = 0.0     # sum of executor step wall times

    def steps_per_sec(self) -> float:
        return self.steps / self.wall_time_s if self.wall_time_s > 0 else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "user": self.user,
            "arena_share_bytes": self.arena_share_bytes,
            "steps": self.steps,
            "last_loss": self.last_loss,
            "peak_bytes": self.peak_bytes,
            "wall_time_s": round(self.wall_time_s, 6),
            "steps_per_sec": round(self.steps_per_sec(), 3),
            "within_share": self.peak_bytes <= self.arena_share_bytes,
        }


@dataclasses.dataclass
class ServeStats:
    """Service-level counters: traffic, queueing, rejection taxonomy."""
    submitted: int = 0
    completed: int = 0
    rejected_admission: int = 0   # no live-session slot free
    rejected_bucket: int = 0      # batch larger than every bucket
    rejected_budget: int = 0      # plan cannot pack inside the arena share
    killed: int = 0               # sessions torn down by fault injection
    queue_depth_high_water: int = 0
    deadlocks: int = 0            # drain passes that made no progress
    sessions: Dict[str, SessionStats] = dataclasses.field(default_factory=dict)

    def session(self, user: str, arena_share_bytes: int) -> SessionStats:
        s = self.sessions.get(user)
        if s is None:
            s = self.sessions[user] = SessionStats(user, arena_share_bytes)
        return s

    def rejected(self) -> int:
        return (self.rejected_admission + self.rejected_bucket
                + self.rejected_budget)

    def report(self) -> Dict[str, Any]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected(),
            "rejected_admission": self.rejected_admission,
            "rejected_bucket": self.rejected_bucket,
            "rejected_budget": self.rejected_budget,
            "killed": self.killed,
            "queue_depth_high_water": self.queue_depth_high_water,
            "deadlocks": self.deadlocks,
            "sessions": {u: s.as_dict()
                         for u, s in sorted(self.sessions.items())},
        }
