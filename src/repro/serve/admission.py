"""Admission control, QoS classes and serving statistics.

The device arena is the scarce resource: every live session's training
steps replay a memory plan whose packed peak must stay inside that
session's *share* of the arena.  Admission is therefore a byte-budget
problem, and the memory planner is the QoS lever — a tenant is admitted
iff (a) a live-session slot of its QoS class is free and (b)
:func:`repro.core.compile_plan_under_budget` can pack its bucket plans
inside the class's share.  Sessions that die (or are killed by fault
injection) release their reservation immediately, so the arena can never
leak.

Shares are priced per :class:`QosClass`: the budget splits
weight-proportionally over the declared slots, so a ``premium`` class
with twice the weight of ``standard`` buys twice the arena share — its
plans pack with fewer swaps and its steps run measurably faster (the
planner, not a scheduler priority, is what the tenant pays for).  The
default is a single equal-share class, byte-identical to the historical
``device_budget_bytes // max_live_sessions`` policy; plans are cached per
(model, bucket, config, share), so each class warms its own cache entry
and tenants of one class still share plans fleet-wide.

Every slot owns a fixed *base offset* into the physical arena.  Each
session's plan packs its own offsets from 0 inside its share, so base
offsets partition the arena into pairwise-disjoint intervals — the
invariant :func:`repro.core.verify.verify_interleaving` proves before the
phase-interleaved scheduler lets sessions share the device streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class QosClass:
    """One admission class: ``slots`` sessions at ``weight``-priced shares.

    ``weight`` scales the class's arena share relative to the other
    classes (share = budget x weight / sum(weight_i x slots_i)); bigger
    share -> the planner packs with fewer swaps -> faster steps.  The
    phase-interleaved scheduler also grants one extra phase advance per
    whole unit of weight each round, so a premium tenant progresses
    faster even when both classes' plans fit without swapping.
    """

    name: str
    weight: float = 1.0
    slots: int = 1

    def __post_init__(self):
        if not self.name:
            raise ValueError("QosClass needs a name")
        if self.weight <= 0:
            raise ValueError(f"QosClass {self.name!r}: weight must be > 0")
        if self.slots <= 0:
            raise ValueError(f"QosClass {self.name!r}: slots must be > 0")


@dataclasses.dataclass(frozen=True)
class _Reservation:
    """One live session's claim: class, priced share, arena base offset."""
    qos: str
    share_bytes: int
    base_offset: int


class AdmissionController:
    """Slot-per-class admission over one device-arena byte budget.

    With the default single class every tenant gets
    ``device_budget_bytes // max_live_sessions`` — deterministic and
    cache-hot, the historical policy.  Declaring :class:`QosClass` tiers
    splits the same budget weight-proportionally; plans are compiled per
    share, so each class fragments the plan cache exactly once.
    """

    def __init__(self, *, max_live_sessions: int,
                 device_budget_bytes: int,
                 qos: Optional[Sequence[QosClass]] = None) -> None:
        if max_live_sessions <= 0:
            raise ValueError("max_live_sessions must be positive")
        if device_budget_bytes <= 0:
            raise ValueError("device_budget_bytes must be positive")
        if qos is None:
            qos = (QosClass("standard", 1.0, slots=max_live_sessions),)
        self.qos: Tuple[QosClass, ...] = tuple(qos)
        names = [c.name for c in self.qos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate QoS class names: {names}")
        if sum(c.slots for c in self.qos) != max_live_sessions:
            raise ValueError(
                f"QoS slots {[(c.name, c.slots) for c in self.qos]} must "
                f"sum to max_live_sessions={max_live_sessions}")
        self.max_live_sessions = max_live_sessions
        self.device_budget_bytes = device_budget_bytes
        weight_units = sum(c.weight * c.slots for c in self.qos)
        self._share: Dict[str, int] = {
            c.name: int(device_budget_bytes * c.weight / weight_units)
            for c in self.qos}
        # fixed base offsets: the arena partitions into one interval per
        # slot, classes in declaration order, so shares never alias
        self._free: Dict[str, List[int]] = {c.name: [] for c in self.qos}
        offset = 0
        for c in self.qos:
            for _ in range(c.slots):
                self._free[c.name].append(offset)
                offset += self._share[c.name]
        self._live: Dict[str, _Reservation] = {}
        self.rejections = 0
        self.rejections_by_class: Dict[str, int] = {c.name: 0
                                                    for c in self.qos}

    @property
    def default_qos(self) -> str:
        return self.qos[0].name

    @property
    def arena_share_bytes(self) -> int:
        """The default class's share (the whole policy, pre-QoS)."""
        return self._share[self.default_qos]

    def share_for(self, qos: Optional[str] = None) -> int:
        return self._share[qos if qos is not None else self.default_qos]

    def qos_class(self, name: str) -> QosClass:
        for c in self.qos:
            if c.name == name:
                return c
        raise KeyError(f"unknown QoS class {name!r}; "
                       f"declared: {[c.name for c in self.qos]}")

    @property
    def live(self) -> Tuple[str, ...]:
        return tuple(sorted(self._live))

    @property
    def reserved_bytes(self) -> int:
        return sum(r.share_bytes for r in self._live.values())

    def reservation(self, user: str) -> Optional[_Reservation]:
        return self._live.get(user)

    def base_offset(self, user: str) -> int:
        return self._live[user].base_offset

    def qos_of(self, user: str) -> str:
        return self._live[user].qos

    def try_admit(self, user: str,
                  qos: Optional[str] = None) -> Optional[int]:
        """Reserve a slot + share for ``user``; None when the class is full.

        Idempotent for already-live users (their existing share is
        returned, nothing double-reserved — the requested ``qos`` must
        not contradict the live reservation).
        """
        existing = self._live.get(user)
        if existing is not None:
            if qos is not None and qos != existing.qos:
                raise ValueError(
                    f"session {user!r} is live in class "
                    f"{existing.qos!r}, cannot re-admit as {qos!r}")
            return existing.share_bytes
        name = qos if qos is not None else self.default_qos
        self.qos_class(name)                     # raises on unknown class
        free = self._free[name]
        if not free:
            self.rejections += 1
            self.rejections_by_class[name] += 1
            return None
        base = free.pop(0)
        share = self._share[name]
        self._live[user] = _Reservation(name, share, base)
        return share

    def release(self, user: str) -> bool:
        """Return ``user``'s slot to its class's pool; False if not live."""
        r = self._live.pop(user, None)
        if r is None:
            return False
        free = self._free[r.qos]
        free.append(r.base_offset)
        free.sort()                  # deterministic re-admission order
        return True

    def arena_slices(self, peaks: Optional[Mapping[str, int]] = None
                     ) -> List["SessionArenaSlice"]:
        """The live sessions as verifier slices (``peaks``: measured or
        planned device peak per user; defaults each to its share)."""
        from repro.core.verify import SessionArenaSlice
        out = []
        for user in self.live:
            r = self._live[user]
            peak = r.share_bytes if peaks is None \
                else peaks.get(user, r.share_bytes)
            out.append(SessionArenaSlice(
                session=user, qos=r.qos, base_offset=r.base_offset,
                share_bytes=r.share_bytes, peak_bytes=peak))
        return out

    def report(self) -> Dict[str, Any]:
        live_by_class: Dict[str, int] = {c.name: 0 for c in self.qos}
        for r in self._live.values():
            live_by_class[r.qos] += 1
        return {
            "max_live_sessions": self.max_live_sessions,
            "device_budget_bytes": self.device_budget_bytes,
            "arena_share_bytes": self.arena_share_bytes,
            "live_sessions": len(self._live),
            "reserved_bytes": self.reserved_bytes,
            "rejections": self.rejections,
            "qos": {
                c.name: {
                    "weight": c.weight,
                    "slots": c.slots,
                    "share_bytes": self._share[c.name],
                    "live": live_by_class[c.name],
                    "rejections": self.rejections_by_class[c.name],
                } for c in self.qos
            },
        }


@dataclasses.dataclass
class SessionStats:
    """Per-tenant QoS counters, updated on every completed step."""
    user: str
    arena_share_bytes: int
    qos: str = "standard"
    steps: int = 0
    last_loss: float = float("nan")
    peak_bytes: int = 0          # max measured HBM high water across steps
    wall_time_s: float = 0.0     # sum of executor step wall times

    def steps_per_sec(self) -> float:
        return self.steps / self.wall_time_s if self.wall_time_s > 0 else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "user": self.user,
            "arena_share_bytes": self.arena_share_bytes,
            "qos": self.qos,
            "steps": self.steps,
            "last_loss": self.last_loss,
            "peak_bytes": self.peak_bytes,
            "wall_time_s": round(self.wall_time_s, 6),
            "steps_per_sec": round(self.steps_per_sec(), 3),
            "within_share": self.peak_bytes <= self.arena_share_bytes,
        }


@dataclasses.dataclass
class QosClassStats:
    """Per-class fairness counters: queue wait and observed starvation."""
    qos: str
    completed: int = 0
    queue_wait_s_total: float = 0.0
    queue_wait_high_water_s: float = 0.0
    # phase advances granted to *other* (higher-weight) classes' sessions
    # while one of this class's sessions sat runnable at a boundary — the
    # round-robin policy's starvation, observable instead of folklore
    bypassed_phases: int = 0

    def note_wait(self, seconds: float) -> None:
        self.queue_wait_s_total += seconds
        self.queue_wait_high_water_s = max(self.queue_wait_high_water_s,
                                           seconds)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "qos": self.qos,
            "completed": self.completed,
            "queue_wait_s_total": round(self.queue_wait_s_total, 6),
            "queue_wait_high_water_s": round(self.queue_wait_high_water_s,
                                             6),
            "bypassed_phases": self.bypassed_phases,
        }


@dataclasses.dataclass
class ServeStats:
    """Service-level counters: traffic, queueing, rejection taxonomy."""
    submitted: int = 0
    completed: int = 0
    rejected_admission: int = 0   # no live-session slot free
    rejected_bucket: int = 0      # batch larger than every bucket
    rejected_budget: int = 0      # plan cannot pack inside the arena share
    killed: int = 0               # sessions torn down by fault injection
    queue_depth_high_water: int = 0
    deadlocks: int = 0            # drain passes that made no progress
    queue_wait_s_total: float = 0.0        # dequeue-to-start, all requests
    queue_wait_high_water_s: float = 0.0
    sessions: Dict[str, SessionStats] = dataclasses.field(default_factory=dict)
    by_qos: Dict[str, QosClassStats] = dataclasses.field(default_factory=dict)

    def session(self, user: str, arena_share_bytes: int,
                qos: str = "standard") -> SessionStats:
        s = self.sessions.get(user)
        if s is None:
            s = self.sessions[user] = SessionStats(user, arena_share_bytes,
                                                   qos)
        return s

    def qos_stats(self, qos: str) -> QosClassStats:
        s = self.by_qos.get(qos)
        if s is None:
            s = self.by_qos[qos] = QosClassStats(qos)
        return s

    def note_queue_wait(self, qos: str, seconds: float) -> None:
        self.queue_wait_s_total += seconds
        self.queue_wait_high_water_s = max(self.queue_wait_high_water_s,
                                           seconds)
        self.qos_stats(qos).note_wait(seconds)

    def rejected(self) -> int:
        return (self.rejected_admission + self.rejected_bucket
                + self.rejected_budget)

    def report(self) -> Dict[str, Any]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected(),
            "rejected_admission": self.rejected_admission,
            "rejected_bucket": self.rejected_bucket,
            "rejected_budget": self.rejected_budget,
            "killed": self.killed,
            "queue_depth_high_water": self.queue_depth_high_water,
            "deadlocks": self.deadlocks,
            "queue_wait_s_total": round(self.queue_wait_s_total, 6),
            "queue_wait_high_water_s": round(self.queue_wait_high_water_s,
                                             6),
            "by_qos": {q: s.as_dict()
                       for q, s in sorted(self.by_qos.items())},
            "sessions": {u: s.as_dict()
                         for u, s in sorted(self.sessions.items())},
        }
