"""Batch-size buckets and the shared compile cache.

The serving cost model: compiling a memory plan (EO analysis -> offload
schedule -> arena packing -> co-optimisation -> verification) is the
expensive step, and it is keyed only by ``(graph, batch shape, planner
config, arena budget)`` — never by *whose* data flows through it.  So the
service quantises request sizes to a small sorted set of buckets, pads
short batches up to the bucket with masked rows, and shares one
:class:`~repro.core.CompiledMemoryPlan` per key across every tenant.

Padding is numerically exact, not approximate: the sample mask zeroes the
loss derivative of pad rows at the source, and because no zoo graph mixes
samples across the batch dimension (batchnorm is the only layer that
would), gradients from a padded bucket match the unpadded batch bit-for-
bit modulo float reassociation (gated at 1e-4 in tests).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import (CompiledMemoryPlan, MemoryPlanConfig, compile_plan,
                        compile_plan_under_budget)
from repro.core.graph import LOSS_KINDS, LayerGraph


# ---------------------------------------------------------------------------
# Bucketing
# ---------------------------------------------------------------------------

def choose_bucket(n: int, buckets: Sequence[int]) -> Optional[int]:
    """Smallest bucket that fits ``n`` samples, or None when ``n`` exceeds
    every bucket (the request must be rejected or split by the caller)."""
    if n <= 0:
        return None
    for b in sorted(buckets):
        if n <= b:
            return b
    return None


def pad_to_bucket(x: jax.Array, y: jax.Array, bucket: int,
                  ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Zero-pad ``(x, y)`` up to ``bucket`` rows; returns ``(x, y, mask)``.

    ``mask`` is a float32 ``(bucket,)`` vector with 1.0 on real rows and
    0.0 on pad rows — feed it to ``CompiledMemoryPlan.loss_and_grads`` so
    the pad rows contribute exactly zero to the loss and every gradient.
    A full batch returns the inputs untouched with ``mask=None`` (the
    unmasked path stays byte-identical to pre-serving behaviour).
    """
    n = int(x.shape[0])
    if n == bucket:
        return x, y, None
    if n > bucket:
        raise ValueError(f"batch of {n} does not fit bucket {bucket}")
    pad = bucket - n
    xp = jnp.concatenate(
        [jnp.asarray(x), jnp.zeros((pad,) + tuple(x.shape[1:]), x.dtype)])
    yp = jnp.concatenate(
        [jnp.asarray(y), jnp.zeros((pad,) + tuple(y.shape[1:]), y.dtype)])
    mask = jnp.concatenate(
        [jnp.ones((n,), jnp.float32), jnp.zeros((pad,), jnp.float32)])
    return xp, yp, mask


def loss_kind(graph: LayerGraph) -> str:
    for l in graph.layers:
        if l.kind in LOSS_KINDS:
            return l.kind
    raise ValueError(f"graph {graph.name!r} has no loss layer")


def dummy_batch(graph: LayerGraph, bucket: int, *,
                seed: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Synthetic ``(x, y)`` at the bucket's full batch size, used to warm
    each bucket's plan (jit compile + first replay) before live traffic."""
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (bucket,) + tuple(graph.input_shape),
                          jnp.float32)
    yshape = (bucket,) + tuple(graph.label_shape)
    if loss_kind(graph) == "loss_ce":
        classes = yshape[-1]
        idx = jax.random.randint(ky, yshape[:-1], 0, classes)
        y = jax.nn.one_hot(idx, classes, dtype=jnp.float32)
    else:
        y = jax.random.normal(ky, yshape, jnp.float32)
    return x, y


# ---------------------------------------------------------------------------
# The compile cache
# ---------------------------------------------------------------------------

class PlanCache:
    """``(model, bucket, planner config, arena budget) -> CompiledMemoryPlan``.

    The key includes every :class:`MemoryPlanConfig` field
    (``config.cache_key()``) *and* the arena byte budget, so two tenants
    whose QoS budgets differ can never share a plan even when every other
    knob matches — plan sharing is an optimisation, never an isolation
    leak.  ``hits``/``misses`` count live lookups; seeding a warm-up
    compile counts as the miss it is (a compile happened).
    """

    def __init__(self) -> None:
        self._plans: Dict[Tuple[Any, ...], CompiledMemoryPlan] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(graph: LayerGraph, bucket: int, config: MemoryPlanConfig,
            arena_budget_bytes: Optional[int]) -> Tuple[Any, ...]:
        return (graph.name, int(bucket), config.cache_key(),
                arena_budget_bytes)

    def get_or_compile(self, graph: LayerGraph, config: MemoryPlanConfig,
                       *, bucket: int,
                       arena_budget_bytes: Optional[int] = None,
                       ) -> CompiledMemoryPlan:
        """Return the cached plan for the key, compiling on first use.

        With a budget, compilation goes through
        :func:`repro.core.compile_plan_under_budget` and may raise
        :class:`repro.core.ArenaBudgetError` — the caller's admission
        signal.  A failed compile caches nothing.
        """
        k = self.key(graph, bucket, config, arena_budget_bytes)
        cp = self._plans.get(k)
        if cp is not None:
            self.hits += 1
            return cp
        self.misses += 1
        if arena_budget_bytes is None:
            cp = compile_plan(graph, config, batch=bucket)
        else:
            cp = compile_plan_under_budget(
                graph, config, batch=bucket,
                arena_budget_bytes=arena_budget_bytes)
        self._plans[k] = cp
        return cp

    def seed(self, graph: LayerGraph, bucket: int, config: MemoryPlanConfig,
             arena_budget_bytes: Optional[int],
             cp: CompiledMemoryPlan) -> None:
        """Install an already-compiled plan (warm-up probes) as a miss."""
        self._plans[self.key(graph, bucket, config, arena_budget_bytes)] = cp
        self.misses += 1

    def __len__(self) -> int:
        return len(self._plans)

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def report(self) -> Dict[str, Any]:
        return {"entries": len(self._plans), "hits": self.hits,
                "misses": self.misses, "hit_rate": round(self.hit_rate(), 4)}
