"""Gradient compression for cross-pod data parallelism.

The inter-pod link (DCN) is an order of magnitude slower than ICI, so the
pod-axis gradient all-reduce is the term to compress.  We implement
int8 block-quantised compression with error feedback (EF-SGD style):

    e_t      — residual carried per parameter
    c_t      = Q(g_t + e_{t-1})         (int8 + per-block fp32 scales)
    e_t      = (g_t + e_{t-1}) - deQ(c_t)
    all-reduce c_t over the pod axis (8.06x fewer DCN bytes), then deQ.

Error feedback makes the compression *unbiased over time*: quantisation
error is re-injected into the next step, preserving convergence (the
standard EF guarantee).  Compression is a hook on the train step — the
within-pod reduction stays full precision (ICI is cheap).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CBLOCK = 256


def _q(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // CBLOCK)
    padded = jnp.pad(flat, (0, nb * CBLOCK - n)).reshape(nb, CBLOCK)
    scale = jnp.max(jnp.abs(padded), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(padded / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _deq(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= int(s)
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compress_gradients(grads) -> Any:
    """Tree of (int8 blocks, fp32 scales) — ~8.06x smaller than fp32."""
    return jax.tree_util.tree_map(
        lambda g: dict(zip(("q", "scale"), _q(g.astype(jnp.float32)))), grads)


def decompress_gradients(cgrads, like) -> Any:
    flat_g, tdef = jax.tree_util.tree_flatten(like)
    flat_c = tdef.flatten_up_to(cgrads)
    return tdef.unflatten([
        _deq(c["q"], c["scale"], g.shape).astype(jnp.float32)
        for c, g in zip(flat_c, flat_g)])


def error_feedback_update(grads, residual):
    """(compressed, new_residual): quantise g+e, carry the error forward."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _q(gf)
        deq = _deq(q, scale, gf.shape)
        return {"q": q, "scale": scale}, gf - deq
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(residual)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_residual(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_pod(grads, residual, axis_name: str = "pod"):
    """Inside shard_map: EF-compress, all-gather the int8 payloads over the
    pod (DCN) axis, dequantise each pod's contribution locally, average.

    Per-pod scales differ, so a plain psum of int8 values is not meaningful;
    the all-gather formulation keeps the DCN traffic at ~1 byte/param
    (vs 4 for an fp32 all-reduce) while staying exact w.r.t. the quantised
    values.  Returns (mean gradient fp32, new error residual).
    """
    cgrads, new_res = error_feedback_update(grads, residual)

    def reduce_one(c, g):
        qs = jax.lax.all_gather(c["q"], axis_name)          # (P, nb, CBLOCK)
        ss = jax.lax.all_gather(c["scale"], axis_name)      # (P, nb, 1)
        contrib = (qs.astype(jnp.float32) * ss)             # dequantised
        mean = jnp.mean(contrib, axis=0)
        n = 1
        for s in g.shape:
            n *= int(s)
        return mean.reshape(-1)[:n].reshape(g.shape)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_c = tdef.flatten_up_to(cgrads)
    reduced = tdef.unflatten(
        [reduce_one(c, g) for c, g in zip(flat_c, flat_g)])
    return reduced, new_res
