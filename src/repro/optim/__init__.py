from repro.optim.optimizers import (Optimizer, adamw, sgd_momentum,
                                    make_optimizer)
from repro.optim.compression import (compress_gradients, decompress_gradients,
                                     error_feedback_update)

__all__ = ["Optimizer", "adamw", "sgd_momentum", "make_optimizer",
           "compress_gradients", "decompress_gradients",
           "error_feedback_update"]
