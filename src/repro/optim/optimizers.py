"""Optimizers with memory-planned state (the paper's ethos at pod scale).

AdamW supports quantised first/second moments (int8 with per-tensor-block
scales) — on a 235B-parameter model the optimizer state drops from 8 bytes
to ~2.06 bytes per parameter, the difference between fitting 256 chips or
not.  State quantisation uses error-free per-block absmax scaling with
fp32 de/requantisation around the update (cf. 8-bit Adam).

All state trees mirror the parameter tree, so parameter shardings apply
verbatim (ZeRO-1 simply maps their specs through FSDP rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256  # quantisation block (elements) for int8 moment storage


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    name: str = "opt"


# ---------------------------------------------------------------------------
# int8 block quantisation for moments
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array) -> Dict[str, jax.Array]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // QBLOCK)
    padded = jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
    scale = jnp.max(jnp.abs(padded), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(padded / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(qs: Dict[str, jax.Array], shape) -> jax.Array:
    x = (qs["q"].astype(jnp.float32) * qs["scale"]).reshape(-1)
    return x[: _size(shape)].reshape(shape)


def _size(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype: str = "float32") -> Optimizer:
    """state_dtype: 'float32' | 'bfloat16' | 'int8' (block-quantised)."""

    def init(params):
        def one(p):
            if state_dtype == "int8":
                z = jnp.zeros(p.shape, jnp.float32)
                return {"m": _quantize(z), "v": _quantize(z)}
            dt = jnp.bfloat16 if state_dtype == "bfloat16" else jnp.float32
            return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}
        return {"mu": jax.tree_util.tree_map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, *_):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def one(g, mv, p):
            gf = g.astype(jnp.float32)
            if state_dtype == "int8":
                m = _dequantize(mv["m"], p.shape)
                v = _dequantize(mv["v"], p.shape)
            else:
                m = mv["m"].astype(jnp.float32)
                v = mv["v"].astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            new_p = p - lr * (upd + weight_decay * p.astype(jnp.float32))
            if state_dtype == "int8":
                new_mv = {"m": _quantize(m), "v": _quantize(v)}
            else:
                dt = mv["m"].dtype
                new_mv = {"m": m.astype(dt), "v": v.astype(dt)}
            return new_p.astype(p.dtype), new_mv

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_mv = tdef.flatten_up_to(state["mu"])
        flat_p = tdef.flatten_up_to(params)
        outs = [one(g, mv, p) for g, mv, p in zip(flat_g, flat_mv, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_mu = tdef.unflatten([o[1] for o in outs])
        return new_p, {"mu": new_mu, "count": count}

    return Optimizer(init=init, update=update, name=f"adamw_{state_dtype}")


def sgd_momentum(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, *_):
        def one(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p - lr * m).astype(p.dtype), m
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["mom"])
        flat_p = tdef.flatten_up_to(params)
        outs = [one(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (tdef.unflatten([o[0] for o in outs]),
                {"mom": tdef.unflatten([o[1] for o in outs])})

    return Optimizer(init=init, update=update, name="sgd_momentum")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name.startswith("adamw"):
        return adamw(**kw)
    if name == "sgd":
        return sgd_momentum(**kw)
    raise ValueError(name)
