from repro.sharding.rules import (constrain, current_mesh, logical_to_spec,
                                  named_sharding, set_mesh_and_rules,
                                  use_mesh)
from repro.sharding.api import (activation_rules, param_shardings,
                                tree_shardings)

__all__ = ["constrain", "current_mesh", "logical_to_spec", "named_sharding",
           "set_mesh_and_rules", "use_mesh", "activation_rules",
           "param_shardings", "tree_shardings"]
