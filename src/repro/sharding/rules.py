"""Logical-axis sharding rules (GSPMD) for the repro framework.

Models annotate tensors with *logical* axis names; the active rule set maps
them to mesh axes.  This is the flax-linen logical-axis pattern without the
flax dependency — a thread-global context installed by the launcher.

Physical mesh axes:
    pod    — across pods (DCN): pure data parallelism (+ pipeline option)
    data   — within-pod data parallelism / FSDP / sequence parallelism
    model  — tensor/expert parallelism (ICI)

Logical axes used across the codebase:
    batch       — global batch            -> ("pod", "data")
    seq         — sequence (activations)  -> None (or "data" for SP)
    heads       — attention heads         -> "model"
    kv_heads    — KV heads                -> "model" iff divisible else None
    embed       — d_model                 -> None (activations) / FSDP "data" (params)
    mlp         — d_ff                    -> "model"
    vocab       — vocabulary              -> "model"
    expert      — MoE experts             -> "model"
    qkv         — fused qkv dim           -> "model"
    kv_seq      — KV-cache sequence       -> None ("data" for long-context)
    stage       — pipeline stage          -> "pod" (pipeline mode)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "batch_nopod": ("data",),
    "seq": None,
    "sp_seq": ("data",),          # sequence parallelism (long context)
    "heads": ("model",),
    "kv_heads": None,             # overridden per-config when divisible
    "embed": None,
    "fsdp_embed": ("data",),      # ZeRO-3/FSDP weight sharding over data
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "kv_seq": None,
    "state": None,
    "conv": None,
}


def set_mesh_and_rules(mesh: Optional[Mesh],
                       rules: Optional[Dict[str, Optional[Tuple[str, ...]]]] = None):
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES)
    if rules:
        _state.rules.update(rules)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Dict[str, Optional[Tuple[str, ...]]]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh],
             rules: Optional[Dict[str, Optional[Tuple[str, ...]]]] = None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", None)
    set_mesh_and_rules(mesh, rules)
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules if prev_rules is not None else dict(DEFAULT_RULES)


def logical_to_spec(logical_axes: Sequence[Optional[str]]) -> P:
    """Map a tuple of logical axis names (or None) to a PartitionSpec,
    dropping mesh axes that do not exist in the active mesh."""
    mesh = current_mesh()
    rules = current_rules()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    out = []
    used = set()
    for ax in logical_axes:
        if ax is None:
            out.append(None)
            continue
        phys = rules.get(ax)
        if phys is None:
            out.append(None)
            continue
        phys = tuple(p for p in phys if p in mesh_axes and p not in used)
        used.update(phys)
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, *logical_axes: Optional[str]):
    """with_sharding_constraint against the active mesh (no-op when absent
    or when running single-device smoke tests)."""
    mesh = current_mesh()
    if mesh is None or len(logical_axes) != x.ndim:
        return x
    spec = logical_to_spec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes))


def spec_for_param(logical_axes: Sequence[Optional[str]]) -> P:
    return logical_to_spec(logical_axes)
