"""Sharding assembly: per-(arch, shape) rule sets and pytree shardings.

Two rule sets exist per run:

* activation rules — installed thread-globally (``use_mesh``) and consumed
  by ``constrain()`` inside the model code.  Heads/kv-heads shard over
  ``model`` only when divisible; batch shards over (pod, data) only when
  divisible (long-context batch=1 falls back to sequence parallelism).

* parameter rules — used only to compute ``in_shardings`` for params and
  optimizer state.  ``embed`` maps to the FSDP axis (``data``) for
  architectures whose parameters do not fit TP-sharded alone (ZeRO-3-style
  weight sharding); optimizer moments are always FSDP-sharded (ZeRO-1).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding import rules as R

# parameter bytes above which FSDP weight sharding is enabled (fp32 master
# params would not fit 16-way TP alone on 16 GiB chips)
FSDP_PARAM_THRESHOLD = 8e9

# Perf-iteration override hooks (set by launch/perf.py around probe runs):
# "rules" updates the activation rule set; "fsdp" forces ZeRO-3 on/off.
_OVERRIDES: Dict[str, object] = {"rules": None, "fsdp": None}


def set_overrides(rules=None, fsdp=None) -> None:
    _OVERRIDES["rules"] = rules
    _OVERRIDES["fsdp"] = fsdp


def clear_overrides() -> None:
    set_overrides(None, None)


def _divisible(n: int, mesh: Mesh, axes: Tuple[str, ...]) -> bool:
    size = 1
    for a in axes:
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size > 0 and n % size == 0


def activation_rules(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Mesh) -> Dict[str, Optional[Tuple[str, ...]]]:
    rules: Dict[str, Optional[Tuple[str, ...]]] = dict(R.DEFAULT_RULES)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if _divisible(shape.global_batch, mesh, batch_axes):
        rules["batch"] = batch_axes
        rules["kv_seq"] = None
    else:
        # long-context decode (batch=1): shard the KV/state sequence instead
        rules["batch"] = None
        rules["kv_seq"] = ("data",)
        rules["sp_seq"] = ("data",)
    rules["heads"] = ("model",) if _divisible(cfg.n_heads, mesh, ("model",)) \
        else None
    rules["kv_heads"] = ("model",) \
        if _divisible(cfg.n_kv_heads, mesh, ("model",)) else None
    if shape.kind == "decode" and rules["kv_heads"] is None:
        # KV heads not divisible by the model axis: shard the KV-cache
        # sequence dim over 'model' instead (flash-decode style — partial
        # attention per shard, GSPMD inserts the softmax-stat combine).
        # Without this, a 32k cache replicates across the model axis and
        # blows HBM (observed 51.9 GiB/dev on qwen3 decode_32k).
        rules["kv_seq"] = tuple(rules["kv_seq"] or ()) + ("model",)
    if cfg.family in ("ssm", "hybrid"):
        state = cfg.ssm_state or 64
        rules["state"] = ("model",) if _divisible(state, mesh, ("model",)) \
            else None
    if _OVERRIDES["rules"]:
        rules.update(_OVERRIDES["rules"])
    return rules


def param_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: Optional[bool] = None,
                zero1: bool = False) -> Dict[str, Optional[Tuple[str, ...]]]:
    if _OVERRIDES["fsdp"] is not None:
        fsdp = bool(_OVERRIDES["fsdp"])
    if fsdp is None:
        fsdp = cfg.param_count() * 4 > FSDP_PARAM_THRESHOLD
    rules = dict(R.DEFAULT_RULES)
    if fsdp or zero1:
        rules["embed"] = ("data",)       # weight d_model dim -> FSDP
    else:
        rules["embed"] = None
    # vocab: model-sharded (padded to a multiple of 256 in the model code)
    return rules


def _spec_from_logical(logical, rules, mesh) -> P:
    mesh_axes = set(mesh.axis_names)
    out = []
    used = set()
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        phys = rules.get(ax)
        if phys is None:
            out.append(None)
            continue
        phys = tuple(p for p in phys if p in mesh_axes and p not in used)
        used.update(phys)
        out.append(None if not phys else
                   (phys[0] if len(phys) == 1 else tuple(phys)))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(mesh: Mesh, spec_tree, rules, shape_tree=None):
    """Map a logical-axis pytree to NamedShardings.

    When ``shape_tree`` (matching ShapeDtypeStructs) is given, any axis whose
    dimension is not divisible by its mesh-axes product is dropped to None —
    the safety net for odd dims (e.g. unpadded vocab remainders).
    """
    def one(logical, aval=None):
        spec = _spec_from_logical(logical, rules, mesh)
        if aval is not None:
            parts = list(spec) + [None] * (len(aval.shape) - len(spec))
            fixed = []
            for dim, part in zip(aval.shape, parts):
                if part is None:
                    fixed.append(None)
                    continue
                axes = (part,) if isinstance(part, str) else tuple(part)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                fixed.append(part if dim % size == 0 else None)
            while fixed and fixed[-1] is None:
                fixed.pop()
            spec = P(*fixed)
        return NamedSharding(mesh, spec)

    is_leaf = lambda v: isinstance(v, tuple)
    if shape_tree is None:
        return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_leaf)
    flat_s, tdef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_leaf)
    flat_a = tdef.flatten_up_to(shape_tree)
    return tdef.unflatten([one(s, a) for s, a in zip(flat_s, flat_a)])


def param_shardings(mesh: Mesh, cfg: ModelConfig, spec_tree, shape_tree=None,
                    *, fsdp: Optional[bool] = None, zero1: bool = False):
    return tree_shardings(mesh, spec_tree,
                          param_rules(cfg, mesh, fsdp=fsdp, zero1=zero1),
                          shape_tree)
