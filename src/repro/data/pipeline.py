"""Data pipeline: DataProducer -> Batch Queue -> DataSet (paper §4 setData).

NNTrainer's ``setData`` process: a user-supplied DataProducer generates
examples, a background thread accumulates them into batch-sized chunks in a
bounded Batch Queue, and the training loop pops ready batches.  The same
structure here, with multi-host awareness: each host produces only its
data-parallel shard of the global batch (``host_batch_slice``).

Producers are deterministic functions of (epoch, index) so a restarted
host reproduces the exact stream — the property checkpoint/restart relies
on (the saved ``DataState`` pins the stream position).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataState:
    """Stream position — saved in checkpoints, restored on restart."""
    epoch: int = 0
    index: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "index": self.index}

    @classmethod
    def from_dict(cls, d) -> "DataState":
        return cls(epoch=int(d["epoch"]), index=int(d["index"]))


Producer = Callable[[int, int, np.random.Generator], Dict[str, np.ndarray]]


def synthetic_lm_producer(vocab: int, seq_len: int) -> Producer:
    """Deterministic synthetic LM stream (self-seeded per (epoch, index)).

    Emits learnable structure — each sequence counts upward from a random
    start (``t[i+1] = t[i] + 1 mod vocab``) with occasional noise tokens —
    so training loss measurably decreases (uniform-random tokens would
    start AT the entropy floor and show nothing)."""
    def produce(epoch: int, index: int, _rng) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((epoch * 1_000_003 + index) & 0x7FFFFFFF)
        start = rng.integers(0, vocab)
        tokens = (start + np.arange(seq_len + 1)) % vocab
        noise = rng.random(seq_len + 1) < 0.05
        tokens = np.where(noise, rng.integers(0, vocab, seq_len + 1), tokens)
        tokens = tokens.astype(np.int32)
        return {"tokens": tokens[:-1], "targets": tokens[1:]}
    return produce


def file_lm_producer(path: str, vocab: int, seq_len: int) -> Producer:
    """Memory-mapped token file: examples are deterministic windows."""
    data = np.memmap(path, dtype=np.int32, mode="r")
    n_windows = max((len(data) - 1) // seq_len, 1)

    def produce(epoch: int, index: int, _rng) -> Dict[str, np.ndarray]:
        w = (epoch * 7919 + index) % n_windows
        chunk = np.asarray(data[w * seq_len: w * seq_len + seq_len + 1])
        if len(chunk) < seq_len + 1:
            chunk = np.pad(chunk, (0, seq_len + 1 - len(chunk)))
        chunk = np.clip(chunk, 0, vocab - 1).astype(np.int32)
        return {"tokens": chunk[:-1], "targets": chunk[1:]}
    return produce


def host_batch_slice(global_batch: int, host_id: int, n_hosts: int
                     ) -> Tuple[int, int]:
    per = global_batch // n_hosts
    return host_id * per, per


class BatchQueue:
    """Bounded queue of ready host-batches filled by a producer thread."""

    def __init__(self, producer: Producer, *, batch: int, state: DataState,
                 prefetch: int = 2, extra: Optional[Dict[str, Callable]] = None):
        self._producer = producer
        self._batch = batch
        self._state = state
        self._extra = extra or {}
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        epoch, index = self._state.epoch, self._state.index
        rng = np.random.default_rng(0)
        while not self._stop.is_set():
            examples = []
            for i in range(self._batch):
                examples.append(self._producer(epoch, index + i, rng))
            batch = {
                k: np.stack([ex[k] for ex in examples])
                for k in examples[0]
            }
            for k, fn in self._extra.items():
                batch[k] = fn(self._batch)
            index += self._batch
            state = DataState(epoch, index)
            try:
                self._q.put((batch, state), timeout=1.0)
            except queue.Full:
                index -= self._batch  # retry the same chunk
                continue

    def get(self, timeout: float = 60.0):
        """-> (host_batch dict of np arrays, DataState after this batch)."""
        return self._q.get(timeout=timeout)

    def close(self) -> None:
        self._stop.set()

    def __iter__(self) -> Iterator:
        while True:
            yield self.get()
