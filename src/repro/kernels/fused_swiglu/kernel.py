"""Pallas TPU kernel: fused SwiGLU gate+up projection.

Computes ``h = silu(x @ Wg) * (x @ Wu)`` in one pass: grid
(M_blocks, F_blocks, K_blocks) with K innermost; two fp32 accumulators live
in VMEM scratch across K steps, and the silu*mul epilogue runs on the final
K step — so x is streamed from HBM once for BOTH matmuls and neither
(M, d_ff) pre-activation is ever written to HBM.

Memory-traffic napkin math per (M,F) tile versus unfused XLA:
    unfused:  read x twice (2*M*K), write g and u (2*M*F), read g,u, write h
              -> extra 4*M*F HBM bytes
    fused:    read x once per F-block, write h once
The elementwise epilogue is exactly the op class the paper flags as
low OP/byte (§2 "Computation") — fusing it into the matmul removes its
memory traffic entirely.

Tiles: (block_m x block_k) @ (block_k x block_f) MXU passes, all dims
multiples of 128; default 256x512x512 bf16 ~ 1.4 MiB VMEM including the
two fp32 accumulators.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, wg_ref, wu_ref, h_ref, accg_ref, accu_ref, *,
                   num_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == num_k - 1)
    def _epilogue():
        g = accg_ref[...]
        u = accu_ref[...]
        h_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(h_ref.dtype)


def fused_swiglu_pallas(x, wg, wu, *, block_m: int = 256, block_f: int = 512,
                        block_k: int = 512, interpret: bool = False):
    """x: (M, K); wg, wu: (K, F) -> h: (M, F) = silu(x wg) * (x wu)."""
    m, kdim = x.shape
    _, f = wg.shape
    block_m = min(block_m, m)
    block_k = min(block_k, kdim)
    block_f = min(block_f, f)
    nm = -(-m // block_m)
    nk = -(-kdim // block_k)
    nf = -(-f // block_f)
    pm, pk, pf = nm * block_m - m, nk * block_k - kdim, nf * block_f - f
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pf:
        wg = jnp.pad(wg, ((0, pk), (0, pf)))
        wu = jnp.pad(wu, ((0, pk), (0, pf)))

    kern = functools.partial(_swiglu_kernel, num_k=nk)
    h = pl.pallas_call(
        kern,
        grid=(nm, nf, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, fi, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_f), lambda mi, fi, ki: (ki, fi)),
            pl.BlockSpec((block_k, block_f), lambda mi, fi, ki: (ki, fi)),
        ],
        out_specs=pl.BlockSpec((block_m, block_f),
                               lambda mi, fi, ki: (mi, fi)),
        out_shape=jax.ShapeDtypeStruct((nm * block_m, nf * block_f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_f), jnp.float32),
            pltpu.VMEM((block_m, block_f), jnp.float32),
        ],
        interpret=interpret,
    )(x, wg, wu)
    return h[:m, :f]
