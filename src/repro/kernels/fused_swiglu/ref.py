"""Pure-jnp oracle for the fused SwiGLU kernel."""

import jax
import jax.numpy as jnp


def swiglu_ref(x, wg, wu):
    """x: (M, K); wg, wu: (K, F) -> silu(x wg) * (x wu), fp32 accumulation."""
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)
