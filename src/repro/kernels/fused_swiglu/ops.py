"""Jit'd wrapper for the fused SwiGLU kernel (interpret fallback off-TPU)."""

import functools

import jax

from repro.kernels.fused_swiglu.kernel import fused_swiglu_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_f", "block_k",
                                    "interpret"))
def fused_swiglu(x, wg, wu, *, block_m: int = 256, block_f: int = 512,
                 block_k: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return fused_swiglu_pallas(x, wg, wu, block_m=block_m, block_f=block_f,
                               block_k=block_k, interpret=interpret)
