"""Jit'd mLSTM scan: Pallas intra-chunk kernel + JAX stabilised cross-chunk
recurrence and combine."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_scan.kernel import mlstm_chunk_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk: int = 256,
               interpret: bool | None = None):
    """q,k,v: (b,s,h,p); i_gate,f_gate: (b,s,h) raw logits -> (b,s,h,p)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, p = q.shape
    scale = 1.0 / math.sqrt(p)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    li = i_gate.astype(jnp.float32)

    qq = min(chunk, s)
    nc = -(-s // qq)
    pad = nc * qq - s
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad4) for t in (q, k, v))
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)

    r5 = lambda t: t.reshape(b, nc, qq, h, p).astype(jnp.float32)
    r4 = lambda t: t.reshape(b, nc, qq, h)
    y_i, n_i, m_i, states, norms, chunk_lf, m_state = mlstm_chunk_pallas(
        r5(q), r5(k), r5(v), r4(li), r4(lf), sm_scale=scale,
        interpret=interpret)

    # ---- cross-chunk stabilised recurrence --------------------------------
    def step(carry, inp):
        C, n, m = carry
        st, nr, clf, mst = inp
        m_new = jnp.maximum(m + clf, mst)
        alpha = jnp.exp(m + clf - m_new)
        beta = jnp.exp(mst - m_new)
        C_new = C * alpha[..., None, None] + st * beta[..., None, None]
        n_new = n * alpha[..., None] + nr * beta[..., None]
        return (C_new, n_new, m_new), (C, n, m)          # emit previous

    C0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, (C_prev, n_prev, m_prev) = jax.lax.scan(
        step, (C0, n0, m0),
        (states.transpose(1, 0, 2, 3, 4), norms.transpose(1, 0, 2, 3),
         chunk_lf.transpose(1, 0, 2), m_state.transpose(1, 0, 2)))
    C_prev = C_prev.transpose(1, 0, 2, 3, 4)
    n_prev = n_prev.transpose(1, 0, 2, 3)
    m_prev = m_prev.transpose(1, 0, 2)

    # ---- combine intra + inter --------------------------------------------
    lf_cum = jnp.cumsum(r4(lf), axis=2)
    inter_decay = lf_cum + m_prev[:, :, None, :]         # (b,nc,q,h)
    m_total = jnp.maximum(m_i, inter_decay)
    w_intra = jnp.exp(m_i - m_total)
    w_inter = jnp.exp(inter_decay - m_total)

    qs = r5(q) * scale
    y_inter = jnp.einsum("bcqhp,bchpr->bcqhr",
                         qs * w_inter[..., None], C_prev)
    n_inter = jnp.einsum("bcqhp,bchp->bcqh",
                         qs * w_inter[..., None], n_prev)
    num = y_i * w_intra[..., None] + y_inter
    den = jnp.maximum(jnp.abs(n_i * w_intra + n_inter), jnp.exp(-m_total))
    y = num / den[..., None]
    return y.reshape(b, nc * qq, h, p)[:, :s]
