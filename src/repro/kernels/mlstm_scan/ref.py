"""Oracle for the mLSTM scan: exact stabilised sequential recurrence
(xLSTM arXiv:2405.04517, eqs. 19-27).

    m_t = max(log f_t + m_{t-1}, i_t)
    C_t = exp(log f_t + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) v_t k_t^T
    n_t = exp(log f_t + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
    y_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t))
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, i_gate, f_gate):
    """q,k,v: (b,s,h,p); i_gate,f_gate: (b,s,h) raw logits -> (b,s,h,p)."""
    b, s, h, p = q.shape
    scale = 1.0 / math.sqrt(p)
    lf = jax.nn.log_sigmoid(f_gate)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lft + m, lit)
        alpha = jnp.exp(lft + m - m_new)
        beta = jnp.exp(lit - m_new)
        C_new = C * alpha[..., None, None] \
            + beta[..., None, None] * jnp.einsum("bhp,bhr->bhpr", kt, vt)
        n_new = n * alpha[..., None] + beta[..., None] * kt
        qs = qt * scale
        num = jnp.einsum("bhp,bhpr->bhr", qs, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qs, n_new)),
                          jnp.exp(-m_new))
        return (C_new, n_new, m_new), num / den[..., None]

    C0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, ys = jax.lax.scan(
        step, (C0, n0, m0),
        (q.transpose(1, 0, 2, 3).astype(jnp.float32),
         k.transpose(1, 0, 2, 3).astype(jnp.float32),
         v.transpose(1, 0, 2, 3).astype(jnp.float32),
         i_gate.transpose(1, 0, 2).astype(jnp.float32),
         lf.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2, 3)
