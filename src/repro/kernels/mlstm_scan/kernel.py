"""Pallas TPU kernel: mLSTM intra-chunk computation (xLSTM matrix memory).

One grid step = one (batch, chunk, head).  Computes in VMEM the
chunk-local quantities the cross-chunk combine needs:

    dmat[i,j] = lf_cum[i] - lf_cum[j] + li[j]   (j<=i)      (Q,Q)
    m_intra   = rowmax(dmat)                                 (Q,1)
    scores    = (q @ k^T) * sm_scale                         (Q,Q)  [MXU]
    y_intra   = (scores * exp(dmat - m_intra)) @ v           (Q,P)  [MXU]
    n_intra   = rowsum(scores * exp(dmat - m_intra))         (Q,1)
    m_state   = max(decay_to_end)                            (1,1)
    state     = k^T @ (exp(decay_to_end - m_state) * v)      (P,P)  [MXU]
    norm      = sum_j exp(decay_to_end - m_state) k_j        (1,P)
    chunk_lf  = lf_cum[Q-1]                                  (1,1)

The sequential cross-chunk recurrence and the stabilised intra/inter
combine stay in JAX (see ops.py) — they are O(S/Q) work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlstm_chunk_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
                        y_ref, ni_ref, mi_ref, st_ref, nr_ref,
                        clf_ref, mst_ref, *, sm_scale: float):
    q = q_ref[0, 0, :, 0].astype(jnp.float32) * sm_scale   # (Q,P)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)              # (Q,P)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)              # (Q,P)
    li = li_ref[0, 0].astype(jnp.float32)                  # (Q,1)
    lf = lf_ref[0, 0].astype(jnp.float32)                  # (Q,1)

    qq = q.shape[0]
    lf_cum = jnp.cumsum(lf, axis=0)                        # (Q,1)
    dmat = lf_cum - lf_cum.reshape(1, qq) + li.reshape(1, qq)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (qq, qq), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (qq, qq), 1))
    dmat = jnp.where(tri, dmat, -1e30)
    m_intra = jnp.max(dmat, axis=1, keepdims=True)         # (Q,1)
    w = jnp.exp(dmat - m_intra)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    sw = scores * w
    y = jax.lax.dot_general(sw, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    ni_ref[0, 0] = jnp.sum(sw, axis=1, keepdims=True).astype(ni_ref.dtype)
    mi_ref[0, 0] = m_intra.astype(mi_ref.dtype)

    decay_end = lf_cum[qq - 1] - lf_cum + li               # (Q,1)
    m_state = jnp.max(decay_end).reshape(1, 1)
    sk = jnp.exp(decay_end - m_state)                      # (Q,1)
    st = jax.lax.dot_general(k, v * sk, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P,P)
    st_ref[0, 0] = st.astype(st_ref.dtype)
    nr_ref[0, 0] = jnp.sum(k * sk, axis=0).astype(nr_ref.dtype)
    clf_ref[...] = lf_cum[qq - 1].reshape(1, 1).astype(clf_ref.dtype)
    mst_ref[...] = m_state.astype(mst_ref.dtype)


def mlstm_chunk_pallas(q, k, v, li, lf, *, sm_scale: float,
                       interpret: bool = False):
    """q,k,v: (b,nc,Q,h,p); li,lf: (b,nc,Q,h).

    Returns per-chunk tensors:
      y_intra (b,nc,Q,h,p), n_intra (b,nc,Q,h), m_intra (b,nc,Q,h),
      states (b,nc,h,p,p), norms (b,nc,h,p), chunk_lf (b,nc,h),
      m_state (b,nc,h)
    """
    import functools
    b, nc, qq, h, p = q.shape
    grid = (b, nc, h)
    kern = functools.partial(_mlstm_chunk_kernel, sm_scale=sm_scale)
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qq, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, qq, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, qq, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, qq, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, qq, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qq, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, qq, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, qq, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, p, p), lambda bi, ci, hi: (bi, ci * h + hi, 0, 0)),
            pl.BlockSpec((1, 1, p), lambda bi, ci, hi: (bi, ci * h + hi, 0)),
            pl.BlockSpec((1, 1), lambda bi, ci, hi: (bi, ci * h + hi)),
            pl.BlockSpec((1, 1), lambda bi, ci, hi: (bi, ci * h + hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, qq, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, qq, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, qq, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h, p, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf)
    y, ni, mi, st, nr, clf, mst = outs
    return (y, ni, mi,
            st.reshape(b, nc, h, p, p), nr.reshape(b, nc, h, p),
            clf.reshape(b, nc, h), mst.reshape(b, nc, h))
