"""Oracle for the SSD/mamba2 scan: the exact sequential recurrence.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T      (outer product)
    y_t = C_t . h_t

h: (N, P) per head; A = -exp(A_log) (negative decay rate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A_log, B, C):
    """x: (b,s,h,p); dt: (b,s,h); A_log: (h,); B,C: (b,s,n) -> y: (b,s,h,p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    A = -jnp.exp(A_log)                                     # (h,)

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp                               # (b,h,p),(b,h),(b,n)
        dA = jnp.exp(dtt * A[None])                         # (b,h)
        dBx = jnp.einsum("bn,bh,bhp->bhnp", Bt, dtt, xt)
        hnew = hstate * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhnp->bhp", Ct, hnew)
        return hnew, y

    h0 = jnp.zeros((b, h, n, p), x.dtype)
    _, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2, 3),
                                    dt.transpose(1, 0, 2),
                                    B.transpose(1, 0, 2),
                                    C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3)                         # (b,s,h,p)
