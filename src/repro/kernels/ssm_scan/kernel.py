"""Pallas TPU kernel: SSD (mamba2) intra-chunk scan.

One grid step handles one (batch, chunk, head) tile and computes, entirely
in VMEM:

    dA      = dt * (-exp(A_log))                      (Q,1)
    dA_cum  = cumsum(dA)                              (Q,1)
    L[i,j]  = exp(dA_cum[i] - dA_cum[j]) . tril       (Q,Q)
    y_diag  = ((C B^T) * L * dt_j) @ X                (Q,P)   [MXU]
    decay_e = exp(dA_cum[Q-1] - dA_cum)               (Q,1)
    state   = B^T @ (dt * decay_e * X)                (N,P)   [MXU]
    clf     = dA_cum[Q-1]                             (1,1)

The inter-chunk recurrence (sequential over S/Q chunk states) stays in JAX
— it is O(S/Q) tiny fused multiply-adds and does not benefit from a kernel.

VMEM working set at Q=256, N=64, P=64 fp32: X/B/C/dt ~ 0.3 MiB, the (Q,Q)
decay/score tiles 0.5 MiB — comfortably inside VMEM with double buffering.
Q is a multiple of the 128-lane VREG / MXU tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref,
                      y_ref, st_ref, clf_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)              # (Q, 1)
    B = b_ref[0, 0].astype(jnp.float32)                # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)                # (Q, N)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))      # (1, 1)

    dA = dt * a                                        # (Q, 1)
    dA_cum = jnp.cumsum(dA, axis=0)                    # (Q, 1)

    q = x.shape[0]
    seg = dA_cum - dA_cum.reshape(1, q)                # (Q, Q): cum_i - cum_j
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    w = scores * L * dt.reshape(1, q)                  # weight for column j
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,P)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    decay_e = jnp.exp(dA_cum[q - 1] - dA_cum)          # (Q,1)
    xw = x * (dt * decay_e)                            # (Q,P)
    st = jax.lax.dot_general(B, xw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (N,P)
    st_ref[0, 0] = st.astype(st_ref.dtype)
    clf_ref[...] = dA_cum[q - 1].reshape(1, 1).astype(clf_ref.dtype)


def ssd_chunk_pallas(x, dt, A_log, B, C, *, interpret: bool = False):
    """Intra-chunk SSD.

    x:  (b, nc, Q, h, p)   chunked per-head inputs
    dt: (b, nc, Q, h)
    A_log: (h,)
    B, C: (b, nc, Q, n)
    returns (y_diag: (b,nc,Q,h,p), states: (b,nc,h,n,p), chunk_lf: (b,nc,h))
    """
    b, nc, q, h, p = x.shape
    n = B.shape[-1]
    # dt blocked with trailing singleton head dim -> (Q, 1) tiles in VMEM
    al = A_log.reshape(h, 1, 1)

    grid = (b, nc, h)
    y, st, clf = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, 1), lambda bi, ci, hi: (hi, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, ci, hi: (bi, ci * h + hi, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, ci, hi: (bi, ci * h + hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, al, B, C)
    states = st.reshape(b, nc, h, n, p)
    chunk_lf = clf.reshape(b, nc, h)
    return y, states, chunk_lf
