"""Jit'd SSD scan: Pallas intra-chunk kernel + JAX inter-chunk recurrence."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_chunk_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 256,
             interpret: bool | None = None):
    """x: (b,s,h,p); dt: (b,s,h); A_log: (h,); B,C: (b,s,n) -> (b,s,h,p)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)

    # ---- intra-chunk (Pallas) ------------------------------------------
    y_diag, states, chunk_lf = ssd_chunk_pallas(
        xc, dtc, A_log.astype(jnp.float32), Bc, Cc, interpret=interpret)

    # ---- inter-chunk recurrence (JAX scan over nc states) ---------------
    chunk_decay = jnp.exp(chunk_lf)                       # (b,nc,h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit previous

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, prev = jax.lax.scan(step, init,
                           (states.transpose(1, 0, 2, 3, 4),
                            chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                   # (b,nc,h,n,p)

    # ---- inter-chunk contribution --------------------------------------
    dA = dtc * (-jnp.exp(A_log))[None, None, None, :]
    dA_cum = jnp.cumsum(dA, axis=2)
    state_decay = jnp.exp(dA_cum)                          # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, state_decay, prev)

    y = (y_diag + y_off).reshape(b, nc * q, h, p)
    return y[:, :s]
