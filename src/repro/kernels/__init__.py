"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with interpret fallback off-TPU) and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
