"""Pure-jnp oracle for flash attention (exact softmax attention)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    Materialises the full score matrix — correct but O(Sq*Skv) memory.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    groups = hq // hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
