"""Jit'd public wrapper for the flash-attention kernel.

Differentiable: forward runs the Pallas kernel; backward recomputes via the
blockwise-jnp formulation's VJP (flash-style recompute — no O(S^2) residual
is ever stored, matching the paper's ethos of trading recompute for
memory).  On non-TPU backends the kernel runs in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, block_q, block_kv):
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=not _on_tpu())


def _flash_fwd(q, k, v, causal, block_q, block_kv):
    out = _flash(q, k, v, causal, block_q, block_kv)
    return out, (q, k, v)


def _flash_bwd(causal, block_q, block_kv, res, do):
    q, k, v = res
    # recompute-based backward through the memory-efficient reference
    from repro.models.attention import blockwise_attention

    def f(q, k, v):
        # blockwise_attention expects (B, S, H, D)
        o = blockwise_attention(q.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=causal, block_q=block_q,
                                block_kv=block_kv)
        return o.transpose(0, 2, 1, 3)

    groups = q.shape[1] // k.shape[1]
    kk = jnp.repeat(k, groups, axis=1) if groups > 1 else k
    vv = jnp.repeat(v, groups, axis=1) if groups > 1 else v
    _, vjp = jax.vjp(f, q, kk, vv)
    dq, dk, dv = vjp(do)
    if groups > 1:
        b, hq, s, d = dk.shape
        dk = dk.reshape(b, k.shape[1], groups, s, d).sum(axis=2)
        dv = dv.reshape(b, v.shape[1], groups, s, d).sum(axis=2)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 1024):
    """Public API.  q/k/v: (B, S, H, D) layout (matching the model code);
    internally transposed to (B, H, S, D) for the kernel."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal, block_q, block_kv)
    return out.transpose(0, 2, 1, 3)
