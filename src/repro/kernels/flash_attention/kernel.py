"""Pallas TPU flash-attention (causal, GQA) — forward kernel.

Schedule: grid (batch*q_heads, num_q_blocks, num_kv_blocks) with KV
innermost; the accumulator, running max and running sum live in VMEM
scratch and persist across KV grid steps (TPU grids execute sequentially,
so scratch carries state — the canonical Pallas flash pattern).

BlockSpecs tile Q/K/V/O into VMEM:

    q block: (1, block_q,  head_dim)  — revisited for every kv step
    k block: (1, block_kv, head_dim)  — row index maps q-head -> kv-head
                                        (GQA without materialising repeats)
    v block: (1, block_kv, head_dim)
    o block: (1, block_q,  head_dim)  — written on the last kv step

VMEM working set = (2*block_q + 2*block_kv) * head_dim * bytes + fp32
scratch; with 512/1024 blocks and head_dim 128 bf16 that is ~0.9 MiB,
leaving headroom for double buffering.  All tile dims are multiples of 128
(MXU/VREG alignment).

Causality is handled at block granularity: fully-masked kv blocks are
skipped via ``pl.when`` (no MXU work), diagonal blocks apply the
elementwise mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      causal: bool, sm_scale: float, block_q: int,
                      block_kv: int, num_kv_blocks: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0].astype(jnp.float32)            # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bkv)

        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < seq_kv
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid = valid & (q_pos >= k_pos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                         # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip kv blocks strictly above the causal diagonal
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 512,
                        block_kv: int = 1024, interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires q_heads % kv_heads == 0"
    groups = hq // hkv
    sm_scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    nq = -(-sq // block_q)
    nkv = -(-skv // block_kv)
    pq, pkv = nq * block_q - sq, nkv * block_kv - skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))

    qf = q.reshape(b * hq, nq * block_q, d)
    kf = k.reshape(b * hkv, nkv * block_kv, d)
    vf = v.reshape(b * hkv, nkv * block_kv, d)

    def kv_row(i):
        return (i // hq) * hkv + (i % hq) // groups

    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv, seq_kv=skv)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda i, qi, ki: (kv_row(i), ki, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda i, qi, ki: (kv_row(i), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, nq * block_q, d)[:, :, :sq]
