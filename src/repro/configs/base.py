"""Architecture + run configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field semantics follow the assignment table."""

    name: str
    family: str                   # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0             # per-expert ffn width (0 -> d_ff)
    capacity_factor: float = 1.25

    # SSM (mamba2)
    moe_impl: str = "einsum"      # einsum (GShard one-hot) | gather (sort)

    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0            # mamba2 heads (0 -> d_inner // 64)

    # xLSTM
    slstm_every: int = 0          # 0 -> no sLSTM blocks; else every k-th block

    # Hybrid (zamba): shared attention block applied every k mamba blocks
    shared_attn_every: int = 0

    # Encoder-decoder (whisper): encoder config
    encoder_layers: int = 0
    encoder_seq: int = 0          # precomputed frame embeddings length (stub)

    # VLM: cross-attention every k layers; image token count (stub frontend)
    cross_attn_every: int = 0
    image_tokens: int = 0

    # Common
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # Attention implementation: naive | blockwise | pallas | skip (probe)
    attention_impl: str = "blockwise"
    # cost-probe differencing: bypass the SSD/mLSTM sequence mixer
    mixer_skip: bool = False
    # cost-probe differencing: bypass the MLP (fused-swiglu kernel cost
    # is added back analytically)
    mlp_skip: bool = False
    # cost-probe differencing: bypass the MoE expert FFN einsums only
    # (dispatch/combine kept; fused expert kernel cost added analytically)
    moe_ffn_skip: bool = False
    block_q: int = 512
    block_kv: int = 1024

    # Remat / memory planning.  ``offload`` enables the host-offload
    # eviction lane: budget-missing intermediates then get a joint
    # keep/recompute/offload decision priced by the hardware cost model
    # below (see repro.core.remat_policy.plan_joint_policy).
    remat: bool = True
    remat_budget_bytes: Optional[int] = None   # per-layer activation budget
    offload: bool = False
    dma_gbps: Optional[float] = None           # host-DMA GB/s (None = default)
    device_tflops: Optional[float] = None      # recompute TFLOP/s (None = default)

    # Parallelism
    pipeline_stages: int = 1

    # Cost-probe mode: python-unroll layer loops instead of lax.scan so
    # compiled.cost_analysis() counts every layer (XLA tallies while-loop
    # bodies once, which silently undercounts scanned stacks).
    unroll_layers: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(self.d_inner // 64, 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        else:
            mlp = 3 * d * self.d_ff if self.d_ff else 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            ssm = d * 2 * di + di * d + di * (self.ssm_state or 64) * 2
        if self.family == "ssm":  # xlstm mLSTM blocks
            di = 2 * d
            ssm = d * di * 3 + di * d
            mlp = 0
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            per_layer = ssm + 2 * d
        if self.family == "hybrid":
            # mamba blocks everywhere; shared attn counted once
            per_layer = ssm + 2 * d
            emb += attn + 3 * d * self.d_ff  # the single shared block
        total = emb + self.n_layers * per_layer
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 3 * d * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense_mlp = self.top_k * 3 * d * self.moe_d_ff + d * self.n_experts
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(emb + self.n_layers * (attn + dense_mlp + 2 * d))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what step is lowered at which size."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int = 0            # 0 -> no gradient accumulation

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, per the assignment's skip rules."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention (skip per assignment)")
    return True, ""
