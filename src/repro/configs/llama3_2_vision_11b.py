"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) d_ff 14336,
vocab 128256, cross-attn image layers every 5.  Vision encoder STUBBED:
image inputs are precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    image_tokens=1600,
)
