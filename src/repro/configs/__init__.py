"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from repro.configs.xlstm_1_3b import CONFIG as xlstm_1_3b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.minitron_4b import CONFIG as minitron_4b
from repro.configs.llama3_2_3b import CONFIG as llama3_2_3b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.llama3_2_vision_11b import CONFIG as llama3_2_vision_11b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b

ARCHS = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "xlstm-1.3b": xlstm_1_3b,
    "whisper-tiny": whisper_tiny,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "minitron-4b": minitron_4b,
    "llama3.2-3b": llama3_2_3b,
    "granite-34b": granite_34b,
    "llama-3.2-vision-11b": llama3_2_vision_11b,
    "zamba2-7b": zamba2_7b,
}

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable"]
