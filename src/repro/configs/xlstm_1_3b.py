"""xlstm-1.3b [ssm]: 48L d=2048 4H, sLSTM + mLSTM blocks, vocab 50304.
[arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,          # every 8th block is sLSTM (6 of 48)
)
