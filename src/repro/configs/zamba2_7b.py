"""zamba2-7b [hybrid]: 81L d=3584 32H (kv=32) d_ff 14336, vocab 32000,
ssm_state=64.  Mamba2 blocks + ONE shared attention block (E-mode weight
sharing) applied every 6 layers.  [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    shared_attn_every=6,
)
