"""whisper-tiny [audio]: 4L enc + 4L dec, d=384 6H, d_ff 1536, vocab 51865.
Conv frontend STUBBED: enc inputs are precomputed frame embeddings.
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    encoder_layers=4,
    encoder_seq=1500,
)
