"""Execution-order-driven host offload scheduling.

The NNTrainer paper's roadmap (§6): "Dynamic off-loading is expected to be
highly efficient because NNTrainer can predict and decide when a buffer is
accessed; thus, we can swap in and out proactively in background."  This
module realises that prediction on TPU: the execution-order analysis gives
every saved activation a write EO (producer forward) and a read EO (consumer
compute-gradient), so the *idle distance* between them is known statically.

Tensors whose idle distance exceeds a threshold — i.e. activations of early
layers in a deep stack, which sit untouched through the entire remaining
forward and most of the backward — are offloaded to host memory and
prefetched back ``prefetch_margin`` phases before their read.

On TPU this lowers to ``jax.checkpoint`` offload policies
(device->pinned-host copies overlapped with compute by XLA); the schedule
itself (what to offload, when to prefetch) is what the EO analysis decides.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.execution_order import OrderedTensors
from repro.core.lifespan import CreateMode


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    name: str
    nbytes: int
    write_eo: int
    read_eo: int
    prefetch_at_eo: int

    @property
    def idle_phases(self) -> int:
        return self.read_eo - self.write_eo


@dataclasses.dataclass
class OffloadSchedule:
    decisions: Tuple[OffloadDecision, ...]
    hbm_bytes_saved: int
    dma_bytes: int                      # total device<->host traffic (2x size)
    peak_inflight_prefetch: int

    def names(self) -> Tuple[str, ...]:
        return tuple(d.name for d in self.decisions)


def plan_offload(ordered: OrderedTensors, *, min_idle_phases: int = 4,
                 min_bytes: int = 1 << 20, prefetch_margin: int = 2,
                 hbm_budget_bytes: Optional[int] = None) -> OffloadSchedule:
    """Choose saved activations to offload based on EO idle distance.

    Only CREATE-owner activation tensors (``X:``) qualify — weights and
    derivatives have short or permanent residency.  Offload the largest,
    longest-idle tensors first until the HBM budget is met (or all
    candidates are taken when no budget is given).
    """
    candidates: List[OffloadDecision] = []
    for t in ordered.planned_tensors():
        if not t.name.startswith(("X:", "S:")):
            continue
        if len(t.exec_orders) < 2:
            continue
        write, read = t.min_eo, t.max_eo
        if read - write < min_idle_phases or t.nbytes < min_bytes:
            continue
        candidates.append(OffloadDecision(
            name=t.name, nbytes=t.nbytes, write_eo=write, read_eo=read,
            prefetch_at_eo=max(write, read - prefetch_margin),
        ))
    # biggest byte-phases product first: most HBM-seconds saved per DMA byte
    candidates.sort(key=lambda d: d.nbytes * d.idle_phases, reverse=True)

    chosen: List[OffloadDecision] = []
    saved = 0
    for d in candidates:
        chosen.append(d)
        saved += d.nbytes
        if hbm_budget_bytes is not None and saved >= hbm_budget_bytes:
            break

    # peak simultaneous prefetch traffic (for ICI/DMA contention estimates)
    peak = 0
    for d in chosen:
        inflight = sum(
            o.nbytes for o in chosen
            if o.prefetch_at_eo <= d.prefetch_at_eo <= o.read_eo
        )
        peak = max(peak, inflight)

    return OffloadSchedule(
        decisions=tuple(chosen),
        hbm_bytes_saved=saved,
        dma_bytes=2 * saved,
        peak_inflight_prefetch=peak,
    )


def offload_policy(names: Sequence[str]):
    """jax.checkpoint policy offloading the given names to host memory.

    Falls back to plain save when the offload policy is unavailable in the
    installed JAX (the schedule itself is produced regardless).
    """
    cp = jax.checkpoint_policies
    if hasattr(cp, "save_and_offload_only_these_names"):
        return cp.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=list(names),
            offload_src="device", offload_dst="pinned_host",
        )
    return cp.save_only_these_names(*names)
