"""Execution-order-driven proactive host swapping (NNTrainer §6).

The NNTrainer paper's roadmap (§6): "Dynamic off-loading is expected to be
highly efficient because NNTrainer can predict and decide when a buffer is
accessed; thus, we can swap in and out proactively in background."  This
module realises that prediction: the execution-order analysis gives every
saved activation its full access timeline, so the *idle window* — the widest
gap between consecutive accesses — is known statically.

Tensors whose idle window exceeds a threshold (activations of early layers
in a deep stack, which sit untouched through the remaining forward and most
of the backward) are swapped out to host memory right after their last
pre-gap access and prefetched back ``prefetch_margin`` phases before the
first post-gap access.

The schedule produced here is consumed in two places:

* :func:`repro.core.planner.plan_memory_swapped` — plans the device arena
  with swapped tensors *split* into two residency intervals (pre-swap and
  post-prefetch), so the vacated bytes are reusable by other tensors, plus
  a second host-pool arena for the offloaded copies packed by its own
  :class:`repro.core.planner.ArenaAllocator`.  The swap-aware placement
  pass there may lower a decision to an *in-place prefetch*
  (``OffloadDecision.inplace``): the packed arena kept its bytes untouched
  at a stable offset, so the swap moves no data at all;
* :func:`repro.core.plan.lower_schedule` — lowers the decisions (plus the
  compute phases and frees) into the flat, typed
  :class:`repro.core.plan.ExecutionSchedule` that the executor backends
  (:mod:`repro.core.exec.backends`: synchronous ``sim`` replay or the
  ``async`` device-stream backend) replay op by op, with HBM and
  host-pool high-water trackers proving the planned bounds are
  respected.

On TPU the same decisions lower to ``jax.checkpoint`` offload policies via
:func:`offload_policy` (device->pinned-host copies overlapped with compute
by XLA); see ``repro.core.remat_policy.RematPlan.offloaded``.

Knobs (all on :func:`plan_offload`):

``min_idle_phases``
    Minimum width (in EO phases) of the idle window for a tensor to be a
    swap candidate.  Swap-out occupies the phase right after the window
    opens and the prefetch occupies ``prefetch_margin`` phases before it
    closes, so windows narrower than ~3 phases cannot vacate any bytes.
``min_bytes``
    Minimum tensor size.  Small tensors cost a DMA descriptor each but
    reclaim little HBM; the default (1 MiB) matches the DMA-efficiency
    cliff observed on embedded DMA engines and TPU host transfers alike.
``prefetch_margin``
    How many phases before the post-gap access the prefetch is issued.
    Larger margins hide more DMA latency but re-occupy HBM earlier
    (shrinking the vacancy window) — this is the memory-vs-traffic knob
    swept by ``benchmarks/swap_bench.py``.
``hbm_budget_bytes``
    Stop choosing candidates once this many bytes have been reclaimed
    (None = take every candidate).  Candidates are ranked by
    ``nbytes * idle_phases`` (HBM-seconds reclaimed per DMA byte).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence, Tuple

import jax

from repro.core.execution_order import OrderedTensors


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    """One tensor's swap plan.

    ``write_eo`` is the last access *before* the idle window (not
    necessarily the producing write) and ``read_eo`` the first access after
    it; both are real accesses, so the device buffer must be resident at
    both.  Swap-out DMA runs during phase ``write_eo + 1``; the prefetch
    DMA starts at ``prefetch_at_eo`` and must complete by ``read_eo``.
    """

    name: str
    nbytes: int
    write_eo: int
    read_eo: int
    prefetch_at_eo: int
    # Set by the swap-aware placement pass (plan_memory_swapped): the packed
    # arena kept this tensor's bytes untouched at a stable offset through
    # the idle window, so re-residency needs no copy — the decision moves
    # no data (no host slot, no DMA) but keeps the planner's freedom to
    # reuse the bytes.  See SwapAwarePlan.inplace_prefetch_count.
    inplace: bool = False

    @property
    def idle_phases(self) -> int:
        return self.read_eo - self.write_eo

    @property
    def swap_out_eo(self) -> int:
        """Phase whose background DMA moves the tensor out (write_eo + 1)."""
        return self.write_eo + 1

    @property
    def vacates(self) -> bool:
        """True when the split actually frees bytes: the device residency
        intervals [.., write_eo+1] and [prefetch_at_eo, ..] are disjoint."""
        return self.prefetch_at_eo > self.write_eo + 1


@dataclasses.dataclass
class OffloadSchedule:
    decisions: Tuple[OffloadDecision, ...]
    # bytes moved off-device during their idle windows — an upper bound on
    # the arena reduction (the packed arena delta depends on what else can
    # occupy the vacated windows; see SwapAwarePlan.hbm_bytes_saved for the
    # realised number)
    hbm_bytes_saved: int
    dma_bytes: int                      # total device<->host traffic (2x size)
    peak_inflight_prefetch: int

    def names(self) -> Tuple[str, ...]:
        return tuple(d.name for d in self.decisions)

    def decision_for(self, name: str) -> Optional[OffloadDecision]:
        for d in self.decisions:
            if d.name == name:
                return d
        return None


def make_schedule(decisions: Sequence[OffloadDecision]) -> OffloadSchedule:
    """Build a consistent :class:`OffloadSchedule` from a decision set.

    Recomputes the aggregate fields (bytes saved, DMA traffic, peak inflight
    prefetch) so callers can restrict a schedule to a subset of decisions —
    the primitive the schedule/planner co-optimisation loop in
    :mod:`repro.core.plan` iterates on.  Non-vacating decisions are dropped,
    matching :func:`plan_offload`'s own filtering.  In-place decisions stay
    in the schedule (their residency split is part of the packed plan) but
    move no data, so they contribute to no aggregate.
    """
    chosen = tuple(d for d in decisions if d.vacates)
    moved = tuple(d for d in chosen if not d.inplace)
    saved = sum(d.nbytes for d in moved)
    peak = 0
    for d in moved:
        inflight = sum(
            o.nbytes for o in moved
            if o.prefetch_at_eo <= d.prefetch_at_eo <= o.read_eo
        )
        peak = max(peak, inflight)
    return OffloadSchedule(
        decisions=chosen,
        hbm_bytes_saved=saved,
        dma_bytes=2 * saved,
        peak_inflight_prefetch=peak,
    )


def plan_offload(ordered: OrderedTensors, *, min_idle_phases: int = 4,
                 min_bytes: int = 1 << 20, prefetch_margin: int = 2,
                 hbm_budget_bytes: Optional[int] = None) -> OffloadSchedule:
    """Choose saved activations to swap based on their widest EO idle gap.

    Only CREATE-owner activation tensors (``X:`` / ``S:``) qualify — weights
    and derivatives have short or permanent residency.  The idle window is
    the widest gap between *consecutive* accesses, so tensors re-read by
    their consumer's forward right after production are judged by the long
    forward->backward gap, not by ``max_eo - min_eo`` (which would let the
    swap race the consumer read).  Candidates are taken largest
    byte-phase-product first until the HBM budget is met.
    """
    candidates: List[OffloadDecision] = []
    for t in ordered.planned_tensors():
        if not t.name.startswith(("X:", "S:")):
            continue
        if len(t.exec_orders) < 2:
            continue
        write, read = t.largest_gap()
        if read - write < min_idle_phases or t.nbytes < min_bytes:
            continue
        d = OffloadDecision(
            name=t.name, nbytes=t.nbytes, write_eo=write, read_eo=read,
            prefetch_at_eo=max(write + 1, read - prefetch_margin),
        )
        if not d.vacates:
            # the prefetch would start before the swap-out DMA drains:
            # no bytes reclaimed, two transfers wasted — never schedule it
            # (and never count it toward savings or the HBM budget).
            continue
        candidates.append(d)
    # biggest byte-phases product first: most HBM-seconds saved per DMA byte
    candidates.sort(key=lambda d: d.nbytes * d.idle_phases, reverse=True)

    chosen: List[OffloadDecision] = []
    saved = 0
    for d in candidates:
        chosen.append(d)
        saved += d.nbytes
        if hbm_budget_bytes is not None and saved >= hbm_budget_bytes:
            break

    return make_schedule(chosen)


def offload_lowering() -> str:
    """How offload decisions lower on the installed JAX.

    ``"native"`` — ``save_and_offload_only_these_names`` exists, so
    offloaded intermediates really move to pinned host memory.
    ``"fallback_save"`` — the policy degrades to plain on-device saves:
    the plan's DMA prices are moot and the HBM budget WILL be exceeded by
    the offloaded bytes.  Recorded in ``CompiledMemoryPlan.report()`` so
    the degradation is visible, not silent.
    """
    return ("native"
            if hasattr(jax.checkpoint_policies,
                       "save_and_offload_only_these_names")
            else "fallback_save")


def offload_policy(names: Sequence[str], *, saved: Sequence[str] = ()):
    """jax.checkpoint policy offloading ``names`` to host memory.

    ``saved`` names are kept on device (no offload, no recompute) — the
    remat planner's on-device keep set.  Falls back to plain save when the
    offload policy is unavailable in the installed JAX; the fallback keeps
    the offloaded names *resident*, so it warns that the planned HBM budget
    no longer holds (see :func:`offload_lowering`).
    """
    cp = jax.checkpoint_policies
    if offload_lowering() == "native":
        return cp.save_and_offload_only_these_names(
            names_which_can_be_saved=list(saved),
            names_which_can_be_offloaded=list(names),
            offload_src="device", offload_dst="pinned_host",
        )
    warnings.warn(
        "jax.checkpoint_policies.save_and_offload_only_these_names is "
        "unavailable in this JAX: offload decisions lower to plain saves, "
        "so the offloaded intermediates stay resident and the planned HBM "
        "budget will be exceeded (report()['offload_lowering'] == "
        "'fallback_save')", RuntimeWarning, stacklevel=2)
    return cp.save_only_these_names(*list(saved) + list(names))
