"""Tensor lifespan and create-mode taxonomy (NNTrainer §4.1, Tables 2 & 3).

The paper's central abstraction: every tensor a layer requests is annotated
with a *lifespan* (during which training sub-processes it must stay valid)
and a *create mode* (how its storage relates to other tensors).  Execution
orders (EOs) are derived from these annotations (Algorithm 1) and the memory
planner (Algorithm 2) assigns arena offsets so that tensors with disjoint
EO intervals share storage.

Training is decomposed into three phases per layer (the paper's
layer-operation basis):

    F   forward
    CG  compute gradient  (dW from dY and saved X)
    CD  compute derivative (dX from dY and W)  -- includes apply-gradient

Lifespans map to subsets of those phases; create modes describe sharing:

    P   place-holder: storage owned externally (model inputs, labels)
    C   create: fresh allocation from the arena
    MV  modify-view: shares memory with a target tensor, data changes
        (in-place ops: activations, batch-norm)
    RV  read-only view: shares memory, data guaranteed unchanged
        (flatten / reshape)
    E   extend: shares *both* spec and data (time-unrolled weights)
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class Lifespan(enum.Enum):
    """When a tensor must be resident (Table 2)."""

    FORWARD = "F"                 # forward only
    CALC_GRAD = "CG"              # compute-gradient only
    CALC_DERIV = "CD"             # compute-derivative only
    FORWARD_GRAD = "F_CG"         # forward + compute-gradient (saved activations)
    FORWARD_DERIV = "F_CD"        # forward + compute-derivative
    BACKWARD = "B"                # compute-gradient + compute-derivative
    FORWARD_BACKWARD = "F_B"      # everything within the layer
    ITERATION = "I"               # valid for a whole iteration, reset after
    MAX = "M"                     # always valid (weights)

    @property
    def spans_forward(self) -> bool:
        return self in (
            Lifespan.FORWARD,
            Lifespan.FORWARD_GRAD,
            Lifespan.FORWARD_DERIV,
            Lifespan.FORWARD_BACKWARD,
            Lifespan.ITERATION,
            Lifespan.MAX,
        )

    @property
    def spans_calc_grad(self) -> bool:
        return self in (
            Lifespan.CALC_GRAD,
            Lifespan.FORWARD_GRAD,
            Lifespan.BACKWARD,
            Lifespan.FORWARD_BACKWARD,
            Lifespan.ITERATION,
            Lifespan.MAX,
        )

    @property
    def spans_calc_deriv(self) -> bool:
        return self in (
            Lifespan.CALC_DERIV,
            Lifespan.FORWARD_DERIV,
            Lifespan.BACKWARD,
            Lifespan.FORWARD_BACKWARD,
            Lifespan.ITERATION,
            Lifespan.MAX,
        )


class CreateMode(enum.Enum):
    """How a tensor's storage is created / shared (Table 3)."""

    PLACEHOLDER = "P"    # external memory, not planned by the arena
    CREATE = "C"         # new allocation
    MODIFY_VIEW = "MV"   # memory sharing, data changes
    READONLY_VIEW = "RV" # memory sharing, data does not change
    EXTEND = "E"         # tensor sharing: spec AND data shared


_DTYPE_BYTES = {
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "float64": 8,
    "int32": 4,
    "int64": 8,
    "int8": 1,
    "uint8": 1,
    "bool": 1,
}


def dtype_bytes(dtype: str) -> int:
    try:
        return _DTYPE_BYTES[str(dtype)]
    except KeyError as exc:
        raise ValueError(f"unknown dtype {dtype!r}") from exc


@dataclasses.dataclass
class TensorSpec:
    """Specification of a requested tensor, separate from its data.

    Mirrors NNTrainer's Tensor-Pool entries: the spec (shape/dtype/lifespan/
    create-mode) exists from *Initialize* onwards, while actual storage is
    assigned only once the Memory Planner has computed offsets.
    """

    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"
    lifespan: Lifespan = Lifespan.FORWARD
    create_mode: CreateMode = CreateMode.CREATE
    # For MV/RV/E tensors: the name of the target tensor whose storage we
    # try to share.  The merge rules of Algorithm 1 decide whether sharing
    # is legal given both tensors' execution orders.
    view_of: Optional[str] = None
    # Execution orders assigned by Algorithm 1 (sorted ascending).
    exec_orders: Tuple[int, ...] = ()
    # Arena placement assigned by Algorithm 2 (byte offset), or None if the
    # tensor was merged into another / is a placeholder.
    offset: Optional[int] = None
    # If merged, the name of the tensor that owns the storage.
    merged_into: Optional[str] = None

    @property
    def nelems(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.nelems * dtype_bytes(self.dtype)

    @property
    def is_planned(self) -> bool:
        """True if this tensor receives its own arena storage."""
        return (
            self.create_mode in (CreateMode.CREATE,)
            and self.merged_into is None
        )

    def add_orders(self, orders) -> None:
        self.exec_orders = tuple(sorted(set(self.exec_orders) | set(orders)))

    @property
    def min_eo(self) -> int:
        if not self.exec_orders:
            raise ValueError(f"tensor {self.name} has no execution orders")
        return self.exec_orders[0]

    @property
    def max_eo(self) -> int:
        if not self.exec_orders:
            raise ValueError(f"tensor {self.name} has no execution orders")
        return self.exec_orders[-1]

    def largest_gap(self) -> Tuple[int, int]:
        """(last-access-before, first-access-after) of the widest idle window.

        The widest gap between *consecutive* accesses is the only interval in
        which the tensor can safely vacate its storage: min/max EO alone
        overstate idleness whenever intermediate accesses exist (e.g. a saved
        activation read by its consumer's forward right after being written).
        Returns ``(min_eo, min_eo)`` for tensors with a single access.
        """
        if not self.exec_orders:
            raise ValueError(f"tensor {self.name} has no execution orders")
        best = (self.exec_orders[0], self.exec_orders[0])
        for a, b in zip(self.exec_orders, self.exec_orders[1:]):
            if b - a > best[1] - best[0]:
                best = (a, b)
        return best


def kib(nbytes: int) -> float:
    return nbytes / 1024.0


def mib(nbytes: int) -> float:
    return nbytes / (1024.0 * 1024.0)
