"""Graph constructors for the paper's evaluation models (Table 4, Fig. 12/14).

All dimensions follow the paper where specified.  Hidden sizes of Models
A/B/D are not given in the paper; we use 64/128 chosen to match the
published ideal-memory numbers within <0.5% (see EXPERIMENTS.md §Table4).
"""

from __future__ import annotations

from typing import List

from repro.core.graph import LayerGraph, LayerNode, compile_graph

# Paper's component inputs: 64:1:1:150528 (linear/lstm), 64:3:224:224 (conv)
LINEAR_IN = 150528
IMG_IN = (3, 224, 224)


def _g(layers: List[LayerNode], input_shape, label_shape, name: str,
       **compile_kw) -> LayerGraph:
    return compile_graph(LayerGraph(layers, tuple(input_shape), tuple(label_shape),
                                    name), **compile_kw)


# ---------------------------------------------------------------------------
# Table 4 component test cases
# ---------------------------------------------------------------------------

def single_linear() -> LayerGraph:
    """Linear: 64:1:1:150528 -> 64:1:1:10, MSE."""
    return _g([
        LayerNode("fc0", "linear", ["__input__"],
                  {"in_features": LINEAR_IN, "out_features": 10, "bias": False}),
        LayerNode("loss", "loss_mse", ["fc0"]),
    ], (LINEAR_IN,), (10,), "single_linear")


def single_conv2d() -> LayerGraph:
    """Conv2D: 64:3:224:224 -> 64:3:112:112 (3 filters 3x3, stride 2), MSE."""
    return _g([
        LayerNode("conv0", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False}),
        LayerNode("loss", "loss_mse", ["conv0"]),
    ], IMG_IN, (3, 112, 112), "single_conv2d")


def single_lstm() -> LayerGraph:
    """LSTM: 64:1:1:150528 -> 64:1:1:10 (single step, hidden=10), MSE."""
    return _g([
        LayerNode("lstm0", "lstm", ["__input__"],
                  {"in_features": LINEAR_IN, "hidden": 10, "seq_len": 1}),
        LayerNode("loss", "loss_mse", ["lstm0"]),
    ], (LINEAR_IN,), (10,), "single_lstm")


def model_a(variant: str = "linear") -> LayerGraph:
    """Model A (Fig. 1/4): three weighted layers, no in-place ops."""
    if variant == "linear":
        d1, d2 = 128, 128
        layers = [
            LayerNode("fc0", "linear", ["__input__"],
                      {"in_features": LINEAR_IN, "out_features": d1, "bias": False}),
            LayerNode("fc1", "linear", ["fc0"],
                      {"in_features": d1, "out_features": d2, "bias": False}),
            LayerNode("fc2", "linear", ["fc1"],
                      {"in_features": d2, "out_features": 10, "bias": False}),
            LayerNode("loss", "loss_mse", ["fc2"]),
        ]
        return _g(layers, (LINEAR_IN,), (10,), "model_a_linear")
    # conv variant: 3 stride-2 convs 224 -> 112 -> 56 -> 28
    layers = [
        LayerNode("conv0", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False}),
        LayerNode("conv1", "conv2d", ["conv0"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False}),
        LayerNode("conv2", "conv2d", ["conv1"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False}),
        LayerNode("loss", "loss_mse", ["conv2"]),
    ]
    return _g(layers, IMG_IN, (3, 28, 28), "model_a_conv2d")


def model_b(variant: str = "linear") -> LayerGraph:
    """Model B (Fig. 5): weighted -> in-place activation -> weighted."""
    if variant == "linear":
        d = 64
        layers = [
            LayerNode("fc0", "linear", ["__input__"],
                      {"in_features": LINEAR_IN, "out_features": d, "bias": False,
                       "activation": "sigmoid"}),
            LayerNode("fc1", "linear", ["fc0"],
                      {"in_features": d, "out_features": 10, "bias": False}),
            LayerNode("loss", "loss_mse", ["fc1"]),
        ]
        return _g(layers, (LINEAR_IN,), (10,), "model_b_linear")
    layers = [
        LayerNode("conv0", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False, "activation": "sigmoid"}),
        LayerNode("conv1", "conv2d", ["conv0"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False}),
        LayerNode("loss", "loss_mse", ["conv1"]),
    ]
    return _g(layers, IMG_IN, (3, 56, 56), "model_b_conv2d")


def model_c(variant: str = "linear") -> LayerGraph:
    """Model C (Fig. 6): weighted -> activation (in-place) -> flatten (RV)."""
    if variant == "linear":
        layers = [
            LayerNode("fc0", "linear", ["__input__"],
                      {"in_features": LINEAR_IN, "out_features": 10, "bias": False,
                       "activation": "sigmoid"}),
            LayerNode("flat", "flatten", ["fc0"]),
            LayerNode("loss", "loss_mse", ["flat"]),
        ]
        return _g(layers, (LINEAR_IN,), (10,), "model_c_linear")
    layers = [
        LayerNode("conv0", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 3, "ksize": 3, "stride": 2,
                   "padding": "same", "bias": False, "activation": "sigmoid"}),
        LayerNode("flat", "flatten", ["conv0"]),
        LayerNode("loss", "loss_mse", ["flat"]),
    ]
    return _g(layers, IMG_IN, (37632,), "model_c_conv2d")


def model_d() -> LayerGraph:
    """Model D (§5.1): input -> multi-out -> two activation branches ->
    addition -> linear -> loss."""
    layers = [
        LayerNode("mo", "multiout", ["__input__"]),
        LayerNode("act_a", "activation", ["mo"], {"fn": "sigmoid"}),
        LayerNode("act_b", "activation", ["mo"], {"fn": "tanh"}),
        LayerNode("add0", "add", ["act_a", "act_b"]),
        LayerNode("fc", "linear", ["add0"],
                  {"in_features": LINEAR_IN, "out_features": 10, "bias": False}),
        LayerNode("loss", "loss_mse", ["fc"]),
    ]
    return _g(layers, (LINEAR_IN,), (10,), "model_d")


# ---------------------------------------------------------------------------
# Fig. 12 application models (CIFAR-like 32x32x3 input, 10/100 classes)
# ---------------------------------------------------------------------------

def lenet5(num_classes: int = 10) -> LayerGraph:
    layers = [
        LayerNode("c1", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 6, "ksize": 5, "stride": 1,
                   "padding": "same", "activation": "tanh"}),
        LayerNode("p1", "pool2d", ["c1"], {"ksize": 2, "stride": 2}),
        LayerNode("c2", "conv2d", ["p1"],
                  {"in_ch": 6, "out_ch": 16, "ksize": 5, "stride": 1,
                   "padding": "valid", "activation": "tanh"}),
        LayerNode("p2", "pool2d", ["c2"], {"ksize": 2, "stride": 2}),
        LayerNode("f5", "linear", ["p2"],
                  {"in_features": 16 * 6 * 6, "out_features": 120,
                   "activation": "tanh"}),
        LayerNode("f6", "linear", ["f5"],
                  {"in_features": 120, "out_features": 84, "activation": "tanh"}),
        LayerNode("f7", "linear", ["f6"],
                  {"in_features": 84, "out_features": num_classes,
                   "activation": "softmax"}),
        LayerNode("loss", "loss_ce", ["f7"]),
    ]
    return _g(layers, (3, 32, 32), (num_classes,), "lenet5")


def _vgg_block(name: str, in_ch: int, out_ch: int, convs: int,
               prev: str) -> List[LayerNode]:
    out: List[LayerNode] = []
    for i in range(convs):
        out.append(LayerNode(
            f"{name}_c{i}", "conv2d", [prev],
            {"in_ch": in_ch if i == 0 else out_ch, "out_ch": out_ch,
             "ksize": 3, "stride": 1, "padding": "same", "activation": "relu"}))
        prev = f"{name}_c{i}"
    out.append(LayerNode(f"{name}_p", "pool2d", [prev], {"ksize": 2, "stride": 2}))
    return out


def vgg16(num_classes: int = 10) -> LayerGraph:
    layers: List[LayerNode] = []
    prev = "__input__"
    for bi, (cin, cout, n) in enumerate(
            [(3, 64, 2), (64, 128, 2), (128, 256, 3), (256, 512, 3), (512, 512, 3)]):
        blk = _vgg_block(f"b{bi}", cin, cout, n, prev)
        layers.extend(blk)
        prev = blk[-1].name
    layers += [
        LayerNode("fc0", "linear", [prev],
                  {"in_features": 512, "out_features": 512, "activation": "relu"}),
        LayerNode("fc1", "linear", ["fc0"],
                  {"in_features": 512, "out_features": num_classes,
                   "activation": "softmax"}),
        LayerNode("loss", "loss_ce", ["fc1"]),
    ]
    return _g(layers, (3, 32, 32), (num_classes,), "vgg16")


def _res_block(name: str, in_ch: int, out_ch: int, stride: int,
               prev: str) -> List[LayerNode]:
    out = [
        LayerNode(f"{name}_c0", "conv2d", [prev],
                  {"in_ch": in_ch, "out_ch": out_ch, "ksize": 3, "stride": stride,
                   "padding": "same", "activation": "relu"}),
        LayerNode(f"{name}_c1", "conv2d", [f"{name}_c0"],
                  {"in_ch": out_ch, "out_ch": out_ch, "ksize": 3, "stride": 1,
                   "padding": "same"}),
    ]
    if stride != 1 or in_ch != out_ch:
        out.append(LayerNode(f"{name}_sc", "conv2d", [prev],
                             {"in_ch": in_ch, "out_ch": out_ch, "ksize": 1,
                              "stride": stride, "padding": "same"}))
        skip = f"{name}_sc"
    else:
        skip = prev
    out.append(LayerNode(f"{name}_add", "add", [f"{name}_c1", skip],
                         {"activation": "relu"}))
    return out


def resnet18(num_classes: int = 10) -> LayerGraph:
    layers: List[LayerNode] = [
        LayerNode("stem", "conv2d", ["__input__"],
                  {"in_ch": 3, "out_ch": 64, "ksize": 3, "stride": 1,
                   "padding": "same", "activation": "relu"}),
    ]
    prev = "stem"
    cfg = [(64, 64, 1), (64, 64, 1), (64, 128, 2), (128, 128, 1),
           (128, 256, 2), (256, 256, 1), (256, 512, 2), (512, 512, 1)]
    for i, (cin, cout, s) in enumerate(cfg):
        blk = _res_block(f"r{i}", cin, cout, s, prev)
        layers.extend(blk)
        prev = blk[-1].name
    layers += [
        LayerNode("gap", "pool2d", [prev], {"ksize": 4, "stride": 4}),
        LayerNode("fc", "linear", ["gap"],
                  {"in_features": 512, "out_features": num_classes,
                   "activation": "softmax"}),
        LayerNode("loss", "loss_ce", ["fc"]),
    ]
    return _g(layers, (3, 32, 32), (num_classes,), "resnet18")


def resnet18_transfer(num_classes: int = 10) -> LayerGraph:
    """Fig. 12 'Transfer': ResNet18 backbone frozen, classifier trainable."""
    g = resnet18(num_classes)
    from repro.core.graph import slice_realizer
    return slice_realizer(g, freeze_until="gap")


def product_rating(num_users: int = 6040, num_items: int = 193610,
                   dim: int = 64) -> LayerGraph:
    """Fig. 12 'Rating': NCF-style — embeddings -> concat -> 3 linear (§5.2)."""
    layers = [
        LayerNode("emb_u", "embedding", ["__input__"],
                  {"vocab": num_users, "dim": dim}),
        LayerNode("emb_i", "embedding", ["__input__"],
                  {"vocab": num_items, "dim": dim}),
        LayerNode("cat", "concat", ["emb_u", "emb_i"], {"axis": -1}),
        LayerNode("fc0", "linear", ["cat"],
                  {"in_features": 2 * dim, "out_features": 128, "activation": "relu"}),
        LayerNode("fc1", "linear", ["fc0"],
                  {"in_features": 128, "out_features": 64, "activation": "relu"}),
        LayerNode("fc2", "linear", ["fc1"], {"in_features": 64, "out_features": 1}),
        LayerNode("loss", "loss_mse", ["fc2"]),
    ]
    return _g(layers, (1,), (1,), "product_rating")


# ---------------------------------------------------------------------------
# Fig. 14: Tacotron2-style decoder (prenet + 2 LSTM + projections + postnet)
# ---------------------------------------------------------------------------

def tacotron2_decoder(time_steps: int = 8, mel_dim: int = 80,
                      prenet_dim: int = 256, lstm_dim: int = 256) -> LayerGraph:
    """Time-unrolled LSTM decoder with E-shared weights (§5.2).

    The recurrent section (prenet->lstm->lstm->proj) is unrolled
    ``time_steps`` times by the Recurrent realizer; weights are shared via
    CreateMode.EXTEND and gradients accumulate with Iteration lifespan.
    """
    # E-shared unrolled copies require in_features == hidden for the
    # self-feeding LSTM chain (weight shapes must match across copies)
    assert prenet_dim == lstm_dim, "unrolled LSTM needs prenet_dim == lstm_dim"
    layers = [
        LayerNode("prenet0", "linear", ["__input__"],
                  {"in_features": mel_dim, "out_features": prenet_dim,
                   "activation": "relu"}),
        LayerNode("prenet1", "linear", ["prenet0"],
                  {"in_features": prenet_dim, "out_features": prenet_dim,
                   "activation": "relu"}),
        LayerNode("lstm0", "lstm", ["prenet1"],
                  {"in_features": prenet_dim, "hidden": lstm_dim, "seq_len": 1,
                   "accumulate_grad": True}),
        LayerNode("lstm1", "lstm", ["lstm0"],
                  {"in_features": lstm_dim, "hidden": lstm_dim, "seq_len": 1,
                   "accumulate_grad": True}),
        LayerNode("proj_mel", "linear", ["lstm1"],
                  {"in_features": lstm_dim, "out_features": mel_dim,
                   "accumulate_grad": True}),
        LayerNode("loss", "loss_mse", ["proj_mel"]),
    ]
    return _g(layers, (mel_dim,), (mel_dim,), "tacotron2_decoder",
              unroll={"lstm0": time_steps, "lstm1": time_steps})


def transformer_mlp_stack(n_layers: int = 28, d_model: int = 3072,
                          d_ff: int = 8192) -> LayerGraph:
    """The llama3.2-3b MLP trunk as a layer graph: 28 x (up-proj 3072->8192,
    activation, down-proj 8192->3072), MSE head.

    The dependence analyser's scaling benchmark: per-op Python dispatch
    costs grow with the 3N phase count (28 layers -> hundreds of lowered
    ops) while the fusion prover should collapse the op list into a few
    dozen jit blocks.  Not in the ZOO dict — attention/GQA are absent, so
    it is a dispatch-count workload, not an accuracy workload."""
    layers: List[LayerNode] = []
    prev = "__input__"
    for i in range(n_layers):
        up, down = f"l{i}_up", f"l{i}_down"
        layers += [
            LayerNode(up, "linear", [prev],
                      {"in_features": d_model, "out_features": d_ff,
                       "bias": False, "activation": "relu"}),
            LayerNode(down, "linear", [up],
                      {"in_features": d_ff, "out_features": d_model,
                       "bias": False}),
        ]
        prev = down
    layers.append(LayerNode("loss", "loss_mse", [prev]))
    return _g(layers, (d_model,), (d_model,),
              f"transformer_mlp_stack_{n_layers}l")


ZOO = {
    "linear": single_linear,
    "conv2d": single_conv2d,
    "lstm": single_lstm,
    "model_a_linear": lambda: model_a("linear"),
    "model_a_conv2d": lambda: model_a("conv2d"),
    "model_b_linear": lambda: model_b("linear"),
    "model_b_conv2d": lambda: model_b("conv2d"),
    "model_c_linear": lambda: model_c("linear"),
    "model_c_conv2d": lambda: model_c("conv2d"),
    "model_d": model_d,
    "lenet5": lenet5,
    "vgg16": vgg16,
    "resnet18": resnet18,
    "resnet18_transfer": resnet18_transfer,
    "product_rating": product_rating,
    "tacotron2_decoder": tacotron2_decoder,
}
