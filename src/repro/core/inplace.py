"""In-place activation calculus (NNTrainer §3, Fig. 1(c) "InplaceOp").

The paper's key observation: for sigmoid, ``dX = dY * Y * (1 - Y)`` — the
derivative needs the *output*, not the input.  Storing only the output (and
letting the input's buffer be reused) halves intermediate-activation memory
for the conv->act / linear->act pattern that dominates real models.

Each activation here provides:
  * ``fwd(x)``            — forward
  * ``deriv_from_out(y)`` — d(act)/dx expressed in terms of y = act(x)

and ``make_inplace_act(fn)`` wraps them in a ``jax.custom_vjp`` whose
residual is the OUTPUT.  Under ``jax.grad`` this changes which buffer XLA
must keep alive across the backward pass — the JAX realisation of the
paper's in-place optimisation (validated in tests against standard autodiff
to 1e-6 and in benchmarks via ``compiled.memory_analysis()``).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def _sigmoid_fwd(x):
    return jax.nn.sigmoid(x)


def _sigmoid_deriv(y):
    return y * (1.0 - y)


def _tanh_fwd(x):
    return jnp.tanh(x)


def _tanh_deriv(y):
    return 1.0 - y * y


def _relu_fwd(x):
    return jnp.maximum(x, 0.0)


def _relu_deriv(y):
    # y > 0 exactly where x > 0 (ties at 0 have zero derivative anyway)
    return (y > 0.0).astype(y.dtype)


def _softmax_fwd(x):
    return jax.nn.softmax(x, axis=-1)


def _softmax_vjp_from_out(y, dy):
    # dX = y * (dy - sum(dy * y, axis=-1, keepdims=True))
    return y * (dy - jnp.sum(dy * y, axis=-1, keepdims=True))


_ELEMENTWISE: Dict[str, Tuple[Callable, Callable]] = {
    "sigmoid": (_sigmoid_fwd, _sigmoid_deriv),
    "tanh": (_tanh_fwd, _tanh_deriv),
    "relu": (_relu_fwd, _relu_deriv),
}


def deriv_from_output(fn: str, y, dy):
    """dLoss/dX given the activation *output* y and upstream derivative dy."""
    if fn == "softmax":
        return _softmax_vjp_from_out(y, dy)
    fwd, deriv = _ELEMENTWISE[fn]
    return dy * deriv(y)


def apply_activation(fn: str, x):
    if fn == "softmax":
        return _softmax_fwd(x)
    return _ELEMENTWISE[fn][0](x)


def make_inplace_act(fn: str):
    """An activation whose VJP residual is its OUTPUT (not input).

    Standard ``jax.nn.sigmoid`` under autodiff keeps the *input* alive for
    the backward pass; this version keeps the output instead, allowing XLA
    to reuse the input's buffer — NNTrainer's MV in-place merge.
    """

    @jax.custom_vjp
    def act(x):
        return apply_activation(fn, x)

    def act_fwd(x):
        y = apply_activation(fn, x)
        return y, y  # residual = output only

    def act_bwd(y, dy):
        return (deriv_from_output(fn, y, dy),)

    act.defvjp(act_fwd, act_bwd)
    return act


# Ready-made in-place activations.
sigmoid = make_inplace_act("sigmoid")
tanh = make_inplace_act("tanh")
relu = make_inplace_act("relu")
softmax = make_inplace_act("softmax")


def make_inplace_batchnorm():
    """Batch-norm whose backward uses the normalised output (paper §3:
    'this is applied to batch normalization as well').

    For y = gamma * xhat + beta, the backward reconstructs
    xhat = (y - beta) / gamma and never needs x:
        dxhat = dy * gamma
        dx    = (1/N) * inv_std * (N*dxhat - sum(dxhat) - xhat*sum(dxhat*xhat))
        dgamma = sum(dy * xhat); dbeta = sum(dy)
    Residuals: output y, gamma, beta, inv_std — all O(C) except y (which is
    the tensor the in-place merge shares with the input).
    """

    @jax.custom_vjp
    def bn(x, gamma, beta, eps=1e-5):
        mean = jnp.mean(x, axis=0, keepdims=True)
        var = jnp.var(x, axis=0, keepdims=True)
        inv_std = jax.lax.rsqrt(var + eps)
        return gamma * (x - mean) * inv_std + beta

    def bn_fwd(x, gamma, beta, eps=1e-5):
        mean = jnp.mean(x, axis=0, keepdims=True)
        var = jnp.var(x, axis=0, keepdims=True)
        inv_std = jax.lax.rsqrt(var + eps)
        y = gamma * (x - mean) * inv_std + beta
        return y, (y, gamma, beta, inv_std)

    def bn_bwd(res, dy):
        y, gamma, beta, inv_std = res
        n = y.shape[0]
        xhat = (y - beta) / jnp.where(gamma == 0, 1.0, gamma)
        dxhat = dy * gamma
        sum_dxhat = jnp.sum(dxhat, axis=0, keepdims=True)
        sum_dxhat_xhat = jnp.sum(dxhat * xhat, axis=0, keepdims=True)
        dx = (inv_std / n) * (n * dxhat - sum_dxhat - xhat * sum_dxhat_xhat)
        dgamma = jnp.sum(dy * xhat, axis=0)
        dbeta = jnp.sum(dy, axis=0)
        return dx, dgamma, dbeta, None

    bn.defvjp(bn_fwd, bn_bwd)
    return bn


batchnorm = make_inplace_batchnorm()
