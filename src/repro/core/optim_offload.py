"""Optimizer-state offload: plan AdamW moments as first-class arena slots.

The paper's small-batch personalization regime makes Adam's optimizer
state — two fp32 moments, 2x the parameter bytes — the dominant device
tenant, not activations.  This module extends the memory plan to cover it,
in the mold of 8-bit Adam and the 256KB-tier on-device training line of
work (PAPERS.md): per-layer optimizer slots become planned tensors with
their own execution-order windows, packed device/host arenas and typed
schedule ops.

Per trainable weighted layer ``<l>`` one slot ``O:<l>`` holds the layer's
flattened ``m || v`` fp32 moments (``2 * weight_nbytes``).  The slot is
only needed around the layer's compute-gradient phase (the AdamW update
reads and writes the moments there), so its *device* residency is the
short window ``[CG - prefetch_margin, CG + 1]`` — packed by the regular
interval planners into a working region a fraction of the all-resident
footprint.  Between updates the state lives in a host pool as an int8
block-scaled copy (``optim/compression.py``'s ``_q``/``_deq`` geometry:
one fp32 absmax scale per :data:`CBLOCK` elements, ~3.94x under fp32).

Lowering emits one :class:`repro.core.plan.OptPrefetch` (compressed host
copy -> fp32 working buffer, ready by the CG update) and one
:class:`repro.core.plan.OptSwapOut` (updated state back to the host slot,
re-quantized with error feedback) per slot; both executor backends replay
them and account them in ``SwapExecStats`` (``opt_*`` counters).

The ``m`` half quantizes linearly; the ``v`` half quantizes in log space
(8-bit-Adam style dynamic-range compression).  ``v`` spans many orders of
magnitude inside one 256-element block — linear (or even sqrt-space) int8
collapses small-``v`` elements to zero, turning the Adam denominator into
``eps`` and exploding that update ~1e8x.  In log space the int8 grid error
becomes a bounded *multiplicative* error on ``sqrt(v)`` (~e^(absmax/254)
per element, a few percent), so the denominator can never collapse and
the per-step update error stays a small fraction of ``lr``.

Error feedback keeps updates unbiased over time: the host re-quantization
of the swapped-out state carries its (encoded-space) rounding error into
the *next* quantization (``total = enc(state) + residual; residual =
total - deq(q)``).
The fp32 residual is host-persistent and never crosses the bus — DMA
carries only the compressed payload H2D and the fp32 working state D2H —
so it is reported separately (``ef_residual_host_bytes``) and NOT counted
against the packed host pool, which holds only the DMA-addressable
compressed copies.

:class:`OptimRuntime` / :func:`offloaded_update` realise the host side of
the dance numerically: per-layer AdamW updates (same math and defaults as
``optim/optimizers.py:adamw``) against dequantized prefetched state, with
EF re-quantization on swap-out.  With ``optim_compress=False`` the host
copies are exact fp32 and the update matches the resident reference
bit-for-bit (modulo float noise); with compression it matches within the
established error-feedback tolerance (BENCH row ``optim_offload``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

from repro.core.execution_order import OrderedTensors
from repro.core.graph import WEIGHTED_KINDS, LayerGraph
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.planner import Plan, _SpecSet, _align, get_planner

_HOST = "@host"

# compression geometry mirrors optim/compression.py: int8 payload plus one
# fp32 absmax scale per CBLOCK elements
CBLOCK = 256

# pricing defaults mirror the remat_policy cost model's documented
# fallbacks (MemoryPlanConfig.dma_gbps / device_tflops override them)
_DEFAULT_DMA_GBPS = 32.0
_DEFAULT_DEVICE_TFLOPS = 200.0
# quantize (absmax reduction, scale divide, round/clip) + dequantize
# (multiply) per element, both directions of one step
_COMPRESS_FLOPS_PER_ELEM = 6


def compressed_nbytes(n_elems: int) -> int:
    """Host bytes for an int8 block-scaled copy of ``n_elems`` fp32 values."""
    return n_elems + 4 * (-(-n_elems // CBLOCK))


@dataclasses.dataclass(frozen=True)
class OptimSlot:
    """One layer's planned optimizer state (flattened ``m || v``, fp32)."""

    layer: str
    name: str                # "O:<layer>"
    n_elems: int             # 2 * weight elements (m and v)
    nbytes: int              # fp32 working-buffer bytes (n_elems * 4)
    host_nbytes: int         # compressed host-copy bytes (== nbytes uncompressed)
    prefetch_eo: int         # H2D issue phase (CG - prefetch_margin)
    read_eo: int             # the layer's CG phase: the update reads here
    swapout_eo: int          # CG + 1: updated state drains back to host

    @property
    def dma_bytes(self) -> int:
        """Bus traffic per step: fp32 state D2H + compressed copy H2D."""
        return self.nbytes + self.host_nbytes


@dataclasses.dataclass
class OptimPlan:
    """Packed optimizer-state offload plan, attached to the memory plan.

    ``device`` packs the fp32 working buffers over their short per-layer
    CG windows (a separate region — nothing here aliases the activation
    arena); ``host`` packs the persistent compressed copies (keyed
    ``<slot>@host``).  ``resident_bytes`` is the all-resident baseline the
    reduction claim is measured against: every slot live simultaneously,
    same alignment.
    """

    slots: Tuple[OptimSlot, ...]
    device: Plan
    host: Plan
    compress: bool
    resident_bytes: int
    est_dma_s_per_step: float
    est_compress_s_per_step: float

    @property
    def device_peak_bytes(self) -> int:
        return self.device.arena_bytes

    @property
    def host_pool_bytes(self) -> int:
        return self.host.arena_bytes

    @property
    def host_fp32_bytes(self) -> int:
        """What the host pool would cost without compression."""
        return sum(_align(s.nbytes) for s in self.slots)

    @property
    def ef_residual_host_bytes(self) -> int:
        """fp32 error-feedback residual held host-side (never on the bus)."""
        return sum(s.nbytes for s in self.slots) if self.compress else 0

    @property
    def dma_bytes_per_step(self) -> int:
        return sum(s.dma_bytes for s in self.slots)

    @property
    def compress_flops_per_step(self) -> int:
        if not self.compress:
            return 0
        return _COMPRESS_FLOPS_PER_ELEM * sum(s.n_elems for s in self.slots)

    @property
    def reduction_x(self) -> float:
        """Device-resident optimizer bytes, all-resident / planned peak."""
        return self.resident_bytes / max(1, self.device_peak_bytes)

    def slot(self, name: str) -> OptimSlot:
        for s in self.slots:
            if s.name == name:
                return s
        raise KeyError(name)

    def summary(self) -> Dict[str, Any]:
        return {
            "n_slots": len(self.slots),
            "compress": self.compress,
            "resident_bytes": self.resident_bytes,
            "device_peak_bytes": self.device_peak_bytes,
            "reduction_x": self.reduction_x,
            "host_pool_bytes": self.host_pool_bytes,
            "host_fp32_bytes": self.host_fp32_bytes,
            "ef_residual_host_bytes": self.ef_residual_host_bytes,
            "dma_bytes_per_step": self.dma_bytes_per_step,
            "compress_flops_per_step": self.compress_flops_per_step,
            "est_dma_s_per_step": self.est_dma_s_per_step,
            "est_compress_s_per_step": self.est_compress_s_per_step,
        }

    def validate(self) -> None:
        self.device.validate()
        self.host.validate()
        for s in self.slots:
            if not (s.prefetch_eo <= s.read_eo < s.swapout_eo):
                raise AssertionError(
                    f"{s.name}: window prefetch={s.prefetch_eo} "
                    f"read={s.read_eo} swapout={s.swapout_eo} out of order")
            dp = self.device.placements.get(s.name)
            if dp is None:
                raise AssertionError(f"{s.name}: no device placement")
            if dp.min_eo > s.prefetch_eo or dp.max_eo < s.swapout_eo:
                raise AssertionError(
                    f"{s.name}: device placement [{dp.min_eo},{dp.max_eo}] "
                    f"does not cover [{s.prefetch_eo},{s.swapout_eo}]")
            hp = self.host.placements.get(s.name + _HOST)
            if hp is None:
                raise AssertionError(f"{s.name}: no host-pool placement")
            if hp.nbytes < s.host_nbytes:
                raise AssertionError(
                    f"{s.name}: host slot {hp.nbytes}B < compressed copy "
                    f"{s.host_nbytes}B")


def optim_slot_specs(graph: LayerGraph, ordered: OrderedTensors,
                     prefetch_margin: int) -> List[Tuple[Any, OptimSlot]]:
    """(LayerNode, OptimSlot) for every layer owning trainable weights.

    E-shared unrolled copies (``shares_weights_with``) and frozen layers
    carry no optimizer state of their own and get no slot.
    """
    out: List[Tuple[Any, OptimSlot]] = []
    for l in graph.layers:
        if l.kind not in WEIGHTED_KINDS or not l.trainable:
            continue
        if l.shares_weights_with or not l.weight_shapes():
            continue
        eo_cg = ordered.layer_orders[l.name][1]
        nbytes = 2 * l.weight_nbytes()          # m and v, fp32
        n_elems = nbytes // 4
        out.append((l, OptimSlot(
            layer=l.name,
            name=f"O:{l.name}",
            n_elems=n_elems,
            nbytes=nbytes,
            host_nbytes=compressed_nbytes(n_elems),
            prefetch_eo=max(0, eo_cg - prefetch_margin),
            read_eo=eo_cg,
            swapout_eo=eo_cg + 1,
        )))
    return out


def plan_optim_offload(graph: LayerGraph, ordered: OrderedTensors,
                       config) -> Optional[OptimPlan]:
    """Price and pack the optimizer slots; None when nothing is eligible.

    The same joint cost model as the activation offload lane prices the
    decision: offloading costs ``dma_bytes_per_step`` bus time plus the
    de/requantization FLOPs (``config.dma_gbps`` / ``config.device_tflops``,
    remat-policy defaults when unset), and buys back
    ``resident_bytes - device_peak_bytes`` of device memory; keeping
    resident costs nothing but holds the full 2x-params footprint.  The
    honest prices land in :meth:`OptimPlan.summary` — the BENCH row and
    the serving admission controller consume them.
    """
    pairs = optim_slot_specs(graph, ordered, config.prefetch_margin)
    if not pairs:
        return None
    compress = bool(config.optim_compress)
    slots = tuple(
        s if compress else dataclasses.replace(s, host_nbytes=s.nbytes)
        for _, s in pairs)

    # fp32 working buffers over their CG windows -> separate device region
    device_specs = [
        TensorSpec(name=s.name, shape=(s.n_elems,), dtype="float32",
                   lifespan=Lifespan.BACKWARD, create_mode=CreateMode.CREATE,
                   exec_orders=(s.prefetch_eo, s.swapout_eo))
        for s in slots
    ]
    device = get_planner(config.planner).plan(
        _SpecSet(device_specs, ordered.eo_max))

    # persistent compressed copies -> host pool (live the whole iteration:
    # the state must survive from one step's swap-out to the next's prefetch)
    host_specs = [
        TensorSpec(name=s.name + _HOST, shape=(s.host_nbytes,), dtype="int8",
                   lifespan=Lifespan.MAX, create_mode=CreateMode.CREATE,
                   exec_orders=(0, ordered.eo_max))
        for s in slots
    ]
    host = get_planner(config.host_planner).plan(
        _SpecSet(host_specs, ordered.eo_max))

    dma_gbps = config.dma_gbps if config.dma_gbps else _DEFAULT_DMA_GBPS
    tflops = config.device_tflops if config.device_tflops \
        else _DEFAULT_DEVICE_TFLOPS
    dma_bytes = sum(s.dma_bytes for s in slots)
    flops = (_COMPRESS_FLOPS_PER_ELEM * sum(s.n_elems for s in slots)
             if compress else 0)

    plan = OptimPlan(
        slots=slots, device=device, host=host, compress=compress,
        resident_bytes=sum(_align(s.nbytes) for s in slots),
        est_dma_s_per_step=dma_bytes / (dma_gbps * 1e9),
        est_compress_s_per_step=flops / (tflops * 1e12),
    )
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Numerical runtime: host-side compressed state + offloaded AdamW update
# ---------------------------------------------------------------------------

class OptimRuntime:
    """Host tier of the offloaded optimizer: compressed copies + EF residual.

    One entry per :class:`OptimSlot`: the int8 block-scaled host copy of the
    layer's flattened ``m || v`` (or the exact fp32 copy when the plan is
    uncompressed) plus, under compression, the fp32 error-feedback residual
    that re-injects each re-quantization's rounding error into the next.
    The residual never crosses the bus; only ``prefetch()``'s compressed
    payload (H2D) and ``swap_out()``'s fp32 state (D2H) are DMA.
    """

    def __init__(self, plan: OptimPlan, graph: LayerGraph,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1):
        import jax.numpy as jnp
        from repro.optim.compression import _deq, _q

        self.plan = plan
        self.lr, self.b1, self.b2 = lr, b1, b2
        self.eps, self.weight_decay = eps, weight_decay
        self.count = 0
        # per-layer flat layout: (wname, shape, size) in weight_shapes order
        self.layouts: Dict[str, List[Tuple[str, Tuple[int, ...], int]]] = {}
        self.halves: Dict[str, int] = {}
        self.host_state: Dict[str, Any] = {}
        self.residual: Dict[str, Any] = {}
        for s in plan.slots:
            l = graph.layer(s.layer)
            self.layouts[s.layer] = [
                (w, tuple(shape), int(math.prod(shape)) if shape else 1)
                for w, shape in l.weight_shapes().items()]
            self.halves[s.layer] = sum(
                sz for _, _, sz in self.layouts[s.layer])
            zero = jnp.zeros((s.n_elems,), jnp.float32)
            if plan.compress:
                # the host copy lives in encoded space: quantize
                # encode(0) so the first prefetch decodes back to exact
                # zero moments (raw zeros would decode v to exp(0) ~ 1)
                enc = self._encode(s.layer, zero)
                q, scale = _q(enc)
                self.host_state[s.layer] = {"q": q, "scale": scale}
                self.residual[s.layer] = enc - _deq(q, scale, enc.shape)
            else:
                self.host_state[s.layer] = zero

    # --------------------------------------------------------- quant space
    # The m half quantizes linearly (signed, roughly normal — the int8
    # grid fits; a collapsed m merely zeroes one step's momentum, which
    # error feedback re-injects).  The v half quantizes in LOG space:
    # v spans orders of magnitude within one block, and a small-v element
    # that linear int8 collapses to zero turns the update denominator
    # into ``eps`` — a 1e8x update explosion.  Encoding 0.5*log(v+floor)
    # makes the int8 grid error *multiplicative* on sqrt(v): with block
    # absmax <= 0.5*|log(floor)| ~ 18.4 the grid is ~0.145, so the
    # denominator is off by at most e^0.0725 ~ 7.5% — bounded, never
    # collapsed.  The floor maps v=0 to an exactly-representable block
    # constant that decodes back to exactly 0.
    _V_LOG_FLOOR = 1e-16

    def _encode(self, layer: str, state):
        import jax.numpy as jnp
        h = self.halves[layer]
        v = jnp.maximum(state[h:], 0.0) + self._V_LOG_FLOOR
        return jnp.concatenate([state[:h], 0.5 * jnp.log(v)])

    def _decode(self, layer: str, enc):
        import jax.numpy as jnp
        h = self.halves[layer]
        v = jnp.exp(2.0 * enc[h:]) - self._V_LOG_FLOOR
        return jnp.concatenate([enc[:h], jnp.maximum(v, 0.0)])

    # ------------------------------------------------------------- transfers
    def prefetch(self, layer: str, stats=None):
        """H2D: dequantize the host copy into the fp32 working state."""
        from repro.optim.compression import _deq

        s = self.plan.slot(f"O:{layer}")
        if self.plan.compress:
            hs = self.host_state[layer]
            state = self._decode(
                layer, _deq(hs["q"], hs["scale"], (s.n_elems,)))
        else:
            state = self.host_state[layer]
        if stats is not None:
            stats.opt_prefetches += 1
            stats.opt_dma_bytes += s.host_nbytes
        return state

    def swap_out(self, layer: str, state, stats=None) -> None:
        """D2H: re-quantize the updated fp32 state with error feedback."""
        from repro.optim.compression import _deq, _q

        s = self.plan.slot(f"O:{layer}")
        if self.plan.compress:
            # EF runs in the quantization (encoded) space: the residual
            # carries the encoded-domain rounding error forward
            total = self._encode(layer, state) + self.residual[layer]
            q, scale = _q(total)
            self.host_state[layer] = {"q": q, "scale": scale}
            self.residual[layer] = total - _deq(q, scale, total.shape)
        else:
            self.host_state[layer] = state
        if stats is not None:
            stats.opt_swap_outs += 1
            stats.opt_dma_bytes += s.nbytes
            stats.opt_compressed_bytes += s.host_nbytes

    # --------------------------------------------------------------- packing
    def unpack(self, layer: str, flat):
        """Flat ``m || v`` vector -> ({wname: m}, {wname: v})."""
        layout = self.layouts[layer]
        half = sum(sz for _, _, sz in layout)
        ms, vs, off = {}, {}, 0
        for wname, shape, sz in layout:
            ms[wname] = flat[off:off + sz].reshape(shape)
            vs[wname] = flat[half + off:half + off + sz].reshape(shape)
            off += sz
        return ms, vs

    def pack(self, layer: str, ms, vs):
        import jax.numpy as jnp
        layout = self.layouts[layer]
        parts = [ms[w].reshape(-1) for w, _, _ in layout]
        parts += [vs[w].reshape(-1) for w, _, _ in layout]
        return jnp.concatenate(parts)


def offloaded_update(runtime: OptimRuntime, params, grads, stats=None):
    """One AdamW step through the offload dance; returns new params.

    Walks the slots in schedule (prefetch) order, per layer: prefetch +
    dequantize the host state, apply the reference AdamW math
    (``optim/optimizers.py:adamw`` — same bias correction, decoupled weight
    decay), swap the updated state back out with EF re-quantization.
    Layers without a slot (frozen, E-shared) keep their params untouched.
    ``stats`` (a ``SwapExecStats``) accumulates the ``opt_*`` counters.
    """
    import jax.numpy as jnp

    runtime.count += 1
    t = float(runtime.count)
    c1 = 1.0 - runtime.b1 ** t
    c2 = 1.0 - runtime.b2 ** t
    new_params = {ln: dict(entry) for ln, entry in params.items()}
    for s in sorted(runtime.plan.slots, key=lambda s: s.prefetch_eo):
        layer = s.layer
        if layer not in grads:
            continue
        flat = runtime.prefetch(layer, stats)
        ms, vs = runtime.unpack(layer, flat)
        for wname, _, _ in runtime.layouts[layer]:
            g = grads[layer][wname].astype(jnp.float32)
            p = params[layer][wname]
            m = runtime.b1 * ms[wname] + (1 - runtime.b1) * g
            v = runtime.b2 * vs[wname] + (1 - runtime.b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + runtime.eps)
            new_params[layer][wname] = (
                p - runtime.lr * (upd + runtime.weight_decay
                                  * p.astype(jnp.float32))).astype(p.dtype)
            ms[wname], vs[wname] = m, v
        runtime.swap_out(layer, runtime.pack(layer, ms, vs), stats)
    return new_params
