"""Memory Planner (NNTrainer §4.2, Algorithm 2) + beyond-paper allocators.

The planner maps each CREATE-mode tensor (post-merge) to a byte offset in a
single arena (the Memory Pool) such that tensors whose execution-order
intervals overlap never share bytes.  Peak memory is known *before*
execution — the property the paper highlights for avoiding OOM crashes.

Every planner implements the :class:`ArenaAllocator` protocol — one
placement abstraction shared by the device arena and the pinned-host pool
(``MemoryPlanConfig.host_planner`` picks the host-side implementation):

* :class:`SortingPlanner` — the paper's Algorithm 2, faithfully: sort by
  ascending ``min(EO)`` (ties: descending ``max(EO)``), then greedily reuse
  the storage of any already-placed tensor whose interval has fully expired.
  We add the size-fit check the pseudo-code leaves implicit (a tensor may
  only reuse a region at least as large as itself).

* :class:`BestFitPlanner` — beyond paper (the paper names fragmentation
  minimisation as future work): interval-overlap-aware offset assignment
  that scans *gaps* between already-placed live tensors and picks the
  tightest fit, falling back to extending the arena.  This is classic
  best-fit address assignment on lifetime intervals (cf. XLA's buffer
  assignment heuristics).

* :class:`SegregatedFitPlanner` — size-class free lists: regions are
  rounded to power-of-two classes and a freed region is reused by the next
  tensor of the same class (LIFO).  Classes make every slot of a class
  interchangeable, so reuse never fails on a few bytes of size mismatch —
  the failure mode of Algorithm 2's exact-fit scan on ragged sizes — at
  the cost of bounded internal padding (< 2x, visible in
  ``Plan.utilization``).

* :class:`BuddyPlanner` — classic binary-buddy allocation over the
  lifetime timeline: blocks split recursively to the requested order and
  freed buddies coalesce, so adjacent small regions can serve one large
  request (which no-coalescing allocators extend the arena for).

* :class:`WorstCasePlanner` — no reuse at all; models a naive tensor-basis
  framework's peak for the Fig. 9 comparison.

All planners return a :class:`Plan` that can be validated (no two live
tensors overlap in [offset, offset+nbytes), every offset ALIGN-aligned)
and queried for peak bytes and fragmentation (:meth:`Plan.utilization`).
"""

from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Dict, List, Optional, Protocol, Set,
                    Tuple, runtime_checkable)

from repro.core.execution_order import OrderedTensors
from repro.core.lifespan import CreateMode, TensorSpec

if TYPE_CHECKING:  # planner <- offload would cycle at runtime
    from repro.core.offload import OffloadSchedule

ALIGN = 64  # byte alignment for every arena slot (cache-line / vector width)


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def _size_class(n: int) -> int:
    """Smallest ALIGN * 2^k >= n (the segregated-fit / buddy granularity)."""
    c = ALIGN
    while c < n:
        c *= 2
    return c


@runtime_checkable
class ArenaAllocator(Protocol):
    """The pluggable allocator layer: assign every planned tensor a byte
    offset in one arena such that lifetime-overlapping tensors never share
    bytes.  Implementations are *offline* packers — they see the full EO
    timeline up front — but several (segregated fit, buddy) simulate the
    behaviour of their online counterpart over that timeline, so their
    fragmentation characteristics carry over to a runtime pool."""

    name: str

    def plan(self, ordered: OrderedTensors) -> "Plan":
        ...


@dataclasses.dataclass
class Placement:
    name: str
    offset: int
    nbytes: int          # bytes reserved (region size — may include padding)
    min_eo: int
    max_eo: int
    # bytes actually requested (0 = same as nbytes).  Class-rounding
    # allocators (segregated fit, buddy) reserve more than requested; the
    # difference is internal fragmentation, charged by utilization().
    requested: int = 0

    @property
    def end(self) -> int:
        return self.offset + self.nbytes

    @property
    def live_bytes(self) -> int:
        return self.requested or self.nbytes


@dataclasses.dataclass
class Plan:
    placements: Dict[str, Placement]
    arena_bytes: int
    planner: str
    # bytes NOT in the arena (placeholders: model inputs / labels)
    external_bytes: int = 0
    # optimizer-state offload plan (repro.core.optim_offload.OptimPlan)
    # attached by compile_plan when MemoryPlanConfig.optim_offload is on;
    # optimizer slots occupy their OWN device region and host pool, so
    # nothing here aliases the activation placements above
    optim: Optional[object] = None

    @property
    def peak_bytes(self) -> int:
        return self.arena_bytes

    @property
    def total_bytes(self) -> int:
        """Arena + externally-held placeholders (the paper's 'ideal' counts
        inputs/labels since they reside in process memory during training)."""
        return self.arena_bytes + self.external_bytes

    def offset_of(self, name: str) -> int:
        return self.placements[name].offset

    def validate(self) -> None:
        """No two tensors with overlapping EO intervals may overlap in bytes,
        every placement is ALIGN-aligned, and nothing exceeds the arena.

        Delegates to the static verifier's aliasing sweep
        (:func:`repro.core.verify.plan_aliasing_diagnostics`) so every
        call site — planners, both compile paths, hand-forged test plans —
        shares one checker; raises :class:`AssertionError` on the first
        finding, preserving the historical contract."""
        from repro.core.verify import plan_aliasing_diagnostics
        diags = plan_aliasing_diagnostics(self)
        if diags:
            raise AssertionError(diags[0].message)

    def utilization(self) -> float:
        """max over time of live requested bytes / arena bytes (1.0 = zero
        fragmentation).  The numerator uses *requested* sizes, so both
        external fragmentation (holes between regions) and internal padding
        (class rounding in segregated fit / buddy) count against it."""
        if not self.placements:
            return 1.0
        events = sorted({p.min_eo for p in self.placements.values()}
                        | {p.max_eo for p in self.placements.values()})
        peak_live = 0
        for t in events:
            live = sum(p.live_bytes for p in self.placements.values()
                       if p.min_eo <= t <= p.max_eo)
            peak_live = max(peak_live, live)
        return peak_live / self.arena_bytes if self.arena_bytes else 1.0


def _planned_and_external(ordered: OrderedTensors) -> Tuple[List[TensorSpec], int]:
    planned = ordered.planned_tensors()
    external = sum(
        t.nbytes for t in ordered.tensors.values()
        if t.create_mode == CreateMode.PLACEHOLDER
    )
    return planned, external


class SortingPlanner:
    """Algorithm 2 — the paper's simple sorting-based planner."""

    name = "sorting"

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        # line 1-4: sort ascending min EO; ties broken by descending max EO
        tensors = sorted(tensors, key=lambda t: (t.min_eo, -t.max_eo))
        placements: Dict[str, Placement] = {}
        order_placed: List[Placement] = []
        arena = 0
        for t in tensors:
            nbytes = _align(t.nbytes)
            reuse: Optional[Placement] = None
            # line 8-13: scan earlier tensors back-to-front for an expired one
            for prev in reversed(order_placed):
                if prev.max_eo < t.min_eo and prev.nbytes >= nbytes:
                    # region fully expired and large enough — but we must also
                    # ensure no *other* live tensor has since been placed there
                    if not self._region_busy(order_placed, prev, t, placements):
                        reuse = prev
                        break
            if reuse is not None:
                pl = Placement(t.name, reuse.offset, nbytes, t.min_eo, t.max_eo)
            else:
                pl = Placement(t.name, arena, nbytes, t.min_eo, t.max_eo)
                arena += nbytes
            placements[t.name] = pl
            order_placed.append(pl)
            t.offset = pl.offset
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan

    @staticmethod
    def _region_busy(placed: List[Placement], region: Placement,
                     t: TensorSpec, placements: Dict[str, Placement]) -> bool:
        """True if any tensor live during t's interval occupies region bytes."""
        for other in placed:
            if other is region:
                continue
            bytes_overlap = not (
                other.end <= region.offset
                or region.offset + _align(t.nbytes) <= other.offset
            )
            life_overlap = not (other.max_eo < t.min_eo or t.max_eo < other.min_eo)
            if bytes_overlap and life_overlap:
                return True
        return False


class BestFitPlanner:
    """Beyond-paper: best-fit gap search over lifetime intervals.

    For each tensor (sorted by min EO, then size descending), collect the
    offsets blocked by tensors whose lifetime overlaps, then choose the
    smallest gap that fits; extend the arena only when no gap fits.
    """

    name = "bestfit"

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        tensors = sorted(tensors, key=lambda t: (t.min_eo, -t.nbytes))
        placements: Dict[str, Placement] = {}
        arena = 0
        for t in tensors:
            nbytes = _align(t.nbytes)
            blockers = sorted(
                (p for p in placements.values()
                 if not (p.max_eo < t.min_eo or t.max_eo < p.min_eo)),
                key=lambda p: p.offset,
            )
            best_off: Optional[int] = None
            best_gap = None
            cursor = 0
            for b in blockers:
                gap = b.offset - cursor
                if gap >= nbytes and (best_gap is None or gap < best_gap):
                    best_off, best_gap = cursor, gap
                cursor = max(cursor, b.end)
            # trailing space inside current arena
            tail_gap = arena - cursor
            if tail_gap >= nbytes and (best_gap is None or tail_gap < best_gap):
                best_off, best_gap = cursor, tail_gap
            if best_off is None:
                best_off = cursor
                arena = max(arena, best_off + nbytes)
            pl = Placement(t.name, best_off, nbytes, t.min_eo, t.max_eo)
            placements[t.name] = pl
            t.offset = pl.offset
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan


class SegregatedFitPlanner:
    """Size-class free lists simulated over the EO timeline.

    Regions are rounded up to power-of-two classes; at each allocation the
    expired regions are returned to their class's free list and the request
    is served from its exact class (LIFO — the hottest slot first, like a
    runtime segregated-fit pool would).  Every slot of a class is
    interchangeable, so reuse never fails on a size mismatch; the price is
    internal padding, charged to :meth:`Plan.utilization` via
    ``Placement.requested``.
    """

    name = "segregated"

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        placements: Dict[str, Placement] = {}
        free: Dict[int, List[int]] = {}        # class size -> free offsets
        live: List[Tuple[int, int, int]] = []  # (max_eo, class, offset)
        arena = 0
        for t in sorted(tensors, key=lambda t: (t.min_eo, -t.nbytes, t.name)):
            nbytes = _align(t.nbytes)
            cls = _size_class(nbytes)
            still_live = []
            for entry in live:
                if entry[0] < t.min_eo:
                    free.setdefault(entry[1], []).append(entry[2])
                else:
                    still_live.append(entry)
            live = still_live
            if free.get(cls):
                off = free[cls].pop()
            else:
                off = arena
                arena += cls
            placements[t.name] = Placement(t.name, off, cls, t.min_eo,
                                           t.max_eo, requested=nbytes)
            live.append((t.max_eo, cls, off))
            t.offset = off
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan


class BuddyPlanner:
    """Binary-buddy allocation simulated over the EO timeline.

    Blocks split recursively down to the requested order and freed buddies
    coalesce back up, so two adjacent freed halves can serve one request of
    their combined size — the reuse that no-splitting/no-coalescing
    allocators miss.  The arena doubles when no block fits (the canonical
    buddy growth rule); ``arena_bytes`` reports the high-water byte span
    actually reserved, not the doubled capacity.
    """

    name = "buddy"

    _MAX_ORDER = 48  # ALIGN << 48 ~ 16 EiB: effectively unbounded

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        placements: Dict[str, Placement] = {}
        free: Dict[int, Set[int]] = {o: set() for o in range(self._MAX_ORDER)}
        live: List[Tuple[int, int, int]] = []  # (max_eo, order, offset)
        self._span = 0          # current pow2 capacity (ALIGN << top order)
        self._top: Optional[int] = None

        for t in sorted(tensors, key=lambda t: (t.min_eo, -t.nbytes, t.name)):
            nbytes = _align(t.nbytes)
            order = (_size_class(nbytes) // ALIGN).bit_length() - 1
            still_live = []
            for entry in live:
                if entry[0] < t.min_eo:
                    self._release(free, entry[2], entry[1])
                else:
                    still_live.append(entry)
            live = still_live
            off = self._alloc(free, order)
            while off is None:
                self._grow(free, order)
                off = self._alloc(free, order)
            placements[t.name] = Placement(t.name, off, ALIGN << order,
                                           t.min_eo, t.max_eo,
                                           requested=nbytes)
            live.append((t.max_eo, order, off))
            t.offset = off
        arena = max((p.end for p in placements.values()), default=0)
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan

    def _alloc(self, free: Dict[int, Set[int]], order: int) -> Optional[int]:
        for o in range(order, self._MAX_ORDER):
            if free[o]:
                off = min(free[o])   # lowest address first: keeps span tight
                free[o].discard(off)
                while o > order:     # split down, freeing the upper halves
                    o -= 1
                    free[o].add(off + (ALIGN << o))
                return off
        return None

    def _release(self, free: Dict[int, Set[int]], off: int, order: int) -> None:
        while order < self._MAX_ORDER - 1:
            buddy = off ^ (ALIGN << order)
            if buddy in free[order]:
                free[order].discard(buddy)
                off = min(off, buddy)
                order += 1
            else:
                break
        free[order].add(off)

    def _grow(self, free: Dict[int, Set[int]], order: int) -> None:
        if self._top is None:
            self._top = order
            self._span = ALIGN << order
            free[order].add(0)
            return
        # double: the new upper half becomes a free block of the old top
        # order; _release coalesces it with the lower half when that is free
        self._release(free, self._span, self._top)
        self._top += 1
        self._span *= 2


class WorstCasePlanner:
    """No reuse: every tensor gets fresh storage (naive-framework model)."""

    name = "worstcase"

    def plan(self, ordered: OrderedTensors) -> Plan:
        # Include would-be views as separate allocations: a tensor-op-basis
        # framework without lifetime analysis materialises each of them.
        tensors = [
            t for t in ordered.tensors.values()
            if t.create_mode != CreateMode.PLACEHOLDER
        ]
        external = sum(
            t.nbytes for t in ordered.tensors.values()
            if t.create_mode == CreateMode.PLACEHOLDER
        )
        placements: Dict[str, Placement] = {}
        arena = 0
        for t in sorted(tensors, key=lambda t: t.min_eo):
            nbytes = _align(t.nbytes)
            placements[t.name] = Placement(t.name, arena, nbytes, t.min_eo, t.max_eo)
            arena += nbytes
        return Plan(placements, arena, self.name, external)


PLANNERS: Dict[str, type] = {
    "sorting": SortingPlanner,
    "bestfit": BestFitPlanner,
    "segregated": SegregatedFitPlanner,
    "buddy": BuddyPlanner,
    "worstcase": WorstCasePlanner,
}


def get_planner(name: str) -> ArenaAllocator:
    """Instantiate a registered :class:`ArenaAllocator` by name."""
    try:
        return PLANNERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown planner {name!r}: choose from "
            f"{', '.join(sorted(PLANNERS))}") from None


def plan_memory(ordered: OrderedTensors, planner: str = "sorting",
                offload: Optional["OffloadSchedule"] = None,
                host_planner: str = "sorting"):
    """Plan the arena; with an :class:`OffloadSchedule` the plan is
    swap-aware (see :func:`plan_memory_swapped`)."""
    if offload is not None:
        return plan_memory_swapped(ordered, offload, planner=planner,
                                   host_planner=host_planner)
    return get_planner(planner).plan(ordered)


# ---------------------------------------------------------------------------
# Swap-aware planning: swapped tensors vacate their bytes mid-lifetime
# ---------------------------------------------------------------------------

_PRE, _POST, _HOST = "@pre", "@post", "@host"


class _SpecSet:
    """Minimal OrderedTensors-shaped view over an explicit spec list, so the
    interval planners can run on split residency intervals unchanged."""

    def __init__(self, specs: List[TensorSpec], eo_max: int,
                 placeholders: Optional[List[TensorSpec]] = None):
        placeholders = placeholders or []
        self.tensors = {t.name: t for t in list(specs) + placeholders}
        self.merged: Dict[str, str] = {}
        self.eo_max = eo_max
        self.layer_orders: Dict[str, Tuple[int, int, int]] = {}
        self._planned = list(specs)

    def planned_tensors(self) -> List[TensorSpec]:
        return self._planned


@dataclasses.dataclass
class SwapAwarePlan:
    """Device arena planned over *residency* intervals + a host-pool arena.

    A swapped tensor's single lifetime interval is split into two residency
    intervals — ``[first access, write_eo + 1]`` (resident until the
    background swap-out DMA completes) and ``[prefetch_at_eo, last access]``
    (re-resident once the prefetch starts) — so every byte it occupied is
    reusable by the planner during the gap.  The offloaded copy occupies a
    second arena modelling the pinned-host pool for ``[write_eo + 1,
    read_eo]``, packed by its own :class:`ArenaAllocator`
    (``host_planner``).

    The two halves may land at different device offsets (the prefetch is a
    fresh write), but the swap-aware placement pass prefers the *same*
    offset for both when nothing else claims it during the post interval.
    When additionally no other tensor touched those bytes during the whole
    idle window, the data survived in place: the swap needs no host slot
    and no DMA in either direction — an *in-place prefetch*.  Such
    decisions are flagged ``inplace`` on the schedule, listed in
    ``self.inplace``, and counted by ``inplace_prefetch_count``.
    """

    device: Plan
    host: Plan
    schedule: "OffloadSchedule"
    # original tensor name -> its residency placements (1 entry if unsplit)
    residencies: Dict[str, Tuple[Placement, ...]]
    baseline_arena_bytes: int        # same planner, no swapping
    planner: str
    host_planner: str = "sorting"
    # swapped tensors whose gap went unused: no host copy, no DMA
    inplace: Tuple[str, ...] = ()
    # optimizer-state offload plan (repro.core.optim_offload.OptimPlan),
    # attached by compile_plan when MemoryPlanConfig.optim_offload is on.
    # Its slots are packed into a separate device working region and
    # compressed host pool — activation_residency_peak() and the two
    # arenas above stay optimizer-blind by construction.
    optim: Optional[object] = None

    @property
    def arena_bytes(self) -> int:
        return self.device.arena_bytes

    @property
    def peak_bytes(self) -> int:
        return self.device.arena_bytes

    @property
    def host_pool_bytes(self) -> int:
        return self.host.arena_bytes

    @property
    def hbm_bytes_saved(self) -> int:
        return self.baseline_arena_bytes - self.device.arena_bytes

    @property
    def inplace_prefetch_count(self) -> int:
        return len(self.inplace)

    def swapped_names(self) -> Tuple[str, ...]:
        return tuple(n for n, rs in self.residencies.items() if len(rs) == 2)

    def activation_residency_peak(self) -> int:
        """Peak simultaneously-resident ``X:``/``S:`` bytes over the EO
        timeline — the bound the swap executor's HBM tracker asserts.
        In-place-prefetch tensors never leave the device (their bytes must
        survive the gap untouched), so they count across their full span."""
        inplace = set(self.inplace)
        places: List[Tuple[int, int, int]] = []
        for n, rs in self.residencies.items():
            if not n.startswith(("X:", "S:")):
                continue
            if n in inplace and len(rs) == 2:
                pre, post = sorted(rs, key=lambda r: r.min_eo)
                places.append((pre.min_eo, post.max_eo, pre.nbytes))
            else:
                places.extend((r.min_eo, r.max_eo, r.nbytes) for r in rs)
        events = sorted({p[0] for p in places} | {p[1] for p in places})
        peak = 0
        for eo in events:
            live = sum(n for lo, hi, n in places if lo <= eo <= hi)
            peak = max(peak, live)
        return peak

    def validate(self) -> None:
        """Prove the swap plan sound: residency intervals never share bytes
        while overlapping in time, swapped tensors truly vacate the arena
        during their idle window, every offloaded copy has host bytes
        covering the whole gap, and every in-place prefetch really kept its
        bytes untouched (same offset, gap unused)."""
        self.device.validate()
        self.host.validate()
        inplace = set(self.inplace)
        for d in self.schedule.decisions:
            rs = self.residencies.get(d.name)
            if rs is None or not d.vacates:
                continue
            if len(rs) != 2:
                raise AssertionError(
                    f"{d.name}: expected 2 residency intervals, got {len(rs)}")
            pre, post = sorted(rs, key=lambda r: r.min_eo)
            if pre.max_eo > d.swap_out_eo:
                raise AssertionError(
                    f"{d.name}: pre-swap residency ends at {pre.max_eo}, "
                    f"after swap-out phase {d.swap_out_eo}")
            if post.min_eo < d.prefetch_at_eo:
                raise AssertionError(
                    f"{d.name}: post-swap residency starts at {post.min_eo}, "
                    f"before prefetch phase {d.prefetch_at_eo}")
            for eo in range(d.swap_out_eo + 1, d.prefetch_at_eo):
                if any(r.min_eo <= eo <= r.max_eo for r in rs):
                    raise AssertionError(
                        f"{d.name}: still resident at EO {eo} inside its "
                        f"idle window ({d.swap_out_eo}, {d.prefetch_at_eo})")
            if d.name in inplace:
                if not d.inplace:
                    raise AssertionError(
                        f"{d.name}: in plan.inplace but its schedule "
                        f"decision is not flagged inplace")
                if pre.offset != post.offset:
                    raise AssertionError(
                        f"{d.name}: in-place prefetch with pre offset "
                        f"{pre.offset} != post offset {post.offset}")
                if self._gap_bytes_used(pre, post):
                    raise AssertionError(
                        f"{d.name}: in-place prefetch but another tensor "
                        f"used its bytes during the idle window")
                if d.name + _HOST in self.host.placements:
                    raise AssertionError(
                        f"{d.name}: in-place prefetch must not hold a "
                        f"host-pool slot")
                continue
            hp = self.host.placements.get(d.name + _HOST)
            if hp is None:
                raise AssertionError(f"{d.name}: no host-pool placement")
            if hp.min_eo > d.swap_out_eo or hp.max_eo < d.read_eo:
                raise AssertionError(
                    f"{d.name}: host copy [{hp.min_eo},{hp.max_eo}] does not "
                    f"cover the swap window [{d.swap_out_eo},{d.read_eo}]")

    def _gap_bytes_used(self, pre: Placement, post: Placement) -> bool:
        """True if any other placement touches [pre.offset, pre.end) while
        live strictly inside the idle window (pre.max_eo, post.min_eo)."""
        for p in self.device.placements.values():
            if p is pre or p is post:
                continue
            if p.end <= pre.offset or pre.offset + post.nbytes <= p.offset:
                continue
            if p.min_eo < post.min_eo and p.max_eo > pre.max_eo:
                return True
        return False


def _clone_spec(t: TensorSpec, name: str, orders: Tuple[int, ...]) -> TensorSpec:
    return TensorSpec(name=name, shape=t.shape, dtype=t.dtype,
                      lifespan=t.lifespan, create_mode=CreateMode.CREATE,
                      exec_orders=tuple(sorted(orders)))


def _prefer_same_offset(device: Plan,
                        residencies: Dict[str, Tuple[Placement, ...]]) -> None:
    """Swap-aware tie-breaking pass: re-anchor each swapped tensor's post
    residency at its pre offset when no other live placement claims those
    bytes during the post interval.  Pointer-stable re-residency is what
    makes an in-place prefetch possible at all; when the idle window's
    bytes additionally went unused, the copy itself is elided (see
    :func:`_detect_inplace`).  Only shrinks the arena, never grows it."""
    for name in sorted(residencies):
        rs = residencies[name]
        if len(rs) != 2:
            continue
        pre, post = sorted(rs, key=lambda r: r.min_eo)
        if pre.offset == post.offset:
            continue
        lo, hi = pre.offset, pre.offset + post.nbytes
        conflict = any(
            p is not post and p is not pre
            and not (p.end <= lo or hi <= p.offset)
            and not (p.max_eo < post.min_eo or post.max_eo < p.min_eo)
            for p in device.placements.values())
        if not conflict:
            post.offset = pre.offset
    device.arena_bytes = max((p.end for p in device.placements.values()),
                             default=0)


def _detect_inplace(device: Plan,
                    residencies: Dict[str, Tuple[Placement, ...]],
                    decisions) -> Tuple[str, ...]:
    """Names whose pre/post residencies share an offset AND whose bytes no
    other tensor touched during the idle window: the device data survived,
    so swap-out and prefetch both become no-ops (no host slot, no DMA)."""
    out: List[str] = []
    for d in decisions:
        rs = residencies.get(d.name)
        if rs is None or len(rs) != 2:
            continue
        pre, post = sorted(rs, key=lambda r: r.min_eo)
        if pre.offset != post.offset:
            continue
        used = any(
            p is not pre and p is not post
            and not (p.end <= pre.offset or pre.offset + post.nbytes <= p.offset)
            and p.min_eo < post.min_eo and p.max_eo > pre.max_eo
            for p in device.placements.values())
        if not used:
            out.append(d.name)
    return tuple(out)


def legacy_host_pool_bytes(ordered: OrderedTensors,
                           schedule: "OffloadSchedule") -> int:
    """What the pre-allocator-layer code charged for the host pool: a
    SortingPlanner pack over EVERY offloaded copy's [swap_out, read]
    lifetime — in-place elision ignored.  The baseline the
    fragmentation-aware pool is benchmarked against (BENCH_swap.json
    ``legacy_host_bytes``); honest, because the old packer did reuse bytes
    across disjoint swap windows."""
    host_specs = [
        _clone_spec(ordered.tensors[d.name], d.name + _HOST,
                    (d.swap_out_eo, d.read_eo))
        for d in schedule.decisions if d.vacates
    ]
    return SortingPlanner().plan(_SpecSet(host_specs, ordered.eo_max)).arena_bytes


def plan_memory_swapped(ordered: OrderedTensors, schedule: "OffloadSchedule",
                        planner: str = "sorting",
                        host_planner: str = "sorting") -> SwapAwarePlan:
    """Plan the device arena with the swap schedule applied.

    Decisions whose prefetch would start before the swap-out completes
    (``not d.vacates``) are kept resident — splitting them would reclaim
    nothing and cost two DMA transfers.  After packing, the swap-aware
    placement pass re-anchors post residencies at their pre offsets where
    possible, decisions whose bytes survived the gap untouched are lowered
    to in-place prefetches (no host slot, no DMA), and the host pool is
    packed by its own allocator (``host_planner``) over the remaining
    offloaded copies.
    """
    from repro.core.offload import make_schedule

    # Re-derive in-place flags from this packing: flags riding in on the
    # caller's schedule describe a different arena layout.
    decisions = tuple(
        dataclasses.replace(d, inplace=False) if d.inplace else d
        for d in schedule.decisions)
    by_name = {d.name: d for d in decisions if d.vacates}

    placeholders = [t for t in ordered.tensors.values()
                    if t.create_mode == CreateMode.PLACEHOLDER]
    # Baseline over the SAME tensor universe the swapped re-pack sees
    # (planned owners + placeholders), so hbm_bytes_saved compares like
    # with like.  Planning ``ordered`` directly would let planners that
    # look beyond planned_tensors() (WorstCasePlanner materialises merged
    # views too) report phantom savings that have nothing to do with swaps.
    baseline = get_planner(planner).plan(_SpecSet(
        [_clone_spec(t, t.name, t.exec_orders)
         for t in ordered.planned_tensors()],
        ordered.eo_max, placeholders))
    split_specs: List[TensorSpec] = []
    split_names: Dict[str, Tuple[str, ...]] = {}
    for t in ordered.planned_tensors():
        d = by_name.get(t.name)
        if d is None:
            split_specs.append(_clone_spec(t, t.name, t.exec_orders))
            split_names[t.name] = (t.name,)
            continue
        pre = tuple(o for o in t.exec_orders if o <= d.write_eo) + (d.swap_out_eo,)
        post = (d.prefetch_at_eo,) + tuple(
            o for o in t.exec_orders if o >= d.read_eo)
        split_specs.append(_clone_spec(t, t.name + _PRE, pre))
        split_specs.append(_clone_spec(t, t.name + _POST, post))
        split_names[t.name] = (t.name + _PRE, t.name + _POST)

    device = get_planner(planner).plan(
        _SpecSet(split_specs, ordered.eo_max, placeholders))

    residencies = {
        name: tuple(device.placements[part] for part in parts)
        for name, parts in split_names.items()
    }
    _prefer_same_offset(device, residencies)
    inplace = _detect_inplace(device, residencies, by_name.values())
    if inplace:
        flagged = set(inplace)
        decisions = tuple(
            dataclasses.replace(d, inplace=True) if d.name in flagged else d
            for d in decisions)
    schedule = make_schedule(decisions)

    host_specs = [
        _clone_spec(ordered.tensors[d.name], d.name + _HOST,
                    (d.swap_out_eo, d.read_eo))
        for d in by_name.values() if d.name not in set(inplace)
    ]
    host = get_planner(host_planner).plan(_SpecSet(host_specs, ordered.eo_max))

    plan = SwapAwarePlan(
        device=device, host=host, schedule=schedule,
        residencies=residencies,
        baseline_arena_bytes=baseline.arena_bytes, planner=planner,
        host_planner=host_planner, inplace=inplace,
    )
    plan.validate()
    return plan
