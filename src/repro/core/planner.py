"""Memory Planner (NNTrainer §4.2, Algorithm 2) + beyond-paper planners.

The planner maps each CREATE-mode tensor (post-merge) to a byte offset in a
single arena (the Memory Pool) such that tensors whose execution-order
intervals overlap never share bytes.  Peak memory is known *before*
execution — the property the paper highlights for avoiding OOM crashes.

Three planners are provided:

* :class:`SortingPlanner` — the paper's Algorithm 2, faithfully: sort by
  ascending ``min(EO)`` (ties: descending ``max(EO)``), then greedily reuse
  the storage of any already-placed tensor whose interval has fully expired.
  We add the size-fit check the pseudo-code leaves implicit (a tensor may
  only reuse a region at least as large as itself).

* :class:`BestFitPlanner` — beyond paper (the paper names fragmentation
  minimisation as future work): interval-overlap-aware offset assignment
  that scans *gaps* between already-placed live tensors and picks the
  tightest fit, falling back to extending the arena.  This is classic
  best-fit address assignment on lifetime intervals (cf. XLA's buffer
  assignment heuristics).

* :class:`WorstCasePlanner` — no reuse at all; models a naive tensor-basis
  framework's peak for the Fig. 9 comparison.

All planners return a :class:`Plan` that can be validated (no two live
tensors overlap in [offset, offset+nbytes)) and queried for peak bytes.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

from repro.core.execution_order import OrderedTensors
from repro.core.lifespan import CreateMode, TensorSpec

if TYPE_CHECKING:  # planner <- offload would cycle at runtime
    from repro.core.offload import OffloadSchedule

ALIGN = 64  # byte alignment for every arena slot (cache-line / vector width)


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


@dataclasses.dataclass
class Placement:
    name: str
    offset: int
    nbytes: int
    min_eo: int
    max_eo: int

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


@dataclasses.dataclass
class Plan:
    placements: Dict[str, Placement]
    arena_bytes: int
    planner: str
    # bytes NOT in the arena (placeholders: model inputs / labels)
    external_bytes: int = 0

    @property
    def peak_bytes(self) -> int:
        return self.arena_bytes

    @property
    def total_bytes(self) -> int:
        """Arena + externally-held placeholders (the paper's 'ideal' counts
        inputs/labels since they reside in process memory during training)."""
        return self.arena_bytes + self.external_bytes

    def offset_of(self, name: str) -> int:
        return self.placements[name].offset

    def validate(self) -> None:
        """No two tensors with overlapping EO intervals may overlap in bytes."""
        ps = list(self.placements.values())
        for i in range(len(ps)):
            for j in range(i + 1, len(ps)):
                a, b = ps[i], ps[j]
                lifetimes_overlap = not (a.max_eo < b.min_eo or b.max_eo < a.min_eo)
                bytes_overlap = not (a.end <= b.offset or b.end <= a.offset)
                if lifetimes_overlap and bytes_overlap:
                    raise AssertionError(
                        f"overlap: {a.name} [{a.offset},{a.end}) eo[{a.min_eo},{a.max_eo}] "
                        f"vs {b.name} [{b.offset},{b.end}) eo[{b.min_eo},{b.max_eo}]"
                    )
        for p in ps:
            if p.end > self.arena_bytes:
                raise AssertionError(f"{p.name} exceeds arena")

    def utilization(self) -> float:
        """max over time of live bytes / arena bytes (1.0 = zero fragmentation)."""
        if not self.placements:
            return 1.0
        events = sorted({p.min_eo for p in self.placements.values()}
                        | {p.max_eo for p in self.placements.values()})
        peak_live = 0
        for t in events:
            live = sum(p.nbytes for p in self.placements.values()
                       if p.min_eo <= t <= p.max_eo)
            peak_live = max(peak_live, live)
        return peak_live / self.arena_bytes if self.arena_bytes else 1.0


def _planned_and_external(ordered: OrderedTensors) -> Tuple[List[TensorSpec], int]:
    planned = ordered.planned_tensors()
    external = sum(
        t.nbytes for t in ordered.tensors.values()
        if t.create_mode == CreateMode.PLACEHOLDER
    )
    return planned, external


class SortingPlanner:
    """Algorithm 2 — the paper's simple sorting-based planner."""

    name = "sorting"

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        # line 1-4: sort ascending min EO; ties broken by descending max EO
        tensors = sorted(tensors, key=lambda t: (t.min_eo, -t.max_eo))
        placements: Dict[str, Placement] = {}
        order_placed: List[Placement] = []
        arena = 0
        for t in tensors:
            nbytes = _align(t.nbytes)
            reuse: Optional[Placement] = None
            # line 8-13: scan earlier tensors back-to-front for an expired one
            for prev in reversed(order_placed):
                if prev.max_eo < t.min_eo and prev.nbytes >= nbytes:
                    # region fully expired and large enough — but we must also
                    # ensure no *other* live tensor has since been placed there
                    if not self._region_busy(order_placed, prev, t, placements):
                        reuse = prev
                        break
            if reuse is not None:
                pl = Placement(t.name, reuse.offset, nbytes, t.min_eo, t.max_eo)
            else:
                pl = Placement(t.name, arena, nbytes, t.min_eo, t.max_eo)
                arena += nbytes
            placements[t.name] = pl
            order_placed.append(pl)
            t.offset = pl.offset
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan

    @staticmethod
    def _region_busy(placed: List[Placement], region: Placement,
                     t: TensorSpec, placements: Dict[str, Placement]) -> bool:
        """True if any tensor live during t's interval occupies region bytes."""
        for other in placed:
            if other is region:
                continue
            bytes_overlap = not (
                other.end <= region.offset or region.offset + _align(t.nbytes) <= other.offset
            )
            life_overlap = not (other.max_eo < t.min_eo or t.max_eo < other.min_eo)
            if bytes_overlap and life_overlap:
                return True
        return False


class BestFitPlanner:
    """Beyond-paper: best-fit gap search over lifetime intervals.

    For each tensor (sorted by min EO, then size descending), collect the
    offsets blocked by tensors whose lifetime overlaps, then choose the
    smallest gap that fits; extend the arena only when no gap fits.
    """

    name = "bestfit"

    def plan(self, ordered: OrderedTensors) -> Plan:
        tensors, external = _planned_and_external(ordered)
        tensors = sorted(tensors, key=lambda t: (t.min_eo, -t.nbytes))
        placements: Dict[str, Placement] = {}
        arena = 0
        for t in tensors:
            nbytes = _align(t.nbytes)
            blockers = sorted(
                (p for p in placements.values()
                 if not (p.max_eo < t.min_eo or t.max_eo < p.min_eo)),
                key=lambda p: p.offset,
            )
            best_off: Optional[int] = None
            best_gap = None
            cursor = 0
            for b in blockers:
                gap = b.offset - cursor
                if gap >= nbytes and (best_gap is None or gap < best_gap):
                    best_off, best_gap = cursor, gap
                cursor = max(cursor, b.end)
            # trailing space inside current arena
            tail_gap = arena - cursor
            if tail_gap >= nbytes and (best_gap is None or tail_gap < best_gap):
                best_off, best_gap = cursor, tail_gap
            if best_off is None:
                best_off = cursor
                arena = max(arena, best_off + nbytes)
            pl = Placement(t.name, best_off, nbytes, t.min_eo, t.max_eo)
            placements[t.name] = pl
            t.offset = pl.offset
        plan = Plan(placements, arena, self.name, external)
        plan.validate()
        return plan


class WorstCasePlanner:
    """No reuse: every tensor gets fresh storage (naive-framework model)."""

    name = "worstcase"

    def plan(self, ordered: OrderedTensors) -> Plan:
        # Include would-be views as separate allocations: a tensor-op-basis
        # framework without lifetime analysis materialises each of them.
        tensors = [
            t for t in ordered.tensors.values()
            if t.create_mode != CreateMode.PLACEHOLDER
        ]
        external = sum(
            t.nbytes for t in ordered.tensors.values()
            if t.create_mode == CreateMode.PLACEHOLDER
        )
        placements: Dict[str, Placement] = {}
        arena = 0
        for t in sorted(tensors, key=lambda t: t.min_eo):
            nbytes = _align(t.nbytes)
            placements[t.name] = Placement(t.name, arena, nbytes, t.min_eo, t.max_eo)
            arena += nbytes
        return Plan(placements, arena, self.name, external)


PLANNERS = {
    "sorting": SortingPlanner,
    "bestfit": BestFitPlanner,
    "worstcase": WorstCasePlanner,
}


def plan_memory(ordered: OrderedTensors, planner: str = "sorting",
                offload: Optional["OffloadSchedule"] = None):
    """Plan the arena; with an :class:`OffloadSchedule` the plan is
    swap-aware (see :func:`plan_memory_swapped`)."""
    if offload is not None:
        return plan_memory_swapped(ordered, offload, planner=planner)
    return PLANNERS[planner]().plan(ordered)


# ---------------------------------------------------------------------------
# Swap-aware planning: swapped tensors vacate their bytes mid-lifetime
# ---------------------------------------------------------------------------

_PRE, _POST, _HOST = "@pre", "@post", "@host"


class _SpecSet:
    """Minimal OrderedTensors-shaped view over an explicit spec list, so the
    interval planners can run on split residency intervals unchanged."""

    def __init__(self, specs: List[TensorSpec], eo_max: int,
                 placeholders: Optional[List[TensorSpec]] = None):
        placeholders = placeholders or []
        self.tensors = {t.name: t for t in list(specs) + placeholders}
        self.merged: Dict[str, str] = {}
        self.eo_max = eo_max
        self.layer_orders: Dict[str, Tuple[int, int, int]] = {}
        self._planned = list(specs)

    def planned_tensors(self) -> List[TensorSpec]:
        return self._planned


@dataclasses.dataclass
class SwapAwarePlan:
    """Device arena planned over *residency* intervals + a host-pool arena.

    A swapped tensor's single lifetime interval is split into two residency
    intervals — ``[first access, write_eo + 1]`` (resident until the
    background swap-out DMA completes) and ``[prefetch_at_eo, last access]``
    (re-resident once the prefetch starts) — so every byte it occupied is
    reusable by the planner during the gap.  The offloaded copy occupies a
    second arena modelling the pinned-host pool for ``[write_eo + 1,
    read_eo]``.  The two halves may land at *different* device offsets: the
    prefetch is a fresh write, nothing pins it to the old address.
    """

    device: Plan
    host: Plan
    schedule: "OffloadSchedule"
    # original tensor name -> its residency placements (1 entry if unsplit)
    residencies: Dict[str, Tuple[Placement, ...]]
    baseline_arena_bytes: int        # same planner, no swapping
    planner: str

    @property
    def arena_bytes(self) -> int:
        return self.device.arena_bytes

    @property
    def peak_bytes(self) -> int:
        return self.device.arena_bytes

    @property
    def host_pool_bytes(self) -> int:
        return self.host.arena_bytes

    @property
    def hbm_bytes_saved(self) -> int:
        return self.baseline_arena_bytes - self.device.arena_bytes

    def swapped_names(self) -> Tuple[str, ...]:
        return tuple(n for n, rs in self.residencies.items() if len(rs) == 2)

    def activation_residency_peak(self) -> int:
        """Peak simultaneously-resident ``X:``/``S:`` bytes over the EO
        timeline — the bound the swap executor's HBM tracker asserts."""
        places = [r for n, rs in self.residencies.items()
                  if n.startswith(("X:", "S:")) for r in rs]
        events = sorted({p.min_eo for p in places} | {p.max_eo for p in places})
        peak = 0
        for eo in events:
            live = sum(p.nbytes for p in places if p.min_eo <= eo <= p.max_eo)
            peak = max(peak, live)
        return peak

    def validate(self) -> None:
        """Prove the swap plan sound: residency intervals never share bytes
        while overlapping in time, swapped tensors truly vacate the arena
        during their idle window, and every offloaded copy has host bytes
        covering the whole gap."""
        self.device.validate()
        self.host.validate()
        for d in self.schedule.decisions:
            rs = self.residencies.get(d.name)
            if rs is None or not d.vacates:
                continue
            if len(rs) != 2:
                raise AssertionError(
                    f"{d.name}: expected 2 residency intervals, got {len(rs)}")
            pre, post = sorted(rs, key=lambda r: r.min_eo)
            if pre.max_eo > d.swap_out_eo:
                raise AssertionError(
                    f"{d.name}: pre-swap residency ends at {pre.max_eo}, "
                    f"after swap-out phase {d.swap_out_eo}")
            if post.min_eo < d.prefetch_at_eo:
                raise AssertionError(
                    f"{d.name}: post-swap residency starts at {post.min_eo}, "
                    f"before prefetch phase {d.prefetch_at_eo}")
            for eo in range(d.swap_out_eo + 1, d.prefetch_at_eo):
                if any(r.min_eo <= eo <= r.max_eo for r in rs):
                    raise AssertionError(
                        f"{d.name}: still resident at EO {eo} inside its "
                        f"idle window ({d.swap_out_eo}, {d.prefetch_at_eo})")
            hp = self.host.placements.get(d.name + _HOST)
            if hp is None:
                raise AssertionError(f"{d.name}: no host-pool placement")
            if hp.min_eo > d.swap_out_eo or hp.max_eo < d.read_eo:
                raise AssertionError(
                    f"{d.name}: host copy [{hp.min_eo},{hp.max_eo}] does not "
                    f"cover the swap window [{d.swap_out_eo},{d.read_eo}]")


def _clone_spec(t: TensorSpec, name: str, orders: Tuple[int, ...]) -> TensorSpec:
    return TensorSpec(name=name, shape=t.shape, dtype=t.dtype,
                      lifespan=t.lifespan, create_mode=CreateMode.CREATE,
                      exec_orders=tuple(sorted(orders)))


def plan_memory_swapped(ordered: OrderedTensors, schedule: "OffloadSchedule",
                        planner: str = "sorting") -> SwapAwarePlan:
    """Plan the device arena with the swap schedule applied.

    Decisions whose prefetch would start before the swap-out completes
    (``not d.vacates``) are kept resident — splitting them would reclaim
    nothing and cost two DMA transfers.
    """
    by_name = {d.name: d for d in schedule.decisions if d.vacates}

    placeholders = [t for t in ordered.tensors.values()
                    if t.create_mode == CreateMode.PLACEHOLDER]
    # Baseline over the SAME tensor universe the swapped re-pack sees
    # (planned owners + placeholders), so hbm_bytes_saved compares like
    # with like.  Planning ``ordered`` directly would let planners that
    # look beyond planned_tensors() (WorstCasePlanner materialises merged
    # views too) report phantom savings that have nothing to do with swaps.
    baseline = PLANNERS[planner]().plan(_SpecSet(
        [_clone_spec(t, t.name, t.exec_orders)
         for t in ordered.planned_tensors()],
        ordered.eo_max, placeholders))
    split_specs: List[TensorSpec] = []
    split_names: Dict[str, Tuple[str, ...]] = {}
    for t in ordered.planned_tensors():
        d = by_name.get(t.name)
        if d is None:
            split_specs.append(_clone_spec(t, t.name, t.exec_orders))
            split_names[t.name] = (t.name,)
            continue
        pre = tuple(o for o in t.exec_orders if o <= d.write_eo) + (d.swap_out_eo,)
        post = (d.prefetch_at_eo,) + tuple(
            o for o in t.exec_orders if o >= d.read_eo)
        split_specs.append(_clone_spec(t, t.name + _PRE, pre))
        split_specs.append(_clone_spec(t, t.name + _POST, post))
        split_names[t.name] = (t.name + _PRE, t.name + _POST)

    device = PLANNERS[planner]().plan(
        _SpecSet(split_specs, ordered.eo_max, placeholders))

    host_specs = [
        _clone_spec(ordered.tensors[d.name], d.name + _HOST,
                    (d.swap_out_eo, d.read_eo))
        for d in by_name.values()
    ]
    host = SortingPlanner().plan(_SpecSet(host_specs, ordered.eo_max))

    residencies = {
        name: tuple(device.placements[part] for part in parts)
        for name, parts in split_names.items()
    }
    plan = SwapAwarePlan(
        device=device, host=host, schedule=schedule,
        residencies=residencies,
        baseline_arena_bytes=baseline.arena_bytes, planner=planner,
    )
    plan.validate()
    return plan
