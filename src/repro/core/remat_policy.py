"""Joint keep / recompute / offload planning for tagged intermediates —
NNTrainer's lifespan analysis adapted to the TPU memory hierarchy.

On-device NNTrainer packs activations into a planned arena because embedded
RAM is the binding constraint.  On a TPU pod the binding constraint is HBM
per chip, and the degree of freedom is not *where* a tensor lives but what
happens to it between its forward write and its backward read.  Per named
intermediate there are three choices, each with a step-time price:

    keep       — stays resident in HBM; free at step time, but consumes
                 budget bytes for the whole Forward+CalcGrad lifespan;
    recompute  — Forward-only lifespan; the backward pass rebuilds it at
                 ``recompute_flops / device FLOP/s`` seconds;
    offload    — proactive swap to pinned host memory (NNTrainer §6); the
                 round trip costs ``2 * bytes / host-DMA bandwidth`` seconds
                 and vacates the HBM bytes during the gap.

:func:`plan_joint_policy` solves the three-way problem *jointly*: keeping an
intermediate is worth the cheaper of its two eviction prices, so the keep
set is the knapsack maximising evicted-cost-avoided under the per-layer HBM
budget (solved exactly for the small per-block tag sets, greedily by
cost-density beyond that), and every evicted intermediate takes whichever
eviction lane — recompute or offload — is cheaper under the
:class:`~repro.core.plan.MemoryPlanConfig` hardware cost model
(``dma_gbps``, ``device_tflops``).  The output is a
:class:`RematPlan` with honest accounting (``recompute_flops_per_layer``,
``offload_dma_bytes_per_layer``) and a ``jax.checkpoint`` policy usable
inside scanned transformer blocks.

:func:`plan_checkpoint_policy` is the deprecated two-knob predecessor:
``offload_dropped=False`` restricts the planner to the recompute lane and
``offload_dropped=True`` prices DMA as free (every budget-missing
intermediate offloads — the old cost-blind behaviour, now with its DMA
traffic at least accounted for).

Intermediates are tagged with ``jax.ad_checkpoint.checkpoint_name`` inside
the model code; standard tag names used across repro models:

    attn_in   — block input (always cheap to keep: residual stream)
    qkv       — projected q/k/v
    attn_out  — attention output
    mlp_in    — post-norm MLP input
    mlp_hidden— SwiGLU hidden (the big one: d_ff wide)
    mlp_out   — MLP output
    expert_in — MoE dispatched tokens
    ssm_state — SSM chunk states
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax import ad_checkpoint

from repro.core.deprecation import warn_once

# Hardware cost-model defaults: a TPU-class accelerator (bf16 matmul
# throughput) attached to host memory over a PCIe-class link.  Overridable
# per compile via MemoryPlanConfig(dma_gbps=..., device_tflops=...) or per
# architecture via the same-named ModelConfig fields.
DEFAULT_DMA_GBPS = 32.0
DEFAULT_DEVICE_TFLOPS = 200.0

# Exact knapsack cutoff: per-block tag sets are tiny (4-8 names), so the
# optimal keep set is found by subset enumeration; beyond this the planner
# falls back to the greedy cost-density fill.
_EXACT_KNAPSACK_MAX_ITEMS = 16

KEEP = "keep"
RECOMPUTE = "recompute"
OFFLOAD = "offload"


@dataclasses.dataclass(frozen=True)
class Intermediate:
    """One named intermediate inside a (scanned) layer."""
    name: str
    bytes_per_layer: int       # bf16 bytes per layer at the planned shape
    recompute_flops: float     # FLOPs to rebuild it in backward if dropped


@dataclasses.dataclass
class RematPlan:
    """Per-layer keep/recompute/offload decisions with honest accounting.

    ``dropped`` holds the intermediates the backward pass recomputes and
    ``offloaded`` the ones round-tripped through pinned host memory; their
    union is exactly the budget-missing set (no decision is ever erased).
    ``recompute_flops_per_layer`` sums over ``dropped`` only and
    ``offload_dma_bytes_per_layer`` counts both DMA directions over
    ``offloaded`` — the two observable prices a plan pays.
    ``est_step_time_s_per_layer`` is their combined step-time estimate under
    the hardware cost model the plan was made with (zero DMA contribution
    when that model priced DMA as free — see :func:`plan_step_time_s` to
    re-price a plan under an honest model).
    """

    saved: Tuple[str, ...]
    dropped: Tuple[str, ...]
    saved_bytes_per_layer: int
    recompute_flops_per_layer: float
    # Names swapped to pinned host memory instead of recomputed — the
    # EO-analysis offload schedule's decision set, lowered to XLA via
    # ``repro.core.offload.offload_policy``.
    offloaded: Tuple[str, ...] = ()
    offload_dma_bytes_per_layer: int = 0
    est_step_time_s_per_layer: float = 0.0

    def decisions(self) -> Dict[str, str]:
        """Per-intermediate choice: name -> keep | recompute | offload."""
        out = {n: KEEP for n in self.saved}
        out.update({n: RECOMPUTE for n in self.dropped})
        out.update({n: OFFLOAD for n in self.offloaded})
        return out

    def policy(self):
        """A jax.checkpoint policy saving (and offloading) the planned names."""
        if self.offloaded:
            from repro.core.offload import offload_policy
            return offload_policy(self.offloaded, saved=self.saved)
        if not self.saved:
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_only_these_names(*self.saved)


def _lane_costs_s(i: Intermediate, dma_gbps: float,
                  device_tflops: float) -> Tuple[float, float]:
    """(recompute, offload) step-time prices in seconds for one eviction.

    A non-positive rate means that lane is unusable (infinite price):
    ``dma_gbps=0`` is "no DMA engine" and forces every eviction down the
    recompute lane; ``dma_gbps=inf`` is the deprecated free-DMA pricing.
    """
    recompute_s = math.inf if device_tflops <= 0 \
        else i.recompute_flops / (device_tflops * 1e12)
    if math.isinf(dma_gbps):
        offload_s = 0.0
    elif dma_gbps <= 0:
        offload_s = math.inf
    else:
        offload_s = 2.0 * i.bytes_per_layer / (dma_gbps * 1e9)
    return recompute_s, offload_s


def _evict_cost_s(i: Intermediate, *, offload: bool, dma_gbps: float,
                  device_tflops: float) -> Tuple[float, str]:
    """Cheapest eviction lane for one intermediate: (seconds, lane)."""
    recompute_s, offload_s = _lane_costs_s(i, dma_gbps, device_tflops)
    if not offload:
        return recompute_s, RECOMPUTE
    # ties go to the offload lane so the deprecated free-DMA mode keeps the
    # old offload-everything decision set
    if offload_s <= recompute_s:
        return offload_s, OFFLOAD
    return recompute_s, RECOMPUTE


def _greedy_keep_set(intermediates: Sequence[Intermediate],
                     budget_bytes_per_layer: int,
                     evict_s: Dict[str, float]) -> List[str]:
    """Greedy fill: highest avoided-cost per byte first, recompute density
    as the tiebreak — with every avoided cost zero (the deprecated free-DMA
    mode) this degenerates to the historical flops-per-byte order exactly.
    """
    ranked = sorted(
        intermediates,
        key=lambda i: (evict_s[i.name] / max(i.bytes_per_layer, 1),
                       i.recompute_flops / max(i.bytes_per_layer, 1)),
        reverse=True,
    )
    saved: List[str] = []
    used = 0
    for i in ranked:
        if used + i.bytes_per_layer <= budget_bytes_per_layer:
            saved.append(i.name)
            used += i.bytes_per_layer
    return saved


def _keep_set(intermediates: Sequence[Intermediate],
              budget_bytes_per_layer: int,
              evict_s: Dict[str, float]) -> List[str]:
    """Keep set maximising evicted-cost-avoided under the byte budget.

    Keeping an intermediate avoids exactly its cheapest eviction price, so
    the optimal keep set is a 0/1 knapsack with value ``evict_s`` and weight
    ``bytes_per_layer`` — solved exactly for the small per-block tag sets
    (ties prefer more kept bytes: fewer evictions to account for), greedily
    by cost density for larger universes.
    """
    items = list(intermediates)
    if len(items) <= _EXACT_KNAPSACK_MAX_ITEMS:
        best_mask, best_value, best_bytes = 0, -1.0, -1
        for mask in range(1 << len(items)):
            used = value = 0
            for bit, i in enumerate(items):
                if mask >> bit & 1:
                    used += i.bytes_per_layer
                    value += evict_s[i.name]
            if used > budget_bytes_per_layer:
                continue
            if value > best_value or (value == best_value and used > best_bytes):
                best_mask, best_value, best_bytes = mask, value, used
        return [i.name for bit, i in enumerate(items) if best_mask >> bit & 1]
    return _greedy_keep_set(items, budget_bytes_per_layer, evict_s)


def plan_joint_policy(
    intermediates: Sequence[Intermediate],
    budget_bytes_per_layer: Optional[int],
    *,
    offload: bool = True,
    dma_gbps: Optional[float] = None,
    device_tflops: Optional[float] = None,
) -> RematPlan:
    """Jointly choose keep / recompute / offload per intermediate.

    Minimises the estimated per-layer step-time cost (recompute FLOPs at
    ``device_tflops`` vs DMA round trips at ``dma_gbps``) subject to the
    per-layer HBM budget.  ``budget_bytes_per_layer`` of None means "save
    everything" (keeping is free at step time, so with no budget pressure
    nothing is ever evicted); 0 means every intermediate is evicted down
    its cheaper lane.  ``offload=False`` disables the offload lane (pure
    save-vs-recompute — the classic remat knapsack).  ``dma_gbps`` of
    ``math.inf`` prices DMA as free, reproducing the deprecated
    ``offload_dropped=True`` decisions (with the traffic still accounted).
    """
    dma_gbps = DEFAULT_DMA_GBPS if dma_gbps is None else dma_gbps
    device_tflops = DEFAULT_DEVICE_TFLOPS if device_tflops is None \
        else device_tflops

    cost: Dict[str, float] = {}
    lane: Dict[str, str] = {}
    for i in intermediates:
        cost[i.name], lane[i.name] = _evict_cost_s(
            i, offload=offload, dma_gbps=dma_gbps,
            device_tflops=device_tflops)

    if budget_bytes_per_layer is None:
        saved = [i.name for i in intermediates]
    elif offload and math.isinf(dma_gbps):
        # deprecated free-DMA mode: every avoided cost is zero, so the
        # knapsack is degenerate — use the historical greedy flops-per-byte
        # fill so the alias reproduces its old keep/offload sets exactly
        saved = _greedy_keep_set(intermediates, budget_bytes_per_layer, cost)
    else:
        saved = _keep_set(intermediates, budget_bytes_per_layer, cost)

    saved_set = set(saved)
    by_name = {i.name: i for i in intermediates}
    dropped = tuple(i.name for i in intermediates
                    if i.name not in saved_set and lane[i.name] == RECOMPUTE)
    offloaded = tuple(i.name for i in intermediates
                      if i.name not in saved_set and lane[i.name] == OFFLOAD)
    return RematPlan(
        saved=tuple(i.name for i in intermediates if i.name in saved_set),
        dropped=dropped,
        saved_bytes_per_layer=sum(
            by_name[n].bytes_per_layer for n in saved_set),
        recompute_flops_per_layer=sum(
            by_name[n].recompute_flops for n in dropped),
        offloaded=offloaded,
        offload_dma_bytes_per_layer=sum(
            2 * by_name[n].bytes_per_layer for n in offloaded),
        est_step_time_s_per_layer=sum(
            cost[n] for n in dropped + offloaded),
    )


def plan_step_time_s(plan: RematPlan, intermediates: Sequence[Intermediate],
                     *, dma_gbps: Optional[float] = None,
                     device_tflops: Optional[float] = None) -> float:
    """Re-price a plan's decisions under a given hardware cost model.

    The honest per-layer step-time estimate of *any* RematPlan — including
    plans made under the deprecated free-DMA pricing — so alternatives can
    be compared on equal terms (the joint-optimality acceptance check).
    """
    dma_gbps = DEFAULT_DMA_GBPS if dma_gbps is None else dma_gbps
    device_tflops = DEFAULT_DEVICE_TFLOPS if device_tflops is None \
        else device_tflops
    by_name = {i.name: i for i in intermediates}
    total = 0.0
    for n in plan.dropped:
        total += _lane_costs_s(by_name[n], dma_gbps, device_tflops)[0]
    for n in plan.offloaded:
        total += _lane_costs_s(by_name[n], dma_gbps, device_tflops)[1]
    return total


def plan_checkpoint_policy(
    intermediates: Sequence[Intermediate],
    budget_bytes_per_layer: Optional[int],
    *,
    offload_dropped: bool = False,
) -> RematPlan:
    """Deprecated two-knob planner — use :func:`plan_joint_policy`.

    ``offload_dropped=False`` is the classic save-vs-recompute knapsack
    (the joint planner with the offload lane disabled — decisions are
    identical).  ``offload_dropped=True`` prices DMA as free, so every
    budget-missing intermediate offloads regardless of whether recomputing
    it would be cheaper; it keeps its historical quirk that offload with
    *no* budget streams every intermediate through host (a budget-less
    config would otherwise keep everything and silently never offload).
    """
    if offload_dropped:
        warn_once(
            "offload_dropped=True is deprecated: it prices DMA as free and "
            "offloads every budget-missing intermediate regardless of cost; "
            "use plan_joint_policy(..., offload=True, dma_gbps=...) for the "
            "priced three-way decision",
            DeprecationWarning, stacklevel=2)
        budget = 0 if budget_bytes_per_layer is None else budget_bytes_per_layer
        return plan_joint_policy(intermediates, budget, offload=True,
                                 dma_gbps=math.inf)
    return plan_joint_policy(intermediates, budget_bytes_per_layer,
                             offload=False)


def tag(name: str, x):
    """Tag an intermediate for the checkpoint policy (no-op outside remat)."""
    return ad_checkpoint.checkpoint_name(x, name)


# ---------------------------------------------------------------------------
# Standard transformer intermediates, parameterised by the block shape.
# ---------------------------------------------------------------------------

def transformer_intermediates(*, batch_tokens: int, d_model: int, d_ff: int,
                              n_q_heads: int, n_kv_heads: int, head_dim: int,
                              moe_experts_per_token: int = 0,
                              dtype_bytes: int = 2) -> List[Intermediate]:
    """Byte/FLOP cost model for one decoder block at the given token count."""
    bt = batch_tokens
    qkv_bytes = bt * (n_q_heads + 2 * n_kv_heads) * head_dim * dtype_bytes
    qkv_flops = 2 * bt * d_model * (n_q_heads + 2 * n_kv_heads) * head_dim
    attn_out_bytes = bt * d_model * dtype_bytes
    # attention recompute ~ 2 * seq * heads * head_dim per token (flash bwd
    # recomputes scores anyway; keeping attn_out avoids the output proj only)
    attn_out_flops = 2 * bt * d_model * d_model
    hidden_mult = max(moe_experts_per_token, 1)
    mlp_hidden_bytes = bt * d_ff * hidden_mult * dtype_bytes * 2  # gate+up
    mlp_hidden_flops = 2 * bt * d_model * d_ff * hidden_mult * 2
    mlp_out_bytes = bt * d_model * dtype_bytes
    mlp_out_flops = 2 * bt * d_ff * hidden_mult * d_model
    return [
        Intermediate("qkv", qkv_bytes, qkv_flops),
        Intermediate("attn_out", attn_out_bytes, attn_out_flops),
        Intermediate("mlp_hidden", mlp_hidden_bytes, mlp_hidden_flops),
        Intermediate("mlp_out", mlp_out_bytes, mlp_out_flops),
    ]


def plan_for_config(cfg, batch_tokens: int) -> Optional[RematPlan]:
    """Deprecated shim: the remat/offload plan for a transformer-shaped
    ``ModelConfig``.

    The single owner of this decision is now ``repro.core.compile_plan``;
    this wrapper returns the compiled plan's ``remat_plan`` (None when the
    config disables remat) so old call sites keep their exact behaviour.
    """
    from repro.core.plan import compile_plan  # local: plan imports this module
    return compile_plan(cfg, batch_tokens=batch_tokens).remat_plan
