"""Planner-driven rematerialisation policy — NNTrainer's lifespan analysis
adapted to the TPU memory hierarchy.

On-device NNTrainer packs activations into a planned arena because embedded
RAM is the binding constraint.  On a TPU pod the binding constraint is HBM
per chip, and the degree of freedom is not *where* a tensor lives but
*whether it is kept at all*: XLA's buffer assignment already performs
arena-style interval packing (the moral equivalent of Algorithm 2), so the
lever our planner controls is the save-vs-recompute decision per named
intermediate — i.e. which tensors get Forward+CalcGrad lifespans (saved)
and which get Forward-only lifespans (recomputed in backward).

``plan_checkpoint_policy`` solves the same problem as the paper's Memory
Planner, one level up: given per-intermediate byte costs and recompute-FLOP
costs, keep the intermediates with the worst recompute-cost/byte ratio and
drop the rest until the per-device activation budget is met.  The output is
a ``jax.checkpoint`` policy usable inside scanned transformer blocks.

Intermediates are tagged with ``jax.ad_checkpoint.checkpoint_name`` inside
the model code; standard tag names used across repro models:

    attn_in   — block input (always cheap to keep: residual stream)
    qkv       — projected q/k/v
    attn_out  — attention output
    mlp_in    — post-norm MLP input
    mlp_hidden— SwiGLU hidden (the big one: d_ff wide)
    mlp_out   — MLP output
    expert_in — MoE dispatched tokens
    ssm_state — SSM chunk states
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import ad_checkpoint


@dataclasses.dataclass(frozen=True)
class Intermediate:
    """One named intermediate inside a (scanned) layer."""
    name: str
    bytes_per_layer: int       # bf16 bytes per layer at the planned shape
    recompute_flops: float     # FLOPs to rebuild it in backward if dropped


@dataclasses.dataclass
class RematPlan:
    saved: Tuple[str, ...]
    dropped: Tuple[str, ...]
    saved_bytes_per_layer: int
    recompute_flops_per_layer: float
    # Names swapped to pinned host memory instead of recomputed — the
    # EO-analysis offload schedule's decision set, lowered to XLA via
    # ``repro.core.offload.offload_policy``.
    offloaded: Tuple[str, ...] = ()

    def policy(self):
        """A jax.checkpoint policy saving (and offloading) the planned names."""
        if self.offloaded:
            from repro.core.offload import offload_policy
            return offload_policy(self.offloaded, saved=self.saved)
        if not self.saved:
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_only_these_names(*self.saved)


def plan_checkpoint_policy(
    intermediates: Sequence[Intermediate],
    budget_bytes_per_layer: Optional[int],
    *,
    offload_dropped: bool = False,
) -> RematPlan:
    """Greedy knapsack: keep high recompute-cost-per-byte intermediates.

    ``budget_bytes_per_layer`` of None means "save everything" (no remat).
    A budget of 0 means full remat (save nothing beyond scan carries).
    With ``offload_dropped`` the intermediates that miss the HBM budget are
    swapped to host memory instead of recomputed (proactive swapping, §6):
    they cost DMA traffic rather than backward FLOPs.  Offload with *no*
    budget means "keep no HBM residents" — every intermediate streams
    through host; otherwise ``cfg.offload=True`` with the default
    (budget-less) config would silently do nothing.
    """
    if budget_bytes_per_layer is None:
        names = tuple(i.name for i in intermediates)
        if offload_dropped:
            return RematPlan(saved=(), dropped=(), saved_bytes_per_layer=0,
                             recompute_flops_per_layer=0.0, offloaded=names)
        return RematPlan(
            saved=names,
            dropped=(),
            saved_bytes_per_layer=sum(i.bytes_per_layer for i in intermediates),
            recompute_flops_per_layer=0.0,
        )
    # Sort by recompute-FLOPs per byte, descending: the intermediates that
    # are most expensive to rebuild per byte of HBM are kept first.
    ranked = sorted(
        intermediates,
        key=lambda i: i.recompute_flops / max(i.bytes_per_layer, 1),
        reverse=True,
    )
    saved: List[str] = []
    used = 0
    for i in ranked:
        if used + i.bytes_per_layer <= budget_bytes_per_layer:
            saved.append(i.name)
            used += i.bytes_per_layer
    saved_set = set(saved)
    dropped = tuple(i.name for i in intermediates if i.name not in saved_set)
    if offload_dropped:
        return RematPlan(
            saved=tuple(saved),
            dropped=(),
            saved_bytes_per_layer=used,
            recompute_flops_per_layer=0.0,
            offloaded=dropped,
        )
    return RematPlan(
        saved=tuple(saved),
        dropped=dropped,
        saved_bytes_per_layer=used,
        recompute_flops_per_layer=sum(
            i.recompute_flops for i in intermediates if i.name not in saved_set
        ),
    )


def tag(name: str, x):
    """Tag an intermediate for the checkpoint policy (no-op outside remat)."""
    return ad_checkpoint.checkpoint_name(x, name)


# ---------------------------------------------------------------------------
# Standard transformer intermediates, parameterised by the block shape.
# ---------------------------------------------------------------------------

def transformer_intermediates(*, batch_tokens: int, d_model: int, d_ff: int,
                              n_q_heads: int, n_kv_heads: int, head_dim: int,
                              moe_experts_per_token: int = 0,
                              dtype_bytes: int = 2) -> List[Intermediate]:
    """Byte/FLOP cost model for one decoder block at the given token count."""
    bt = batch_tokens
    qkv_bytes = bt * (n_q_heads + 2 * n_kv_heads) * head_dim * dtype_bytes
    qkv_flops = 2 * bt * d_model * (n_q_heads + 2 * n_kv_heads) * head_dim
    attn_out_bytes = bt * d_model * dtype_bytes
    # attention recompute ~ 2 * seq * heads * head_dim per token (flash bwd
    # recomputes scores anyway; keeping attn_out avoids the output proj only)
    attn_out_flops = 2 * bt * d_model * d_model
    hidden_mult = max(moe_experts_per_token, 1)
    mlp_hidden_bytes = bt * d_ff * hidden_mult * dtype_bytes * 2  # gate+up
    mlp_hidden_flops = 2 * bt * d_model * d_ff * hidden_mult * 2
    mlp_out_bytes = bt * d_model * dtype_bytes
    mlp_out_flops = 2 * bt * d_ff * hidden_mult * d_model
    return [
        Intermediate("qkv", qkv_bytes, qkv_flops),
        Intermediate("attn_out", attn_out_bytes, attn_out_flops),
        Intermediate("mlp_hidden", mlp_hidden_bytes, mlp_hidden_flops),
        Intermediate("mlp_out", mlp_out_bytes, mlp_out_flops),
    ]


def plan_for_config(cfg, batch_tokens: int) -> Optional[RematPlan]:
    """Deprecated shim: the remat/offload plan for a transformer-shaped
    ``ModelConfig``.

    The single owner of this decision is now ``repro.core.compile_plan``;
    this wrapper returns the compiled plan's ``remat_plan`` (None when the
    config disables remat) so old call sites keep their exact behaviour.
    """
    from repro.core.plan import compile_plan  # local: plan imports this module
    return compile_plan(cfg, batch_tokens=batch_tokens).remat_plan
