"""Ideal (minimum) memory requirement calculator (NNTrainer §3, Table 4).

The *ideal* requirement is the peak, over the execution-order timeline, of
the sum of bytes of all simultaneously-live tensors (after MV/RV/E merging)
plus externally-held placeholders (inputs/labels stay resident for the whole
iteration).  A planner with zero fragmentation achieves exactly this number;
the paper's Fig. 9 compares measured peaks against it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.execution_order import OrderedTensors, compute_execution_order
from repro.core.graph import LayerGraph
from repro.core.lifespan import CreateMode


@dataclasses.dataclass
class IdealMemory:
    arena_bytes: int        # peak live CREATE-tensor bytes (perfect packing)
    external_bytes: int     # placeholders (input/label)
    weight_bytes: int       # subset of arena: Max-lifespan tensors
    activation_bytes: int   # subset at peak: saved activations

    @property
    def total_bytes(self) -> int:
        return self.arena_bytes + self.external_bytes

    @property
    def total_kib(self) -> float:
        return self.total_bytes / 1024.0


def ideal_memory(graph: LayerGraph, batch: int) -> IdealMemory:
    ordered = compute_execution_order(graph, batch)
    return ideal_from_ordered(ordered)


def ideal_from_ordered(ordered: OrderedTensors) -> IdealMemory:
    planned = ordered.planned_tensors()
    external = sum(
        t.nbytes for t in ordered.tensors.values()
        if t.create_mode == CreateMode.PLACEHOLDER
    )
    events = sorted({t.min_eo for t in planned} | {t.max_eo for t in planned})
    peak = 0
    peak_t = 0
    for ts in events:
        live = sum(t.nbytes for t in planned if t.min_eo <= ts <= t.max_eo)
        if live > peak:
            peak, peak_t = live, ts
    weight = sum(t.nbytes for t in planned if t.name.startswith("W:"))
    act_at_peak = sum(
        t.nbytes for t in planned
        if t.min_eo <= peak_t <= t.max_eo and t.name.startswith("X:")
    )
    return IdealMemory(
        arena_bytes=peak,
        external_bytes=external,
        weight_bytes=weight,
        activation_bytes=act_at_peak,
    )


# Paper Table 4 published ideal sizes (KiB) at batch 64, for validation.
PAPER_TABLE4_KIB: Dict[str, float] = {
    "linear": 49397,
    "conv2d": 65856,
    "lstm": 84731,
    "model_a_linear": 188250,
    "model_a_conv2d": 51157,
    "model_b_linear": 112935,
    "model_b_conv2d": 54097,
    "model_c_linear": 49399,
    "model_c_conv2d": 65856,
    "model_d": 162295,
}
