"""Algorithm 1: Compute Execution Order (NNTrainer §4.1).

Training of an N-layer model is decomposed into 3N phases:

    EO_F(i)  = i                         (forward, front to back)
    EO_CG(i) = EO_max - (i + 1) * 2      (compute gradient, back to front)
    EO_CD(i) = EO_CG(i) + 1              (compute derivative / apply grad)

with ``EO_max = 3 * N``.  Every tensor requested by layer *i* receives the
subset of {EO_F, EO_CG, EO_CD} selected by its lifespan.  Tensors with
Max lifespan span [0, EO_max]; Iteration-lifespan tensors span from their
first write to EO_max (reset after the iteration).

After assignment, MV / RV / E create-modes are merged:

* ``MV`` (modify-view, e.g. in-place activations): merged into the target
  iff ``min(EOs of merged) >= max(EOs of target)`` — otherwise the target
  is read after the overwrite and integrity breaks (Fig. 5).
* ``RV`` (read-only view, e.g. flatten): always merged — data never
  changes, so integrity holds even with interval overlap (Fig. 6).
* ``E`` (extend, e.g. unrolled weights): always merged — spec and data are
  both shared (§5.2 time-unrolling).

Merging a tensor into a target also unions its EOs into the target so that
the Memory Planner sees the full live interval.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.graph import LayerGraph, tensor_requests
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec


@dataclasses.dataclass
class OrderedTensors:
    """Result of Algorithm 1: the Tensor-Pool map with EOs + merges applied."""

    # name -> spec (post-merge owners + placeholders)
    tensors: Dict[str, TensorSpec]
    merged: Dict[str, str]                  # merged tensor name -> owner name
    eo_max: int
    layer_orders: Dict[str, Tuple[int, int, int]]  # layer -> (F, CG, CD)

    def owner(self, name: str) -> str:
        """Resolve a tensor name to the name owning its storage."""
        while name in self.merged:
            name = self.merged[name]
        return name

    def planned_tensors(self) -> List[TensorSpec]:
        """Tensors that need arena storage (CREATE owners, not placeholders)."""
        return [
            t for t in self.tensors.values()
            if t.create_mode == CreateMode.CREATE and t.merged_into is None
        ]

    def phase_schedule(self) -> List[Tuple[int, str, str]]:
        """The full 3N-phase timeline: (eo, layer, kind) sorted by EO.

        ``kind`` is one of "F" / "CG" / "CD".  Forward phases occupy EOs
        0..N-1 and backward phases N..3N-1; EOs are unique across phases, so
        this is the walk order of the layer-basis executor — the timeline the
        proactive swap engine ticks along.
        """
        phases: List[Tuple[int, str, str]] = []
        for lname, (eo_f, eo_cg, eo_cd) in self.layer_orders.items():
            phases.append((eo_f, lname, "F"))
            phases.append((eo_cg, lname, "CG"))
            phases.append((eo_cd, lname, "CD"))
        return sorted(phases)


def _orders_for(lifespan: Lifespan, eo_f: int, eo_cg: int, eo_cd: int,
                eo_max: int) -> List[int]:
    if lifespan == Lifespan.MAX:
        return [0, eo_max]
    if lifespan == Lifespan.ITERATION:
        # live from first touch in this layer to the end of the iteration
        return [eo_f if lifespan.spans_forward else eo_cg, eo_max]
    orders: List[int] = []
    if lifespan.spans_forward:
        orders.append(eo_f)
    if lifespan.spans_calc_grad:
        orders.append(eo_cg)
    if lifespan.spans_calc_deriv:
        orders.append(eo_cd)
    return orders


def compute_execution_order(graph: LayerGraph, batch: int) -> OrderedTensors:
    """Run Algorithm 1 over a compiled graph."""
    layers = graph.layers
    n = len(layers)
    eo_max = 3 * n

    layer_orders: Dict[str, Tuple[int, int, int]] = {}
    for i, l in enumerate(layers):
        eo_f = i
        eo_cg = eo_max - (i + 1) * 2
        eo_cd = eo_cg + 1
        layer_orders[l.name] = (eo_f, eo_cg, eo_cd)

    # ---- lines 3..12: accumulate EOs into the tensor map --------------------
    tmap: Dict[str, TensorSpec] = {}
    for lname, spec in tensor_requests(graph, batch):
        eo_f, eo_cg, eo_cd = layer_orders[lname]
        node = graph.layer(lname)
        existing = tmap.get(spec.name)
        if existing is None:
            tmap[spec.name] = spec
            existing = spec
        if spec.name == f"X:{lname}":
            # Output activation produced by this layer: written at our F.
            # Everything later (consumer CG reads, loss reads, in-place CD
            # reads) is added by the consumer pass below — crucially, a saved
            # activation is freed after its *consumer's* compute-gradient,
            # not after the producer's (Fig. 4: X1 has orders 0 and 5, where
            # 5 is L1's CG, not L0's).
            orders = [eo_f]
            if node.kind == "activation":
                orders.append(eo_cd)  # derivative computed from own output
        else:
            orders = _orders_for(spec.lifespan, eo_f, eo_cg, eo_cd, eo_max)
            # Layers that skip compute-derivative (first layer / frozen
            # boundary) drop the CD order for their *input-side* tensors;
            # the CD phase itself is still scheduled (it applies gradients).
            if not node.needs_input_derivative and spec.name.startswith("D:"):
                orders = [o for o in orders if o != eo_cd] or orders
        existing.add_orders(orders)
        # Keep the "most conservative" lifespan when different layers request
        # the same tensor: union is realised by the EO set itself.
        if spec is not existing and spec.create_mode != existing.create_mode:
            # A consumer may request the producer's tensor with CREATE while
            # the producer declared a view; prefer the view declaration.
            if existing.create_mode == CreateMode.CREATE and spec.create_mode in (
                CreateMode.MODIFY_VIEW, CreateMode.READONLY_VIEW, CreateMode.EXTEND,
            ):
                existing.create_mode = spec.create_mode
                existing.view_of = spec.view_of

    # Consumers also touch their *input* activations: layer i reading
    # X:<producer> at its own F (and CG if weighted) — those EOs were encoded
    # in the producer-side lifespan via _consumer_save_lifespan, but the
    # actual order values must come from the consumer's schedule.  Add them.
    for i, l in enumerate(layers):
        eo_f, eo_cg, eo_cd = layer_orders[l.name]
        for inp in l.inputs:
            xname = f"X:{inp}"
            if xname not in tmap:
                continue
            t = tmap[xname]
            orders = [eo_f]
            from repro.core.graph import WEIGHTED_KINDS, LOSS_KINDS
            if l.kind in WEIGHTED_KINDS and l.trainable:
                orders.append(eo_cg)
            # NOTE: an activation consumer does NOT read its input after
            # forward — its derivative comes from its *output* (in-place).
            # A pool2d consumer DOES: max-pool backward re-reads the argmax
            # source at its CD phase.  Record the access, otherwise the
            # offload planner sees a false idle window there and swaps would
            # race the read.
            if l.kind == "pool2d":
                orders.append(eo_cd)
            if l.kind in LOSS_KINDS:
                orders.extend([eo_cg, eo_cd])
            t.add_orders(orders)
            # The consumer's CD phase *writes* D:<inp>; the producer's CG/CD
            # phases read it.
            dname = f"D:{inp}"
            if dname in tmap and l.needs_input_derivative:
                tmap[dname].add_orders([eo_cd])

    # ---- lines 13..23: merge views ------------------------------------------
    merged: Dict[str, str] = {}
    order = sorted(tmap.values(), key=lambda t: t.min_eo)
    for t in order:
        if t.create_mode == CreateMode.MODIFY_VIEW and t.view_of:
            target = tmap.get(t.view_of)
            if target is None:
                t.create_mode = CreateMode.CREATE
                continue
            target_owner = tmap[_resolve(merged, t.view_of)]
            # MV may not overwrite externally-owned memory (the data set's
            # input buffer must survive the iteration).
            if target_owner.create_mode == CreateMode.PLACEHOLDER:
                t.create_mode = CreateMode.CREATE
                continue
            # line 17: min(EOs of merged) >= max(EOs of target)
            if t.min_eo >= target_owner.max_eo:
                _merge(tmap, merged, t, target_owner)
            # else: integrity not guaranteed — keep a fresh tensor (mode C)
            else:
                t.create_mode = CreateMode.CREATE
        elif t.create_mode in (CreateMode.READONLY_VIEW,
                               CreateMode.EXTEND) and t.view_of:
            target_owner = tmap.get(_resolve(merged, t.view_of))
            if target_owner is not None:
                _merge(tmap, merged, t, target_owner)
            else:
                t.create_mode = CreateMode.CREATE

    return OrderedTensors(tensors=tmap, merged=merged, eo_max=eo_max,
                          layer_orders=layer_orders)


def _resolve(merged: Dict[str, str], name: str) -> str:
    while name in merged:
        name = merged[name]
    return name


def _merge(tmap: Dict[str, TensorSpec], merged: Dict[str, str],
           t: TensorSpec, owner: TensorSpec) -> None:
    """Merge tensor ``t`` into ``owner``, unioning execution orders."""
    if owner.name == t.name:
        return
    merged[t.name] = owner.name
    t.merged_into = owner.name
    owner.add_orders(t.exec_orders)
    # A view can be *larger* in spec only for E (same spec); MV/RV share the
    # same data extent.  Keep the max byte size to stay safe.
    if t.nbytes > owner.nbytes:
        raise ValueError(
            f"view {t.name} ({t.nbytes}B) larger than target {owner.name} "
            f"({owner.nbytes}B) — merge would overflow the target's storage"
        )
