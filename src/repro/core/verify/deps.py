"""Static dependence analysis + fusion-legality prover over the lowered IR.

PR 6's checker passes prove a *given* :class:`~repro.core.plan.ExecutionSchedule`
memory-safe; this module proves that a *reordered or fused* schedule is
equivalent to the verified one — the analysis that separates planned-memory
prototypes from deployable runtimes (On-device Training systems survey).

Three provers over the happens-before dependence DAG extracted from per-op
read/write sets (tensors *and* arena byte ranges):

* :func:`schedules_equivalent` — a permuted/fused candidate op stream
  preserves every dependence edge of the verifier-signed original
  (check ids ``dep_edge`` / ``dep_transfer_fence`` / ``dep_stream``);
  the admission gate of the ``jit_blocks`` executor backend.
* :func:`plan_fusion` — the maximal runs of ``Compute`` ops whose fusion
  crosses no transfer fence, no ``Free``-reuse hazard and no
  in-place-prefetch window, with ``Free`` ops absorbed (deferred to the
  block end) under the packed residency peak.  :func:`verify_fusion`
  re-proves a :class:`FusionPlan` independently (check ids
  ``fusion_fence`` / ``fusion_hazard`` / ``fusion_peak``) and
  :func:`replay_stream` materialises the fused op order.
* :func:`transfer_slack` — per-transfer static slack from critical-path
  analysis: how many compute phases each DMA has to hide behind.  The
  static denominator for the async backend's achieved-overlap number.

The dependence edge families (every edge is oriented by the canonical
lowering sort key, so a clean lowered schedule is always a linear
extension of its own DAG):

* ``data`` — the compute spine (computes never reorder against each
  other: the interpreter threads derivs/ctx state through every phase),
  plus each ``SwapOut``/``Free`` after the ``Compute`` of its phase;
* ``fence`` — ``SwapOut(t)`` before ``Prefetch(t)``, and ``Prefetch(t)``
  before the consuming ``Compute`` at its ``read_eo``;
* ``reuse`` — arena-byte WAR/WAW edges: a device-range evictor
  (``SwapOut``/``Free``) before any later writer of overlapping bytes
  (``Prefetch`` target or producing ``F`` compute), and a host-slot
  reader (``Prefetch``) before a later ``SwapOut`` reusing its slot.

:func:`check_deps` wraps the self-equivalence proof as a registry pass
(``CHECKS["deps"]``); :func:`deps_summary` folds edge counts, the fusion
plan and the slack table into ``CompiledMemoryPlan.report()["deps"]``.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.planner import SwapAwarePlan, _align
from repro.core.verify.checks import (SEV_ERROR, CheckContext, Diagnostic,
                                      VerifyReport)


def _ops_of(schedule_or_ops) -> Tuple[Any, ...]:
    """Accept an ExecutionSchedule or a raw op sequence."""
    return tuple(getattr(schedule_or_ops, "ops", schedule_or_ops))


def _canon_key(op) -> Tuple[int, int, str, str]:
    """The lowering sort key — the canonical happens-before position of an
    op, independent of where a (possibly corrupted) list placed it."""
    from repro.core.plan import _OP_RANK
    return (op.eo, _OP_RANK[type(op)], getattr(op, "tensor", ""),
            getattr(op, "layer", ""))


def _describe(op) -> str:
    who = getattr(op, "tensor", None) or getattr(op, "layer", "")
    return f"{type(op).__name__}(eo={op.eo}, {who})"


@dataclasses.dataclass(frozen=True)
class DepEdge:
    """One happens-before edge: op ``src`` must execute before ``dst``.

    ``src``/``dst`` index :attr:`DependenceGraph.ops`; ``kind`` is
    ``"data"`` | ``"fence"`` | ``"reuse"``; ``check`` the id a violation
    is reported under (``dep_edge`` or ``dep_transfer_fence``)."""

    src: int
    dst: int
    kind: str
    check: str
    tensor: Optional[str] = None
    why: str = ""


@dataclasses.dataclass(frozen=True)
class DependenceGraph:
    """The happens-before DAG of one lowered schedule."""

    ops: Tuple[Any, ...]
    edges: Tuple[DepEdge, ...]

    def edge_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {"data": 0, "fence": 0, "reuse": 0}
        for e in self.edges:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def check_order(self, candidate_ops: Sequence[Any]) -> List[Diagnostic]:
        """Is ``candidate_ops`` a linear extension of this DAG?

        Two proofs: the candidate replays exactly the original op multiset
        (``dep_stream`` — no op dropped, duplicated or invented), and every
        dependence edge's endpoints appear in order (``dep_edge`` /
        ``dep_transfer_fence``)."""
        cand = _ops_of(candidate_ops)
        diags: List[Diagnostic] = []
        want, got = Counter(self.ops), Counter(cand)
        if want != got:
            missing = want - got
            extra = got - want
            for op, n in sorted(missing.items(), key=lambda e: _canon_key(e[0])):
                diags.append(Diagnostic(
                    SEV_ERROR, "dep_stream",
                    f"candidate stream dropped {_describe(op)} x{n}",
                    tensor=getattr(op, "tensor", None)))
            for op, n in sorted(extra.items(), key=lambda e: _canon_key(e[0])):
                diags.append(Diagnostic(
                    SEV_ERROR, "dep_stream",
                    f"candidate stream invented {_describe(op)} x{n}",
                    tensor=getattr(op, "tensor", None)))
        pos: Dict[Any, int] = {}
        for i, op in enumerate(cand):
            pos.setdefault(op, i)
        for e in self.edges:
            src, dst = self.ops[e.src], self.ops[e.dst]
            ps, pd = pos.get(src), pos.get(dst)
            if ps is None or pd is None:
                continue   # already a dep_stream finding
            if ps >= pd:
                diags.append(Diagnostic(
                    SEV_ERROR, e.check,
                    f"{e.kind} edge violated: {_describe(src)} must precede "
                    f"{_describe(dst)} ({e.why}), found at positions "
                    f"{ps} >= {pd}", op_index=pd, tensor=e.tensor))
        return diags


def build_dependence_graph(schedule_or_ops, ordered=None,
                           plan=None) -> DependenceGraph:
    """Extract per-op read/write sets and build the happens-before DAG.

    ``ordered``/``plan`` sharpen the arena-reuse family with the packed
    placements (producing ``F`` computes get their device byte range);
    without them only the ranges the transfer/free ops themselves carry
    are used.  Every edge is oriented by the canonical lowering key, so
    the DAG is acyclic by construction and a canonically lowered op list
    is always one of its linear extensions.

    Optimizer-slot ops (``OptPrefetch``/``OptSwapOut``) get their own edge
    families — prefetch before the consuming CG compute, the CG compute
    before the swap-out, prefetch before swap-out (WAR on the working
    buffer), and working-region byte reuse between slots — but never mix
    with the activation-arena reuse scans: their offsets index a separate
    device region, so byte comparisons across the two families would be
    meaningless."""
    from repro.core.plan import (Compute, Free, OptPrefetch, OptSwapOut,
                                 Prefetch, SwapOut)
    ops = _ops_of(schedule_or_ops)
    edges: List[DepEdge] = []
    key = [_canon_key(op) for op in ops]

    computes = sorted((i for i, op in enumerate(ops)
                       if isinstance(op, Compute)), key=lambda i: key[i])
    compute_at_eo: Dict[int, int] = {ops[i].eo: i for i in computes}

    # -- data: the compute spine (the interpreter threads state through
    # every phase, so computes are totally ordered among themselves)
    for a, b in zip(computes, computes[1:]):
        edges.append(DepEdge(
            a, b, "data", "dep_edge", tensor=None,
            why=f"phase {ops[a].eo} state feeds phase {ops[b].eo}"))

    def phase_compute(eo: int) -> Optional[int]:
        ci = compute_at_eo.get(eo)
        if ci is not None:
            return ci
        earlier = [i for i in computes if ops[i].eo <= eo]
        return earlier[-1] if earlier else None

    # -- data: an evictor reads/releases its tensor only after the compute
    # of its scheduled phase (the swap drains at the end of the phase, the
    # free runs after the last access)
    for i, op in enumerate(ops):
        if isinstance(op, (SwapOut, Free, OptSwapOut)):
            ci = phase_compute(op.eo)
            if ci is not None:
                edges.append(DepEdge(
                    ci, i, "data", "dep_edge", tensor=op.tensor,
                    why=f"{op.tensor} still accessed at EO {ops[ci].eo}"))

    # -- fence: SwapOut(t) -> Prefetch(t) (the prefetch re-reads the host
    # copy the swap-out wrote), Prefetch(t) -> consuming Compute(read_eo)
    out_of: Dict[str, int] = {}
    for i, op in enumerate(ops):
        if isinstance(op, SwapOut):
            out_of[op.tensor] = i
        elif isinstance(op, Prefetch):
            oi = out_of.get(op.tensor)
            if oi is not None:
                edges.append(DepEdge(
                    oi, i, "fence", "dep_transfer_fence", tensor=op.tensor,
                    why="prefetch re-reads the host copy its swap-out "
                        "wrote"))
            ri = compute_at_eo.get(op.read_eo)
            if ri is not None:
                edges.append(DepEdge(
                    i, ri, "fence", "dep_transfer_fence", tensor=op.tensor,
                    why=f"consumer at EO {op.read_eo} fences this "
                        f"prefetch"))

    # -- fence/data: optimizer slot ops.  Within one step the prefetch
    # comes FIRST (dequantized state feeds the CG update, then the updated
    # state drains): OptPrefetch(t) -> consuming CG compute, CG compute ->
    # OptSwapOut(t), and OptPrefetch(t) -> OptSwapOut(t) (WAR on the
    # working buffer and on the host slot both ops address).
    opt_in_of: Dict[str, int] = {}
    for i, op in enumerate(ops):
        if isinstance(op, OptPrefetch):
            opt_in_of[op.tensor] = i
            ri = compute_at_eo.get(op.read_eo)
            if ri is not None:
                edges.append(DepEdge(
                    i, ri, "fence", "dep_transfer_fence", tensor=op.tensor,
                    why=f"the optimizer update at EO {op.read_eo} reads "
                        f"this slot's dequantized state"))
        elif isinstance(op, OptSwapOut):
            pi = opt_in_of.get(op.tensor)
            if pi is not None:
                edges.append(DepEdge(
                    pi, i, "fence", "dep_transfer_fence", tensor=op.tensor,
                    why="swap-out overwrites the working buffer and host "
                        "slot its prefetch read"))

    # -- reuse: optimizer working-region bytes between slots (their own
    # address space — never compared against activation-arena offsets)
    def opt_range(op) -> Optional[Tuple[int, int]]:
        if op.device_offset < 0:
            return None
        return (op.device_offset, op.device_offset + _align(op.nbytes))

    opt_evictors = [(i, op.tensor, opt_range(op))
                    for i, op in enumerate(ops)
                    if isinstance(op, OptSwapOut) and opt_range(op)]
    opt_writers = [(i, op.tensor, opt_range(op))
                   for i, op in enumerate(ops)
                   if isinstance(op, OptPrefetch) and opt_range(op)]
    for ei, etensor, (elo, ehi) in opt_evictors:
        for wi, wtensor, (wlo, whi) in opt_writers:
            if wtensor == etensor or key[wi] <= key[ei]:
                continue
            if not (whi <= elo or ehi <= wlo):
                edges.append(DepEdge(
                    ei, wi, "reuse", "dep_edge", tensor=wtensor,
                    why=f"optimizer working bytes "
                        f"[{max(elo, wlo)},{min(ehi, whi)}) of {etensor} "
                        f"are reused by {wtensor}"))

    # -- reuse: arena byte-range WAR/WAW.  Device: an evictor's vacated
    # range must precede any later writer of overlapping bytes; host: a
    # prefetch retires its host slot before a later swap-out reuses it.
    def dev_range(op) -> Optional[Tuple[int, int]]:
        off = getattr(op, "device_offset", -1)
        if off is None or off < 0:
            return None
        return (off, off + _align(op.nbytes))

    def host_range(op) -> Optional[Tuple[int, int]]:
        off = getattr(op, "host_offset", -1)
        if off is None or off < 0:
            return None
        return (off, off + _align(op.nbytes))

    # producing-F-compute write ranges come from the packed pre-placement
    producer_writes: List[Tuple[int, str, Tuple[int, int]]] = []
    if ordered is not None and plan is not None:
        ctx = CheckContext.build(ordered, None, plan, None)
        for name, t in ctx.activations.items():
            eo = ctx.producer_eo(name)
            ci = compute_at_eo.get(eo)
            off = ctx.planned_device_offset(name, post=False)
            if ci is not None and off >= 0:
                producer_writes.append(
                    (ci, name, (off, off + ctx.aligned_nbytes(name))))

    evictors = [(i, op.tensor, dev_range(op)) for i, op in enumerate(ops)
                if isinstance(op, (SwapOut, Free)) and dev_range(op)]
    dev_writers = [(i, op.tensor, dev_range(op)) for i, op in enumerate(ops)
                   if isinstance(op, Prefetch) and dev_range(op)]
    dev_writers += producer_writes
    for ei, etensor, (elo, ehi) in evictors:
        for wi, wtensor, (wlo, whi) in dev_writers:
            if wtensor == etensor or key[wi] <= key[ei]:
                continue
            if not (whi <= elo or ehi <= wlo):
                edges.append(DepEdge(
                    ei, wi, "reuse", "dep_edge", tensor=wtensor,
                    why=f"device bytes [{max(elo, wlo)},{min(ehi, whi)}) "
                        f"of {etensor} are reused by {wtensor}"))

    host_readers = [(i, op.tensor, host_range(op))
                    for i, op in enumerate(ops)
                    if isinstance(op, Prefetch) and host_range(op)]
    host_writers = [(i, op.tensor, host_range(op))
                    for i, op in enumerate(ops)
                    if isinstance(op, SwapOut) and host_range(op)]
    for ri, rtensor, (rlo, rhi) in host_readers:
        for wi, wtensor, (wlo, whi) in host_writers:
            if wtensor == rtensor or key[wi] <= key[ri]:
                continue
            if not (whi <= rlo or rhi <= wlo):
                edges.append(DepEdge(
                    ri, wi, "reuse", "dep_edge", tensor=wtensor,
                    why=f"host slot [{max(rlo, wlo)},{min(rhi, whi)}) of "
                        f"{rtensor} is reused by {wtensor}"))

    return DependenceGraph(ops=ops, edges=tuple(edges))


def schedules_equivalent(original, candidate, *, ordered=None,
                         plan=None) -> VerifyReport:
    """Prove ``candidate`` preserves every dependence edge of ``original``.

    ``original`` is the verifier-signed op stream (an
    :class:`~repro.core.plan.ExecutionSchedule` or raw op tuple);
    ``candidate`` the permuted/fused replay to admit.  Returns a
    :class:`VerifyReport` (``ok`` means equivalent); raising is the
    caller's policy."""
    t0 = time.perf_counter()
    graph = build_dependence_graph(original, ordered, plan)
    diags = graph.check_order(candidate)
    dt = time.perf_counter() - t0
    return VerifyReport(
        diagnostics=tuple(diags), checks_run=("deps",),
        ops_scanned=len(graph.ops) + len(_ops_of(candidate)),
        placements_scanned=0, wall_time_s=dt,
        check_seconds={"deps": dt})


def check_deps(ctx: CheckContext) -> List[Diagnostic]:
    """Registry pass: the op list must be a linear extension of its own
    happens-before DAG.  A canonically lowered schedule always is (every
    edge is oriented by the lowering sort key); a permuted one that broke
    an edge is named op-by-op."""
    if not ctx.ops:
        return []
    graph = build_dependence_graph(ctx.ops, ctx.ordered, ctx.plan)
    return graph.check_order(ctx.ops)


# ---------------------------------------------------------------------------
# Static slack: the critical-path denominator for achieved overlap
# ---------------------------------------------------------------------------

def transfer_slack(schedule_or_ops) -> Dict[str, Any]:
    """Per-transfer static slack from critical-path analysis.

    A prefetch issued at EO ``e`` must complete by ``read_eo``: the
    computes dispatched in ``[e, read_eo)`` are the window the DMA can
    hide behind — ``window_computes`` is its length on the compute
    critical path and ``slack_phases`` the raw phase distance.  A
    swap-out's slack runs until its own prefetch re-reads the host copy.
    The minimum over all transfers bounds the overlap any backend can
    achieve without stalling a fence."""
    from repro.core.plan import Compute, Prefetch, SwapOut
    ops = _ops_of(schedule_or_ops)
    compute_eos = sorted(op.eo for op in ops if isinstance(op, Compute))

    def computes_in(lo: int, hi: int) -> int:
        return sum(1 for eo in compute_eos if lo <= eo < hi)

    per: Dict[str, Dict[str, int]] = {}
    out_eo: Dict[str, int] = {}
    for op in ops:
        if isinstance(op, SwapOut):
            out_eo[op.tensor] = op.eo
        elif isinstance(op, Prefetch):
            entry = {
                "prefetch_eo": op.eo,
                "read_eo": op.read_eo,
                "slack_phases": op.read_eo - op.eo,
                "window_computes": computes_in(op.eo, op.read_eo),
            }
            if op.tensor in out_eo:
                entry["swap_out_eo"] = out_eo[op.tensor]
                entry["swap_window_phases"] = op.eo - out_eo[op.tensor]
            per[op.tensor] = entry
    slacks = [e["slack_phases"] for e in per.values()]
    return {
        "transfers": per,
        "min_prefetch_slack_phases": min(slacks) if slacks else None,
        "mean_prefetch_slack_phases": (statistics.fmean(slacks)
                                       if slacks else None),
    }


# ---------------------------------------------------------------------------
# Fusion planning: maximal legal Compute runs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedBlock:
    """One proven-fusable run: its ``Compute`` members dispatch as a single
    call, its absorbed ``Free`` ops are deferred to the block end."""

    index: int
    op_indices: Tuple[int, ...]        # indices into the original op list
    compute_indices: Tuple[int, ...]
    free_indices: Tuple[int, ...]

    def span(self) -> Tuple[int, int]:
        return (min(self.op_indices), max(self.op_indices))


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """plan_fusion's result: which ops fuse, and why the rest do not."""

    blocks: Tuple[FusedBlock, ...]
    n_ops: int
    n_computes: int
    fence_splits: int          # runs ended by a SwapOut/Prefetch
    hazard_splits: int         # runs ended by a Free-reuse hazard
    inplace_splits: int        # runs ended at an in-place re-admission
    peak_splits: int           # runs ended by the residency-peak guard

    def fused_computes(self) -> int:
        return sum(len(b.compute_indices) for b in self.blocks)

    def dispatch_calls(self) -> int:
        """Python-level dispatches replaying under this plan: one per
        block plus one per op outside any block."""
        covered = sum(len(b.op_indices) for b in self.blocks)
        return self.n_ops - covered + len(self.blocks)

    def largest_block(self) -> int:
        return max((len(b.compute_indices) for b in self.blocks), default=0)

    def summary(self) -> Dict[str, Any]:
        return {
            "n_blocks": len(self.blocks),
            "fused_computes": self.fused_computes(),
            "n_computes": self.n_computes,
            "n_ops": self.n_ops,
            "largest_block": self.largest_block(),
            "dispatch_calls": self.dispatch_calls(),
            "splits": {
                "fence": self.fence_splits,
                "hazard": self.hazard_splits,
                "inplace": self.inplace_splits,
                "peak": self.peak_splits,
            },
        }


def _fusion_env(ops, ordered, plan):
    """Shared precomputation for plan_fusion / verify_fusion: producing-F
    compute map, raw owner byte sizes, packed pre-ranges, in-place
    re-admission EOs and the residency peak bound."""
    produced_at: Dict[int, Tuple[str, int, Optional[Tuple[int, int]]]] = {}
    inplace_eos: set = set()
    peak = None
    if ordered is not None:
        ctx = CheckContext.build(ordered, None, plan, None)
        for name, t in ctx.activations.items():
            eo = ctx.producer_eo(name)
            off = ctx.planned_device_offset(name, post=False)
            rng = (off, off + ctx.aligned_nbytes(name)) if off >= 0 else None
            produced_at[eo] = (name, t.nbytes, rng)
    if isinstance(plan, SwapAwarePlan):
        peak = plan.activation_residency_peak()
        inplace_eos = {d.read_eo for d in plan.schedule.decisions
                       if d.inplace}
    return produced_at, inplace_eos, peak


def plan_fusion(schedule_or_ops, ordered=None, plan=None, *,
                min_block: int = 2) -> FusionPlan:
    """The maximal legal ``Compute`` runs of a lowered schedule.

    A run grows over consecutive ``Compute``/``Free`` ops and splits
    when fusing further would change observable behaviour:

    * *fence* — the next op is a ``SwapOut``/``Prefetch``: transfers keep
      their exact issue point (that is the overlap the plan priced);
    * *hazard* — a ``Free`` already absorbed into the run vacates bytes
      an upcoming producer in the same run would reuse: deferring that
      free past the produce would alias live data;
    * *inplace* — the next compute is an in-place decision's re-admission
      phase (``read_eo``): the fused block must not span the vacated
      window's edge, where the static model re-admits the bytes;
    * *peak* — deferring the run's frees past the next production would
      push residency (canonical bytes + deferred bytes) over the packed
      ``activation_residency_peak()`` the backends assert against.

    ``Free`` ops inside a surviving block are absorbed and replayed at
    the block end; runs shorter than ``min_block`` computes stay eager.
    Optimizer-slot transfers (``OptPrefetch``/``OptSwapOut``) are fences
    exactly like activation transfers — their issue point around the CG
    update is the overlap the plan priced — so a run never spans one.
    The result always satisfies :func:`schedules_equivalent` against the
    original (see :func:`replay_stream`)."""
    from repro.core.plan import (Compute, Free, OptPrefetch, OptSwapOut,
                                 Prefetch, SwapOut)
    ops = _ops_of(schedule_or_ops)
    produced_at, inplace_eos, peak = _fusion_env(ops, ordered, plan)

    blocks: List[FusedBlock] = []
    splits = {"fence": 0, "hazard": 0, "inplace": 0, "peak": 0}
    run_computes: List[int] = []
    run_frees: List[int] = []
    deferred_bytes = 0
    deferred_ranges: List[Tuple[int, int]] = []
    current = 0    # canonical resident bytes (raw, HbmTracker accounting)

    def flush(reason: Optional[str] = None) -> None:
        nonlocal run_computes, run_frees, deferred_bytes, deferred_ranges
        if reason is not None and run_computes:
            splits[reason] += 1
        if len(run_computes) >= min_block:
            blocks.append(FusedBlock(
                index=len(blocks),
                op_indices=tuple(sorted(run_computes + run_frees)),
                compute_indices=tuple(run_computes),
                free_indices=tuple(run_frees)))
        run_computes, run_frees = [], []
        deferred_bytes, deferred_ranges = 0, []

    n_computes = 0
    for i, op in enumerate(ops):
        if isinstance(op, (OptSwapOut, OptPrefetch)):
            # optimizer transfers fence like activation transfers, but
            # touch neither the activation residency counter nor the
            # deferred-free ranges (separate device region)
            flush("fence")
        elif isinstance(op, (SwapOut, Prefetch)):
            flush("fence")
            nb = (ordered.tensors[op.tensor].nbytes
                  if ordered is not None and op.tensor in ordered.tensors
                  else op.nbytes)
            current += nb if isinstance(op, Prefetch) else -nb
        elif isinstance(op, Free):
            nb = (ordered.tensors[op.tensor].nbytes
                  if ordered is not None and op.tensor in ordered.tensors
                  else op.nbytes)
            current -= nb
            if run_computes:
                run_frees.append(i)
                deferred_bytes += nb
                off = op.device_offset
                if off >= 0:
                    deferred_ranges.append((off, off + _align(op.nbytes)))
            # an eager Free between blocks needs no dispatch of its own in
            # spirit, but fusing a computes-less run is pointless
        elif isinstance(op, Compute):
            n_computes += 1
            prod = produced_at.get(op.eo) if op.kind == "F" else None
            if prod is not None:
                name, nb, rng = prod
                if rng is not None and any(
                        not (rhi <= rng[0] or rng[1] <= rlo)
                        for rlo, rhi in deferred_ranges):
                    flush("hazard")
                if (peak is not None and run_computes
                        and current + nb + deferred_bytes > peak):
                    flush("peak")
            if op.eo in inplace_eos and run_computes:
                flush("inplace")
            if prod is not None:
                current += prod[1]
            run_computes.append(i)
    flush()
    return FusionPlan(
        blocks=tuple(blocks), n_ops=len(ops), n_computes=n_computes,
        fence_splits=splits["fence"], hazard_splits=splits["hazard"],
        inplace_splits=splits["inplace"], peak_splits=splits["peak"])


def replay_stream(schedule_or_ops, fusion: FusionPlan) -> Tuple[Any, ...]:
    """The op order a fused replay actually executes: each block's
    computes in order, then its deferred frees, everything else in
    place.  By construction of :func:`plan_fusion` this stream passes
    :func:`schedules_equivalent` against the original."""
    ops = _ops_of(schedule_or_ops)
    first_of: Dict[int, FusedBlock] = {}
    covered: set = set()
    for b in fusion.blocks:
        first_of[min(b.op_indices)] = b
        covered.update(b.op_indices)
    out: List[Any] = []
    for i, op in enumerate(ops):
        b = first_of.get(i)
        if b is not None:
            out.extend(ops[j] for j in b.compute_indices)
            out.extend(ops[j] for j in b.free_indices)
        elif i not in covered:
            out.append(op)
    return tuple(out)


def verify_fusion(fusion: FusionPlan, schedule_or_ops, ordered=None,
                  plan=None, *, peak_bytes: Optional[int] = None
                  ) -> List[Diagnostic]:
    """Independently re-prove a :class:`FusionPlan` legal (the prover is
    not trusted to have been the planner): no block spans a transfer
    fence (``fusion_fence``), no deferred ``Free`` aliases a later
    producer in its block or crosses an in-place re-admission
    (``fusion_hazard``), and deferred residency never exceeds the packed
    peak (``fusion_peak``, overridable via ``peak_bytes`` for tests)."""
    from repro.core.plan import (Compute, Free, OptPrefetch, OptSwapOut,
                                 Prefetch, SwapOut)
    ops = _ops_of(schedule_or_ops)
    produced_at, inplace_eos, peak = _fusion_env(ops, ordered, plan)
    if peak_bytes is not None:
        peak = peak_bytes
    diags: List[Diagnostic] = []

    deferred_until: Dict[int, int] = {}   # free op index -> block end index
    for b in fusion.blocks:
        lo, hi = b.span()
        # membership comes from the typed sets, not the claimed
        # op_indices: a forged block cannot smuggle a transfer past the
        # fence scan by listing it as a "member"
        members = set(b.compute_indices) | set(b.free_indices)
        for i in range(lo, hi + 1):
            if i in members:
                continue
            op = ops[i]
            if isinstance(op, (SwapOut, Prefetch, OptSwapOut, OptPrefetch)):
                diags.append(Diagnostic(
                    SEV_ERROR, "fusion_fence",
                    f"block {b.index} [{lo},{hi}] spans {_describe(op)}: "
                    f"fusing across a transfer fence would move its issue "
                    f"point", op_index=i, tensor=op.tensor))
            else:
                diags.append(Diagnostic(
                    SEV_ERROR, "fusion_hazard",
                    f"block {b.index} [{lo},{hi}] spans foreign op "
                    f"{_describe(op)}", op_index=i,
                    tensor=getattr(op, "tensor", None)))
        # deferred-free vs later-in-block producer ranges
        ranges: List[Tuple[int, Tuple[int, int], str]] = []
        for fi in b.free_indices:
            off = ops[fi].device_offset
            if off >= 0:
                ranges.append((fi, (off, off + _align(ops[fi].nbytes)),
                               ops[fi].tensor))
            deferred_until[fi] = hi
        for ci in b.compute_indices:
            op = ops[ci]
            prod = produced_at.get(op.eo) if op.kind == "F" else None
            if prod is None:
                continue
            name, _nb, rng = prod
            if rng is None:
                continue
            for fi, (flo, fhi), ftensor in ranges:
                if fi < ci and not (fhi <= rng[0] or rng[1] <= flo):
                    diags.append(Diagnostic(
                        SEV_ERROR, "fusion_hazard",
                        f"block {b.index} defers Free({ftensor}) past the "
                        f"producer of {name}, which reuses bytes "
                        f"[{max(flo, rng[0])},{min(fhi, rng[1])})",
                        op_index=fi, tensor=ftensor))
        for ci in b.compute_indices[1:]:
            if ops[ci].eo in inplace_eos:
                diags.append(Diagnostic(
                    SEV_ERROR, "fusion_hazard",
                    f"block {b.index} spans the in-place re-admission at "
                    f"EO {ops[ci].eo}: the vacated-window edge must stay "
                    f"a block boundary", op_index=ci))

    # residency with deferrals: frees charge until their block end
    if peak is not None and ordered is not None:
        current = 0
        deferred: Dict[int, int] = {}   # release-at op index -> bytes
        high = 0
        for i, op in enumerate(ops):
            if isinstance(op, Compute):
                prod = produced_at.get(op.eo) if op.kind == "F" else None
                if prod is not None:
                    current += prod[1]
            elif isinstance(op, Prefetch):
                current += ordered.tensors[op.tensor].nbytes \
                    if op.tensor in ordered.tensors else op.nbytes
            elif isinstance(op, SwapOut):
                current -= ordered.tensors[op.tensor].nbytes \
                    if op.tensor in ordered.tensors else op.nbytes
            elif isinstance(op, Free):
                nb = ordered.tensors[op.tensor].nbytes \
                    if op.tensor in ordered.tensors else op.nbytes
                until = deferred_until.get(i)
                if until is not None and until > i:
                    deferred[until] = deferred.get(until, 0) + nb
                else:
                    current -= nb
            high = max(high, current)
            current -= deferred.pop(i, 0)
        if high > peak:
            diags.append(Diagnostic(
                SEV_ERROR, "fusion_peak",
                f"deferred frees push residency to {high} bytes, over the "
                f"packed activation residency peak ({peak})",
                offsets=(high, peak)))
    return diags


def deps_summary(schedule_or_ops, ordered=None, plan=None) -> Dict[str, Any]:
    """The ``report()["deps"]`` payload: dependence-edge counts, the
    fusion plan summary and the per-transfer static slack table."""
    ops = _ops_of(schedule_or_ops)
    graph = build_dependence_graph(ops, ordered, plan)
    fusion = plan_fusion(ops, ordered, plan)
    slack = transfer_slack(ops)
    return {
        "n_ops": len(ops),
        "edges": graph.edge_counts(),
        "fusion": fusion.summary(),
        "min_prefetch_slack_phases": slack["min_prefetch_slack_phases"],
        "mean_prefetch_slack_phases": slack["mean_prefetch_slack_phases"],
    }
