"""Static plan verifier: prove memory-safety *and* order-equivalence of a
lowered ExecutionSchedule.

The stack's central claim — proactive swapping cuts peak memory *without
sacrificing correctness* — rests on every planner/allocator/lowering
combination emitting a sound schedule.  Until now that soundness was only
sampled at run time (grads vs ``jax.grad``, high-water assertions); this
package proves it *before any op executes*, the way On-Device Training
Under 256KB Memory proves its compile-time memory contracts.

A registry of independent checker passes (:data:`CHECKS`, mirroring the
``PLANNERS``/``BACKENDS`` registries) walks the
:class:`repro.core.plan.ExecutionSchedule` together with the packed
:class:`repro.core.planner.Plan` arenas and emits structured
:class:`Diagnostic` records.  The passes and the check ids they emit:

======================  =====================================================
registry pass           invariant proven (check ids emitted)
======================  =====================================================
``use_before_resident`` every access of a planned ``X:`` tensor is covered
                        by its producing phase or a completed ``Prefetch`` —
                        the static analogue of the async backend's consumer
                        fence (``use_before_resident``)
``transfer_race``       no ``Prefetch`` is issued before its ``SwapOut``
                        retired, no two host slots overlap while both swap
                        windows are live, and no prefetch target overlaps a
                        still-resident tensor's device bytes
                        (``transfer_race``)
``arena_alias``         interval-overlap sweep over the device arena *and*
                        the host pool, plus op<->placement offset
                        consistency — subsumes ``Plan.validate()``
                        (``arena_alias``)
``heap``                every ``SwapOut``/``Free`` pairs with a live
                        residency and all heap bytes are freed by schedule
                        end (``double_free``, ``leak``)
``budget``              the high-water of the statically simulated offsets
                        stays within the packed ``peak_bytes`` /
                        ``host_pool_bytes`` and every offset is
                        ALIGN-aligned (``budget``, ``alignment``)
``inplace_prefetch``    an in-place prefetch moves no data (no DMA ops) and
                        no conflicting writer touched its bytes in the
                        vacated window (``inplace_prefetch``)
``optim_region``        optimizer-state transfers replay the optimizer
                        plan's packed offsets, stay inside the opt
                        device/host arenas, honour ALIGN, and every slot
                        pairs one ``OptPrefetch`` with one later
                        ``OptSwapOut`` (``optim_region``, ``alignment``)
``deps``                the op list is a linear extension of its own
                        happens-before dependence DAG (:mod:`.deps`): every
                        data / arena-reuse edge respected (``dep_edge``),
                        every transfer fence respected
                        (``dep_transfer_fence``), op multiset intact
                        (``dep_stream``)
======================  =====================================================

:mod:`repro.core.verify.deps` additionally proves *fusion legality*: a
:class:`FusionPlan` produced by :func:`plan_fusion` may only group
``Compute`` runs that cross no transfer fence (``fusion_fence``), defer no
``Free`` whose bytes a later producer in the block reuses and span no
in-place-prefetch window edge (``fusion_hazard``), and never push deferred
residency past the packed peak (``fusion_peak``).
:func:`schedules_equivalent` proves a permuted or fused replay stream
preserves every dependence edge of the verifier-signed original — the
admission gate of the ``jit_blocks`` executor backend.

Entry points: :func:`verify_plan` (a :class:`CompiledMemoryPlan`, either
path), :func:`verify_schedule` (raw graph-path pieces).  ``compile_plan``
runs the verifier according to ``MemoryPlanConfig.verify``
(``"error"|"warn"|"off"``) and folds the report into
``CompiledMemoryPlan.report()["verify"]``; executor backends refuse to
replay a schedule that has not been verified (see
:func:`mark_verified` / :func:`is_verified`), and their debug sanitizer
mode cross-checks runtime residency against :class:`StaticResidencyModel`
op by op.
"""

from repro.core.verify.checks import (CHECKS, SEV_ERROR, SEV_WARNING,
                                      CheckContext, Diagnostic,
                                      ScheduleVerificationError, VerifyReport,
                                      SessionArenaSlice,
                                      StaticResidencyModel, _walk_residency,
                                      check_arena_alias, check_budget,
                                      check_heap, check_inplace_prefetch,
                                      check_optim_region,
                                      check_transfer_race,
                                      check_use_before_resident, is_verified,
                                      mark_verified,
                                      plan_aliasing_diagnostics,
                                      verify_interleaving, verify_model_plan,
                                      verify_plan, verify_schedule)
from repro.core.verify.deps import (DepEdge, DependenceGraph, FusedBlock,
                                    FusionPlan, build_dependence_graph,
                                    check_deps, deps_summary, plan_fusion,
                                    replay_stream, schedules_equivalent,
                                    transfer_slack, verify_fusion)

# The deps pass joins the registry here (not in checks.py) so the module
# split stays acyclic: deps.py builds on checks.py's Diagnostic machinery.
CHECKS.setdefault("deps", check_deps)

__all__ = [
    "CHECKS",
    "SEV_ERROR",
    "SEV_WARNING",
    "CheckContext",
    "DepEdge",
    "DependenceGraph",
    "Diagnostic",
    "FusedBlock",
    "FusionPlan",
    "ScheduleVerificationError",
    "SessionArenaSlice",
    "StaticResidencyModel",
    "VerifyReport",
    "build_dependence_graph",
    "check_arena_alias",
    "check_budget",
    "check_deps",
    "check_heap",
    "check_inplace_prefetch",
    "check_optim_region",
    "check_transfer_race",
    "check_use_before_resident",
    "deps_summary",
    "is_verified",
    "mark_verified",
    "plan_aliasing_diagnostics",
    "plan_fusion",
    "replay_stream",
    "schedules_equivalent",
    "transfer_slack",
    "verify_fusion",
    "verify_interleaving",
    "verify_model_plan",
    "verify_plan",
    "verify_schedule",
]
