"""The memory-safety checker passes of :mod:`repro.core.verify`.

The stack's central claim — proactive swapping cuts peak memory *without
sacrificing correctness* — rests on every planner/allocator/lowering
combination emitting a sound schedule.  Until now that soundness was only
sampled at run time (grads vs ``jax.grad``, high-water assertions); these
passes prove it *before any op executes*, the way On-Device Training
Under 256KB Memory proves its compile-time memory contracts.

A registry of independent checker passes (:data:`CHECKS`, mirroring the
``PLANNERS``/``BACKENDS`` registries) walks the
:class:`repro.core.plan.ExecutionSchedule` together with the packed
:class:`repro.core.planner.Plan` arenas and emits structured
:class:`Diagnostic` records.  The authoritative check-id table lives in
the package docstring (:mod:`repro.core.verify`); the dependence /
fusion-legality prover is :mod:`repro.core.verify.deps`, which joins the
registry as the ``deps`` pass from the package ``__init__``.

Entry points: :func:`verify_plan` (a :class:`CompiledMemoryPlan`, either
path), :func:`verify_schedule` (raw graph-path pieces).  ``compile_plan``
runs the verifier according to ``MemoryPlanConfig.verify``
(``"error"|"warn"|"off"``) and folds the report into
``CompiledMemoryPlan.report()["verify"]``; executor backends refuse to
replay a schedule that has not been verified (see
:func:`mark_verified` / :func:`is_verified`), and their debug sanitizer
mode cross-checks runtime residency against :class:`StaticResidencyModel`
op by op.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from typing import (Any, Callable, Dict, Iterable, List, Optional, Set,
                    Tuple)

from repro.core.execution_order import OrderedTensors
from repro.core.planner import (ALIGN, Plan, Placement, SwapAwarePlan,
                                _align)

SEV_ERROR = "error"
SEV_WARNING = "warning"


class ScheduleVerificationError(AssertionError):
    """A schedule failed static verification in ``"error"`` mode.

    Subclasses :class:`AssertionError` so call sites that guarded the old
    ``Plan.validate()`` assertions keep catching verifier failures."""

    def __init__(self, diagnostics: Tuple["Diagnostic", ...]):
        self.diagnostics = diagnostics
        lines = [d.render() for d in diagnostics[:8]]
        more = len(diagnostics) - len(lines)
        if more > 0:
            lines.append(f"... and {more} more")
        super().__init__(
            "schedule failed static verification "
            f"({len(diagnostics)} error diagnostic(s)):\n  "
            + "\n  ".join(lines))


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One structured finding of a checker pass."""

    severity: str                      # "error" | "warning"
    check: str                         # check id (see module docstring)
    message: str                       # human-readable explanation
    op_index: Optional[int] = None     # index into ExecutionSchedule.ops
    tensor: Optional[str] = None       # tensor the finding is about
    offsets: Tuple[int, ...] = ()      # byte offsets involved

    def render(self) -> str:
        where = "" if self.op_index is None else f" op[{self.op_index}]"
        who = "" if self.tensor is None else f" {self.tensor}"
        return f"[{self.severity}:{self.check}]{where}{who}: {self.message}"


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """What one verifier run proved (or failed to prove)."""

    diagnostics: Tuple[Diagnostic, ...]
    checks_run: Tuple[str, ...]
    ops_scanned: int
    placements_scanned: int
    wall_time_s: float
    # per-pass wall time (check id -> seconds), recorded on BOTH entry
    # points so the cost of each pass — notably the O(T^2) deps sweep —
    # is visible in report()["verify"] / BENCH_swap.json
    check_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.errors()

    def errors(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics
                     if d.severity == SEV_ERROR)

    def warnings(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics
                     if d.severity == SEV_WARNING)

    def check_ids(self) -> Set[str]:
        return {d.check for d in self.diagnostics}

    def raise_if_errors(self) -> None:
        errs = self.errors()
        if errs:
            raise ScheduleVerificationError(errs)

    def summary(self) -> Dict[str, Any]:
        """The report()["verify"] / BENCH_swap.json row shape."""
        out: Dict[str, Any] = {
            "ok": self.ok,
            "errors": len(self.errors()),
            "warnings": len(self.warnings()),
            "checks_run": list(self.checks_run),
            "ops_scanned": self.ops_scanned,
            "placements_scanned": self.placements_scanned,
            "wall_time_s": self.wall_time_s,
            "check_wall_time_s": dict(self.check_seconds),
        }
        if self.diagnostics:
            out["diagnostics"] = [dataclasses.asdict(d)
                                  for d in self.diagnostics[:20]]
        return out


# ---------------------------------------------------------------------------
# Check context: everything a pass may inspect, derived once
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckContext:
    """One verification subject: the lowered ops plus their plan context."""

    ordered: OrderedTensors
    schedule: Any                      # OffloadSchedule | None
    plan: Any                          # SwapAwarePlan | Plan | None
    ops: Tuple[Any, ...]               # ExecutionSchedule.ops

    # derived fields (populated by build)
    decisions: Dict[str, Any] = dataclasses.field(default_factory=dict)
    activations: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, ordered: OrderedTensors, schedule, plan,
              lowered) -> "CheckContext":
        ctx = cls(ordered=ordered, schedule=schedule, plan=plan,
                  ops=tuple(lowered.ops) if lowered is not None else ())
        if schedule is not None:
            ctx.decisions = {d.name: d for d in schedule.decisions}
        ctx.activations = {
            t.name: t for t in ordered.planned_tensors()
            if t.name.startswith("X:")
        }
        return ctx

    # ------------------------------------------------------------- queries
    @property
    def swap_aware(self) -> bool:
        return isinstance(self.plan, SwapAwarePlan)

    @property
    def device_plan(self) -> Optional[Plan]:
        if self.swap_aware:
            return self.plan.device
        return self.plan if isinstance(self.plan, Plan) else None

    @property
    def host_plan(self) -> Optional[Plan]:
        return self.plan.host if self.swap_aware else None

    def residency_placements(self, name: str) -> Tuple[Placement, ...]:
        """Pre/post device placements for ``name`` (1 entry if unsplit)."""
        if self.swap_aware:
            rs = self.plan.residencies.get(name)
            if rs:
                return tuple(sorted(rs, key=lambda r: r.min_eo))
        dp = self.device_plan
        if dp is not None and name in dp.placements:
            return (dp.placements[name],)
        return ()

    def planned_device_offset(self, name: str, *, post: bool) -> int:
        rs = self.residency_placements(name)
        if not rs:
            return -1
        return rs[-1 if post else 0].offset

    def planned_host_offset(self, name: str) -> int:
        hp = self.host_plan
        if hp is not None:
            p = hp.placements.get(name + "@host")
            if p is not None:
                return p.offset
        return -1

    def aligned_nbytes(self, name: str) -> int:
        t = self.ordered.tensors.get(name)
        return _align(t.nbytes) if t is not None else 0

    def transfer_ops(self, name: str) -> List[Tuple[int, Any]]:
        """(op index, op) of every SwapOut/Prefetch naming ``name``."""
        from repro.core.plan import Prefetch, SwapOut
        return [(i, op) for i, op in enumerate(self.ops)
                if isinstance(op, (SwapOut, Prefetch))
                and op.tensor == name]

    def producer_eo(self, name: str) -> int:
        """The phase producing ``name`` (its first recorded access)."""
        t = self.ordered.tensors.get(name)
        return min(t.exec_orders) if t is not None and t.exec_orders else -1


# ---------------------------------------------------------------------------
# The checker passes
# ---------------------------------------------------------------------------

def check_use_before_resident(ctx: CheckContext) -> List[Diagnostic]:
    """Every recorded access of a planned ``X:`` tensor must land while the
    tensor is device-resident: between production and its ``SwapOut``, or at
    (or after) the ``read_eo`` its ``Prefetch`` guarantees — the static
    analogue of the async backend's consumer fence."""
    from repro.core.plan import Prefetch, SwapOut
    diags: List[Diagnostic] = []
    if not ctx.ops:
        return diags
    for name, t in ctx.activations.items():
        tops = sorted(ctx.transfer_ops(name), key=lambda e: e[1].eo)
        if not tops:
            continue
        for eo in t.exec_orders:
            # the most recent transfer at or before this access decides
            # residency: SwapOut -> gone, Prefetch -> back (readable once
            # the transfer's read_eo deadline passes)
            last = None
            for _, op in tops:
                if op.eo <= eo:
                    last = op
                else:
                    break
            if last is None or isinstance(last, SwapOut):
                if last is not None and eo > last.eo:
                    diags.append(Diagnostic(
                        SEV_ERROR, "use_before_resident",
                        f"read at EO {eo} while swapped out since EO "
                        f"{last.eo} with no prefetch in between",
                        tensor=name))
            elif isinstance(last, Prefetch) and eo < last.read_eo \
                    and eo > last.eo:
                diags.append(Diagnostic(
                    SEV_ERROR, "use_before_resident",
                    f"read at EO {eo} races the in-flight prefetch issued "
                    f"at EO {last.eo} (guaranteed complete only at EO "
                    f"{last.read_eo})", tensor=name))
    return diags


def check_transfer_race(ctx: CheckContext) -> List[Diagnostic]:
    """No transfer may race another: a prefetch must follow its own
    swap-out, host slots of concurrent swap windows must not overlap, and a
    prefetch target must not overlap a still-resident tensor's bytes."""
    from repro.core.plan import Prefetch, SwapOut
    diags: List[Diagnostic] = []

    # (a) per-tensor ordering: the prefetch re-reads what the swap-out
    # wrote, so it must be issued strictly after the swap-out's phase
    per_tensor: Dict[str, Dict[str, Tuple[int, Any]]] = {}
    for i, op in enumerate(ctx.ops):
        if isinstance(op, SwapOut):
            per_tensor.setdefault(op.tensor, {})["out"] = (i, op)
        elif isinstance(op, Prefetch):
            per_tensor.setdefault(op.tensor, {})["in"] = (i, op)
    for name, pair in per_tensor.items():
        if "in" in pair and "out" in pair:
            (oi, out), (pi, pin) = pair["out"], pair["in"]
            if pin.eo <= out.eo:
                diags.append(Diagnostic(
                    SEV_ERROR, "transfer_race",
                    f"prefetch at EO {pin.eo} issued before its swap-out "
                    f"(EO {out.eo}) retired", op_index=pi, tensor=name))
        elif "in" in pair and "out" not in pair:
            pi, pin = pair["in"]
            diags.append(Diagnostic(
                SEV_ERROR, "transfer_race",
                f"prefetch at EO {pin.eo} has no swap-out producing its "
                f"host copy", op_index=pi, tensor=name))

    # (b) host-slot overlap between concurrent swap windows
    windows = []
    for name, pair in per_tensor.items():
        if "in" not in pair or "out" not in pair:
            continue
        _, out = pair["out"]
        _, pin = pair["in"]
        if out.host_offset < 0:
            continue
        windows.append((name, out.eo, pin.read_eo, out.host_offset,
                        out.host_offset + _align(out.nbytes)))
    for i in range(len(windows)):
        for j in range(i + 1, len(windows)):
            a, b = windows[i], windows[j]
            time_overlap = not (a[2] < b[1] or b[2] < a[1])
            byte_overlap = not (a[4] <= b[3] or b[4] <= a[3])
            if time_overlap and byte_overlap:
                diags.append(Diagnostic(
                    SEV_ERROR, "transfer_race",
                    f"host slot [{a[3]},{a[4]}) of {a[0]} overlaps "
                    f"[{b[3]},{b[4]}) of {b[0]} while both swap windows "
                    f"are live", tensor=a[0], offsets=(a[3], b[3])))

    # (c) prefetch target vs still-resident device bytes, simulated over
    # the op list (catches reordered swap-outs the placements cannot see)
    for i, op, resident in _walk_residency(ctx):
        if not isinstance(op, Prefetch) or op.device_offset < 0:
            continue
        lo, hi = op.device_offset, op.device_offset + _align(op.nbytes)
        for other, (ooff, oend) in resident.items():
            if other == op.tensor or ooff < 0:
                continue
            if not (oend <= lo or hi <= ooff):
                diags.append(Diagnostic(
                    SEV_ERROR, "transfer_race",
                    f"prefetch target [{lo},{hi}) overlaps still-resident "
                    f"{other} [{ooff},{oend}) at EO {op.eo}",
                    op_index=i, tensor=op.tensor, offsets=(lo, ooff)))
    return diags


def check_arena_alias(ctx: CheckContext) -> List[Diagnostic]:
    """Interval-overlap sweep over both packed arenas, plus op offset <->
    placement consistency.  Subsumes (and backs) ``Plan.validate()``."""
    from repro.core.plan import Free, Prefetch, SwapOut
    diags: List[Diagnostic] = []
    dp, hp = ctx.device_plan, ctx.host_plan
    if dp is not None:
        diags.extend(d for d in plan_aliasing_diagnostics(dp, "device")
                     if d.check == "arena_alias")
    if hp is not None:
        diags.extend(d for d in plan_aliasing_diagnostics(hp, "host")
                     if d.check == "arena_alias")
    if dp is None:
        return diags
    for i, op in enumerate(ctx.ops):
        if isinstance(op, (SwapOut, Prefetch)):
            post = isinstance(op, Prefetch)
            want = ctx.planned_device_offset(op.tensor, post=post)
            if op.device_offset != want:
                diags.append(Diagnostic(
                    SEV_ERROR, "arena_alias",
                    f"{type(op).__name__} device offset {op.device_offset} "
                    f"diverges from the packed placement ({want})",
                    op_index=i, tensor=op.tensor,
                    offsets=(op.device_offset, want)))
            want_h = ctx.planned_host_offset(op.tensor)
            if op.host_offset != want_h:
                diags.append(Diagnostic(
                    SEV_ERROR, "arena_alias",
                    f"{type(op).__name__} host offset {op.host_offset} "
                    f"diverges from the packed host slot ({want_h})",
                    op_index=i, tensor=op.tensor,
                    offsets=(op.host_offset, want_h)))
        elif isinstance(op, Free):
            want = ctx.planned_device_offset(op.tensor, post=True)
            if op.device_offset != want:
                diags.append(Diagnostic(
                    SEV_ERROR, "arena_alias",
                    f"Free device offset {op.device_offset} diverges from "
                    f"the packed placement ({want})",
                    op_index=i, tensor=op.tensor,
                    offsets=(op.device_offset, want)))
    return diags


def check_heap(ctx: CheckContext) -> List[Diagnostic]:
    """Heap discipline over the op list: swap-outs and frees must pair with
    a live residency, and every planned ``X:`` byte is freed by the end."""
    from repro.core.plan import Compute, Free, Prefetch, SwapOut
    diags: List[Diagnostic] = []
    if not ctx.ops:
        return diags
    produced_at = {name: ctx.producer_eo(name) for name in ctx.activations}
    alive: Set[str] = set()
    hosted: Set[str] = set()
    freed: Set[str] = set()
    for i, op in enumerate(ctx.ops):
        if isinstance(op, Compute):
            if op.kind != "F":
                continue
            owner = ctx.ordered.owner(f"X:{op.layer}")
            if owner in produced_at and produced_at[owner] == op.eo:
                alive.add(owner)
        elif isinstance(op, SwapOut):
            if op.tensor not in alive:
                diags.append(Diagnostic(
                    SEV_ERROR, "double_free",
                    f"swap-out at EO {op.eo} of a tensor with no live "
                    f"device residency", op_index=i, tensor=op.tensor))
            alive.discard(op.tensor)
            hosted.add(op.tensor)
        elif isinstance(op, Prefetch):
            if op.tensor not in hosted and op.tensor not in alive:
                diags.append(Diagnostic(
                    SEV_ERROR, "double_free",
                    f"prefetch at EO {op.eo} of a tensor with no host "
                    f"copy", op_index=i, tensor=op.tensor))
            hosted.discard(op.tensor)
            alive.add(op.tensor)
        elif isinstance(op, Free):
            if op.tensor not in alive and op.tensor not in hosted:
                diags.append(Diagnostic(
                    SEV_ERROR, "double_free",
                    f"free at EO {op.eo} of a tensor with no live "
                    f"residency (double free?)", op_index=i,
                    tensor=op.tensor))
            alive.discard(op.tensor)
            hosted.discard(op.tensor)
            freed.add(op.tensor)
    for name in sorted(set(ctx.activations) - freed):
        diags.append(Diagnostic(
            SEV_ERROR, "leak",
            "no Free op releases this tensor's arena bytes by schedule "
            "end", tensor=name))
    for name in sorted(hosted):
        diags.append(Diagnostic(
            SEV_ERROR, "leak",
            "host-pool copy never retired by schedule end", tensor=name))
    return diags


def check_budget(ctx: CheckContext) -> List[Diagnostic]:
    """Statically simulate the op offsets: the device high-water must stay
    within the packed ``peak_bytes``, host slots within
    ``host_pool_bytes``, and every offset must be ALIGN-aligned."""
    from repro.core.plan import Free, Prefetch, SwapOut
    diags: List[Diagnostic] = []
    dp, hp = ctx.device_plan, ctx.host_plan
    # placement-level bounds + alignment over both packed arenas
    if dp is not None:
        diags.extend(d for d in plan_aliasing_diagnostics(dp, "device")
                     if d.check in ("budget", "alignment"))
    if hp is not None:
        diags.extend(d for d in plan_aliasing_diagnostics(hp, "host")
                     if d.check in ("budget", "alignment"))
    arena = dp.arena_bytes if dp is not None else None
    high = 0
    for op in ctx.ops:
        if isinstance(op, Prefetch) and op.device_offset >= 0:
            high = max(high, op.device_offset + _align(op.nbytes))
    if arena is not None and high > arena:
        diags.append(Diagnostic(
            SEV_ERROR, "budget",
            f"simulated device high-water {high} exceeds the packed arena "
            f"peak {arena}", offsets=(high, arena)))
    if hp is not None:
        for i, op in enumerate(ctx.ops):
            if isinstance(op, SwapOut) and op.host_offset >= 0:
                end = op.host_offset + _align(op.nbytes)
                if end > hp.arena_bytes:
                    diags.append(Diagnostic(
                        SEV_ERROR, "budget",
                        f"host slot end {end} exceeds the packed host pool "
                        f"({hp.arena_bytes} bytes)", op_index=i,
                        tensor=op.tensor, offsets=(op.host_offset,)))
    for i, op in enumerate(ctx.ops):
        if isinstance(op, (SwapOut, Prefetch, Free)):
            for off in (op.device_offset,
                        getattr(op, "host_offset", -1)):
                if off > 0 and off % ALIGN != 0:
                    diags.append(Diagnostic(
                        SEV_ERROR, "alignment",
                        f"offset {off} violates ALIGN={ALIGN}",
                        op_index=i, tensor=op.tensor, offsets=(off,)))
    return diags


def check_inplace_prefetch(ctx: CheckContext) -> List[Diagnostic]:
    """An in-place prefetch moves no data: it must emit no DMA ops, hold no
    host slot, keep a stable offset, and no conflicting writer may touch
    its bytes during the vacated window."""
    diags: List[Diagnostic] = []
    if not ctx.swap_aware:
        return diags
    for name, d in ctx.decisions.items():
        if not d.inplace:
            continue
        for i, op in ctx.transfer_ops(name):
            diags.append(Diagnostic(
                SEV_ERROR, "inplace_prefetch",
                f"in-place prefetch must lower to no DMA ops, found "
                f"{type(op).__name__} at EO {op.eo}", op_index=i,
                tensor=name))
        if ctx.planned_host_offset(name) >= 0:
            diags.append(Diagnostic(
                SEV_ERROR, "inplace_prefetch",
                "in-place prefetch must not hold a host-pool slot",
                tensor=name))
        rs = ctx.residency_placements(name)
        if len(rs) != 2:
            continue
        pre, post = rs
        if pre.offset != post.offset:
            diags.append(Diagnostic(
                SEV_ERROR, "inplace_prefetch",
                f"pre offset {pre.offset} != post offset {post.offset}: "
                f"the bytes cannot have survived in place", tensor=name,
                offsets=(pre.offset, post.offset)))
            continue
        lo, hi = pre.offset, pre.offset + post.nbytes
        for p in ctx.device_plan.placements.values():
            if p is pre or p is post:
                continue
            if p.end <= lo or hi <= p.offset:
                continue
            if p.min_eo < post.min_eo and p.max_eo > pre.max_eo:
                diags.append(Diagnostic(
                    SEV_ERROR, "inplace_prefetch",
                    f"{p.name} writes [{p.offset},{p.end}) inside the "
                    f"vacated window ({pre.max_eo},{post.min_eo}) — the "
                    f"in-place bytes do not survive", tensor=name,
                    offsets=(pre.offset, p.offset)))
    return diags


def check_optim_region(ctx: CheckContext) -> List[Diagnostic]:
    """Optimizer-state transfer ops must replay exactly what the optimizer
    plan packed: offsets match the opt device/host placements, stay inside
    the opt arenas, honour ALIGN, and every slot pairs one ``OptPrefetch``
    with one later ``OptSwapOut`` (the working buffer is read before it is
    re-quantized back out — the reverse of the activation pairing)."""
    from repro.core.plan import OptPrefetch, OptSwapOut
    diags: List[Diagnostic] = []
    opt_ops = [(i, op) for i, op in enumerate(ctx.ops)
               if isinstance(op, (OptPrefetch, OptSwapOut))]
    if not opt_ops:
        return diags
    optim = getattr(ctx.plan, "optim", None)
    if optim is None:
        diags.append(Diagnostic(
            SEV_ERROR, "optim_region",
            f"{len(opt_ops)} optimizer transfer op(s) but the plan carries "
            f"no optimizer plan to validate them against",
            op_index=opt_ops[0][0], tensor=opt_ops[0][1].tensor))
        return diags
    per_tensor: Dict[str, Dict[str, Tuple[int, Any]]] = {}
    for i, op in opt_ops:
        kind = "in" if isinstance(op, OptPrefetch) else "out"
        per_tensor.setdefault(op.tensor, {})[kind] = (i, op)
        # placement consistency: device working buffer + host slot
        dpl = optim.device.placements.get(op.tensor)
        if dpl is None:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                "no packed optimizer device placement for this slot",
                op_index=i, tensor=op.tensor))
            continue
        if op.device_offset != dpl.offset:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"{type(op).__name__} device offset {op.device_offset} "
                f"diverges from the packed opt placement ({dpl.offset})",
                op_index=i, tensor=op.tensor,
                offsets=(op.device_offset, dpl.offset)))
        hpl = optim.host.placements.get(op.tensor + "@host")
        if hpl is None:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                "no packed optimizer host slot for this tensor",
                op_index=i, tensor=op.tensor))
            continue
        if op.host_offset != hpl.offset:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"{type(op).__name__} host offset {op.host_offset} "
                f"diverges from the packed opt host slot ({hpl.offset})",
                op_index=i, tensor=op.tensor,
                offsets=(op.host_offset, hpl.offset)))
        if op.host_nbytes > hpl.nbytes:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"compressed copy ({op.host_nbytes} B) overflows its "
                f"packed host slot ({hpl.nbytes} B)",
                op_index=i, tensor=op.tensor,
                offsets=(op.host_offset,)))
        # bounds + alignment against the *opt* arenas (their own address
        # spaces — never mixed with the activation arenas)
        if op.device_offset + _align(op.nbytes) > optim.device.arena_bytes:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"working buffer end "
                f"{op.device_offset + _align(op.nbytes)} exceeds the opt "
                f"device arena ({optim.device.arena_bytes} B)",
                op_index=i, tensor=op.tensor, offsets=(op.device_offset,)))
        if op.host_offset + _align(op.host_nbytes) > optim.host.arena_bytes:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"host slot end {op.host_offset + _align(op.host_nbytes)} "
                f"exceeds the opt host pool ({optim.host.arena_bytes} B)",
                op_index=i, tensor=op.tensor, offsets=(op.host_offset,)))
        for off in (op.device_offset, op.host_offset):
            if off > 0 and off % ALIGN != 0:
                diags.append(Diagnostic(
                    SEV_ERROR, "alignment",
                    f"opt offset {off} violates ALIGN={ALIGN}",
                    op_index=i, tensor=op.tensor, offsets=(off,)))
    # pairing: one prefetch strictly before one swap-out per slot
    for name, pair in sorted(per_tensor.items()):
        if "in" not in pair:
            i, _ = pair["out"]
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                "OptSwapOut with no OptPrefetch admitting the working "
                "state it re-quantizes", op_index=i, tensor=name))
        elif "out" not in pair:
            i, _ = pair["in"]
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                "OptPrefetch with no OptSwapOut retiring the working "
                "buffer", op_index=i, tensor=name))
        elif pair["in"][0] > pair["out"][0]:
            diags.append(Diagnostic(
                SEV_ERROR, "optim_region",
                f"OptSwapOut at op[{pair['out'][0]}] precedes its "
                f"OptPrefetch at op[{pair['in'][0]}]: the swap-out would "
                f"re-quantize an unwritten working buffer",
                op_index=pair["out"][0], tensor=name))
    return diags


# The checker registry: independent passes, run in order.  Mirrors the
# PLANNERS / BACKENDS registries — register a new invariant by adding an
# entry; verify_schedule runs every pass (or the caller's subset).
CHECKS: Dict[str, Callable[[CheckContext], List[Diagnostic]]] = {
    "use_before_resident": check_use_before_resident,
    "transfer_race": check_transfer_race,
    "arena_alias": check_arena_alias,
    "heap": check_heap,
    "budget": check_budget,
    "inplace_prefetch": check_inplace_prefetch,
    "optim_region": check_optim_region,
}


# ---------------------------------------------------------------------------
# Shared static simulation
# ---------------------------------------------------------------------------

def _walk_residency(ctx: CheckContext):
    """Walk the op list maintaining the statically known device residency.

    Yields ``(op_index, op, resident)`` where ``resident`` maps each
    device-resident planned ``X:`` tensor to its ``[offset, end)`` byte
    interval *before* the op takes effect.  Production happens at the
    producing layer's F phase; ``SwapOut``/``Free`` evict; ``Prefetch``
    re-admits at the op's target offset.  Tensors without a placement
    (offset < 0) are tracked with a degenerate interval so heap-style
    checks still see them."""
    from repro.core.plan import Compute, Free, Prefetch, SwapOut
    produced_at = {name: ctx.producer_eo(name) for name in ctx.activations}
    resident: Dict[str, Tuple[int, int]] = {}
    for i, op in enumerate(ctx.ops):
        yield i, op, resident
        if isinstance(op, Compute):
            if op.kind != "F":
                continue
            owner = ctx.ordered.owner(f"X:{op.layer}")
            if owner in produced_at and produced_at[owner] == op.eo \
                    and owner not in resident:
                off = ctx.planned_device_offset(owner, post=False)
                end = off + ctx.aligned_nbytes(owner) if off >= 0 else off
                resident[owner] = (off, end)
        elif isinstance(op, SwapOut):
            resident.pop(op.tensor, None)
        elif isinstance(op, Prefetch):
            off = op.device_offset
            end = off + _align(op.nbytes) if off >= 0 else off
            resident[op.tensor] = (off, end)
        elif isinstance(op, Free):
            resident.pop(op.tensor, None)


class StaticResidencyModel:
    """The verifier's residency model, steppable op by op at run time.

    The executor backends' debug sanitizer walks this model alongside the
    real :class:`repro.core.exec.store.ActivationStore` and cross-checks
    that the set of device-resident planned ``X:`` owners matches the
    static prediction after every replayed op — any divergence means the
    runtime wandered off the verified schedule."""

    def __init__(self, ordered: OrderedTensors):
        self.ordered = ordered
        self.resident: Set[str] = set()
        self._produced_at = {
            t.name: (min(t.exec_orders) if t.exec_orders else -1)
            for t in ordered.planned_tensors()
            if t.name.startswith("X:")
        }

    def step(self, op) -> None:
        from repro.core.plan import Compute, Free, Prefetch, SwapOut
        if isinstance(op, Compute):
            if op.kind != "F":
                return
            owner = self.ordered.owner(f"X:{op.layer}")
            if self._produced_at.get(owner) == op.eo:
                self.resident.add(owner)
        elif isinstance(op, SwapOut):
            self.resident.discard(op.tensor)
        elif isinstance(op, Prefetch):
            self.resident.add(op.tensor)
        elif isinstance(op, Free):
            self.resident.discard(op.tensor)

    def cross_check(self, store_alive: Iterable[str], op_index: int) -> None:
        actual = {n for n in store_alive if n in self._produced_at}
        if actual != self.resident:
            missing = sorted(self.resident - actual)
            extra = sorted(actual - self.resident)
            raise AssertionError(
                f"sanitizer: runtime residency diverged from the static "
                f"model after op[{op_index}]: missing={missing} "
                f"extra={extra}")


# ---------------------------------------------------------------------------
# Plan.validate() substrate: the aliasing sweep as diagnostics
# ---------------------------------------------------------------------------

def plan_aliasing_diagnostics(plan: Plan,
                              arena: str = "device") -> List[Diagnostic]:
    """The interval-overlap/bounds/alignment sweep over one packed arena,
    as structured diagnostics.  ``Plan.validate()`` delegates here and
    raises on the first finding, preserving its historical contract."""
    diags: List[Diagnostic] = []
    ps = list(plan.placements.values())
    for i in range(len(ps)):
        for j in range(i + 1, len(ps)):
            a, b = ps[i], ps[j]
            lifetimes_overlap = not (a.max_eo < b.min_eo
                                     or b.max_eo < a.min_eo)
            bytes_overlap = not (a.end <= b.offset or b.end <= a.offset)
            if lifetimes_overlap and bytes_overlap:
                diags.append(Diagnostic(
                    SEV_ERROR, "arena_alias",
                    f"overlap: {a.name} [{a.offset},{a.end}) "
                    f"eo[{a.min_eo},{a.max_eo}] vs {b.name} "
                    f"[{b.offset},{b.end}) eo[{b.min_eo},{b.max_eo}]",
                    tensor=a.name, offsets=(a.offset, b.offset)))
    for p in ps:
        if p.end > plan.arena_bytes:
            diags.append(Diagnostic(
                SEV_ERROR, "budget", f"{p.name} exceeds arena",
                tensor=p.name, offsets=(p.offset,)))
        if p.offset % ALIGN != 0:
            diags.append(Diagnostic(
                SEV_ERROR, "alignment",
                f"{p.name} at offset {p.offset} violates ALIGN={ALIGN}",
                tensor=p.name, offsets=(p.offset,)))
    return diags


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def verify_schedule(ordered: OrderedTensors, schedule, plan, lowered, *,
                    checks: Optional[Iterable[str]] = None) -> VerifyReport:
    """Run the checker registry over one lowered graph-path plan.

    ``checks`` restricts the passes (default: all of :data:`CHECKS`).
    Returns the :class:`VerifyReport`; raising on errors is the caller's
    policy (``MemoryPlanConfig.verify``)."""
    t0 = time.perf_counter()
    ctx = CheckContext.build(ordered, schedule, plan, lowered)
    names = tuple(checks) if checks is not None else tuple(CHECKS)
    diags: List[Diagnostic] = []
    check_seconds: Dict[str, float] = {}
    for name in names:
        try:
            checker = CHECKS[name]
        except KeyError:
            raise ValueError(
                f"unknown verifier check {name!r}: choose from "
                f"{', '.join(sorted(CHECKS))}") from None
        t_pass = time.perf_counter()
        diags.extend(checker(ctx))
        check_seconds[name] = time.perf_counter() - t_pass
    placements = 0
    if ctx.device_plan is not None:
        placements += len(ctx.device_plan.placements)
    if ctx.host_plan is not None:
        placements += len(ctx.host_plan.placements)
    return VerifyReport(
        diagnostics=tuple(diags), checks_run=names,
        ops_scanned=len(ctx.ops), placements_scanned=placements,
        wall_time_s=time.perf_counter() - t0,
        check_seconds=check_seconds)


def verify_model_plan(cp) -> VerifyReport:
    """The model-config path's static contract: the knapsack's kept bytes
    must respect the per-layer HBM budget it was solved under."""
    t0 = time.perf_counter()
    diags: List[Diagnostic] = []
    budget = cp.config.remat_budget_bytes
    if budget is None:
        budget = getattr(cp.model_config, "remat_budget_bytes", None)
    rp = cp.remat_plan
    if rp is not None and budget is not None \
            and rp.saved_bytes_per_layer > budget:
        diags.append(Diagnostic(
            SEV_ERROR, "budget",
            f"kept intermediates ({rp.saved_bytes_per_layer} B/layer) "
            f"exceed the per-layer HBM budget ({budget} B)",
            offsets=(rp.saved_bytes_per_layer, budget)))
    dt = time.perf_counter() - t0
    return VerifyReport(
        diagnostics=tuple(diags), checks_run=("budget",),
        ops_scanned=0, placements_scanned=0,
        wall_time_s=dt, check_seconds={"budget": dt})


def verify_plan(cp, *, checks: Optional[Iterable[str]] = None
                ) -> VerifyReport:
    """Verify a :class:`CompiledMemoryPlan` (either compile path)."""
    if cp.source == "graph":
        return verify_schedule(cp.ordered, cp.schedule, cp.plan,
                               cp.lowered, checks=checks)
    return verify_model_plan(cp)


# ---------------------------------------------------------------------------
# Cross-session interleaving legality
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionArenaSlice:
    """One admitted session's claim on the shared device arena.

    The phase-interleaved scheduler (:mod:`repro.serve.scheduler`) runs N
    sessions' plans concurrently over one physical arena; each session's
    plan packs its own offsets from 0 inside a share starting at
    ``base_offset``.  Interleaving is alias-free iff the shares are
    pairwise disjoint intervals and every plan fits inside its share —
    exactly what :func:`verify_interleaving` proves.
    """

    session: str                 # session/user id
    qos: str                     # admission QoS class name
    base_offset: int             # share start in the physical arena
    share_bytes: int             # share size (admission-priced)
    peak_bytes: int              # the session plan's packed device peak

    @property
    def end(self) -> int:
        return self.base_offset + self.share_bytes


def verify_interleaving(slices) -> VerifyReport:
    """Prove N admitted sessions may interleave on one device arena.

    Emits ``cross_session_arena`` diagnostics when any share starts at a
    negative offset, any session's packed peak exceeds its share (its ops
    would write past the share's end), or any two shares' byte intervals
    overlap (one session's swaps would alias another's live activations).
    This check judges the *admission state*, not a single lowered
    schedule, so it lives outside the per-schedule :data:`CHECKS`
    registry — the scheduler runs it over the live slice set before any
    cursor advances, and the mutation harness forges overlapping slices
    against it (class 12).
    """
    t0 = time.perf_counter()
    sl = sorted(slices, key=lambda s: (s.base_offset, s.session))
    diags: List[Diagnostic] = []
    for s in sl:
        if s.base_offset < 0:
            diags.append(Diagnostic(
                SEV_ERROR, "cross_session_arena",
                f"session {s.session!r} ({s.qos}) share starts at negative "
                f"offset {s.base_offset}",
                tensor=s.session, offsets=(s.base_offset,)))
        if s.peak_bytes > s.share_bytes:
            diags.append(Diagnostic(
                SEV_ERROR, "cross_session_arena",
                f"session {s.session!r} ({s.qos}) plan peak "
                f"{s.peak_bytes} B exceeds its arena share "
                f"{s.share_bytes} B",
                tensor=s.session,
                offsets=(s.base_offset, s.peak_bytes, s.share_bytes)))
    for a, b in zip(sl, sl[1:]):
        if b.base_offset < a.end:
            diags.append(Diagnostic(
                SEV_ERROR, "cross_session_arena",
                f"arena shares overlap: {a.session!r} ({a.qos}) "
                f"[{a.base_offset},{a.end}) vs {b.session!r} ({b.qos}) "
                f"[{b.base_offset},{b.end})",
                tensor=a.session,
                offsets=(a.base_offset, a.end, b.base_offset, b.end)))
    dt = time.perf_counter() - t0
    return VerifyReport(
        diagnostics=tuple(diags), checks_run=("cross_session_arena",),
        ops_scanned=0, placements_scanned=len(sl),
        wall_time_s=dt, check_seconds={"cross_session_arena": dt})


# ---------------------------------------------------------------------------
# Verified-schedule registry (the backends' admission check)
# ---------------------------------------------------------------------------

# Schedules that passed verification with zero errors.  Executor backends
# consult this before replaying: an unverified schedule is verified on the
# spot and refused if unsound (see _ReplayBackend.run).  Keyed by object
# identity (frozen dataclasses compare by value, and a verdict belongs to
# the exact compiled object, not to look-alikes); weak values, so a
# schedule's entry dies with the schedule.
_VERIFIED: "weakref.WeakValueDictionary" = weakref.WeakValueDictionary()


def mark_verified(lowered) -> None:
    _VERIFIED[id(lowered)] = lowered


def is_verified(lowered) -> bool:
    return _VERIFIED.get(id(lowered)) is lowered
