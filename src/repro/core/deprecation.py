"""Call-site-deduplicated deprecation warnings.

The deprecated shims (``StepBundle.remat_plan``, the ``offload_dropped``
alias, the old ``repro.core`` free-function re-exports) sit on paths that
run once per training step or once per compile — warning on *every*
invocation buries the signal.  :func:`warn_once` warns once per call site
(filename + line + message) per process instead.

The dedup defers to the active warning filters: when the first filter
matching the warning says ``"always"`` or ``"error"`` — which is what
``pytest.warns`` / ``recwarn`` install, and what ``-W always`` requests —
every invocation warns, so tests can keep asserting the warnings are
alive with ``pytest.warns`` (and parametrized tests re-triggering the
same call site keep seeing them).
"""

from __future__ import annotations

import sys
import warnings
from typing import Set, Tuple, Type

_seen: Set[Tuple[str, int, str, type]] = set()


def _always_shown(category: Type[Warning], text: str) -> bool:
    """Whether the first matching filter forces the warning through.

    Mirrors the stdlib resolution order over ``warnings.filters`` for the
    filters we can evaluate here: message pattern + category subclass.
    Module- or line-scoped filters cannot be matched without the caller's
    module, so they are skipped rather than guessed — a module-specific
    ``ignore`` ahead of a global ``always`` (pytest's default) must not
    shadow it and re-enable the dedup."""
    for action, msg, cat, module, lineno in warnings.filters:
        if module is not None or lineno != 0:
            continue
        if not issubclass(category, cat):
            continue
        if msg is not None and not msg.match(text):
            continue
        return action in ("always", "error")
    return False


def warn_once(message: str, category: Type[Warning] = DeprecationWarning,
              *, stacklevel: int = 2) -> None:
    """Issue ``message`` at most once per call site.

    ``stacklevel`` follows :func:`warnings.warn` semantics relative to the
    function calling ``warn_once``: 2 (the default) attributes the warning
    to that function's caller — the deprecated shim's call site, which is
    also the dedup key."""
    try:
        frame = sys._getframe(stacklevel)
        key = (frame.f_code.co_filename, frame.f_lineno, str(message),
               category)
    except ValueError:   # stack shallower than stacklevel: no site to key on
        warnings.warn(message, category, stacklevel=stacklevel + 1)
        return
    if key in _seen and not _always_shown(category, str(message)):
        return
    _seen.add(key)
    warnings.warn(message, category, stacklevel=stacklevel + 1)


def reset_seen_call_sites() -> None:
    """Forget every deduped call site (test isolation hook)."""
    _seen.clear()
