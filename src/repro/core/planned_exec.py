"""Compatibility shim over the executor subsystem :mod:`repro.core.exec`.

The monolithic layer-basis executor that used to live here was split into
a subsystem (the pluggable-backend refactor):

* :mod:`repro.core.exec.layers`   — pure per-layer F/CG/CD math, the plain
  planned walk and the whole-graph ``jax.grad`` reference;
* :mod:`repro.core.exec.store`    — :class:`HbmTracker` /
  :class:`ActivationStore` with the transfer-engine seam;
* :mod:`repro.core.exec.backends` — the :class:`ExecutorBackend` protocol
  with :class:`SimulatedBackend` (synchronous replay, default) and
  :class:`AsyncDeviceBackend` (real ``jax.device_put`` device-stream
  transfers, fenced at the consumer).

Every public (and previously-private-but-imported) name keeps resolving
from here so existing imports continue to work; new code should import
from :mod:`repro.core.exec` or go through
``repro.core.compile_plan(...).loss_and_grads()`` with the
``MemoryPlanConfig.executor`` knob.
"""

from __future__ import annotations

from repro.core.exec.backends import (BACKENDS, AsyncDeviceBackend,
                                      ExecutorBackend, SimulatedBackend,
                                      get_backend,
                                      swap_planned_loss_and_grads)
from repro.core.exec.layers import (_conv2d_fwd, _lstm_cell, _needs_deriv,
                                    _param_owner, _pool2d_fwd, init_params,
                                    layer_calc_derivative,
                                    layer_calc_gradient, layer_forward,
                                    loss_derivative, loss_forward,
                                    planned_loss_and_grads,
                                    reference_forward,
                                    reference_loss_and_grads, sgd_update)
from repro.core.exec.store import (ActivationStore, DeviceStreamEngine,
                                   HbmTracker, SwapExecStats, SyncHostEngine,
                                   TransferEngine, _ActivationStore,
                                   _HbmTracker)

__all__ = [
    "init_params", "layer_forward", "layer_calc_gradient",
    "layer_calc_derivative", "loss_forward", "loss_derivative",
    "planned_loss_and_grads", "reference_forward",
    "reference_loss_and_grads", "sgd_update",
    "SwapExecStats", "HbmTracker", "ActivationStore", "TransferEngine",
    "SyncHostEngine", "DeviceStreamEngine",
    "ExecutorBackend", "SimulatedBackend", "AsyncDeviceBackend",
    "BACKENDS", "get_backend", "swap_planned_loss_and_grads",
]
