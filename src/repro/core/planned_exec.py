"""Layer-operation-basis training executor (NNTrainer §3/§4, Figure 2(b)).

Executes a :class:`LayerGraph` the way NNTrainer does: an explicit schedule
of per-layer Forward, Compute-Gradient and Compute-Derivative phases, with
saved tensors chosen by the lifespan analysis rather than by a tape.  This
is the JAX realisation of the paper's layer-basis engine:

* forward pass stores exactly the residuals the plan retains (inputs for
  weighted layers, *outputs* for in-place activations / batch-norm);
* backward walks layers in reverse: CG (weight grads) then CD (input
  derivative), with the incoming-derivative buffer logically shared —
  D tensors are consumed exactly once, matching Backward lifespans;
* unrolled recurrences accumulate gradients across time and the optimizer
  applies them once per iteration (Iteration lifespan, §5.2);
* :func:`swap_planned_loss_and_grads` additionally replays the compiled
  :class:`repro.core.plan.ExecutionSchedule` — the proactive host-swap
  plan (§6) lowered to typed ``Compute``/``SwapOut``/``Prefetch``/``Free``
  ops — with high-water-mark accounting proving the swap-aware plan's
  residency peak and packed host pool are respected.

Gradients are validated against whole-graph ``jax.grad`` (see
``reference_loss_and_grads``) to 1e-5 in tests — the paper's own CI gate
("if a weight or activation value has an error over 1e-4 the commit is
rejected").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inplace
from repro.core.execution_order import OrderedTensors, compute_execution_order
from repro.core.graph import (LOSS_KINDS, WEIGHTED_KINDS, LayerGraph,
                              LayerNode)
from repro.core.lifespan import CreateMode
from repro.core.offload import OffloadSchedule


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(graph: LayerGraph, rng: jax.Array,
                dtype=jnp.float32) -> Dict[str, Dict[str, jax.Array]]:
    """He-init weights for every weighted layer; E-shared layers reuse the
    first unrolled copy's parameters (Tensor-sharing, CreateMode.EXTEND)."""
    params: Dict[str, Dict[str, jax.Array]] = {}
    for l in graph.layers:
        if l.shares_weights_with:
            continue  # storage owned by the first copy
        shapes = l.weight_shapes()
        if not shapes:
            continue
        entry = {}
        for wname, shape in shapes.items():
            rng, sub = jax.random.split(rng)
            if wname in ("b", "beta"):
                entry[wname] = jnp.zeros(shape, dtype)
            elif wname in ("gamma",):
                entry[wname] = jnp.ones(shape, dtype)
            else:
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                if l.kind in ("conv2d", "conv1d"):
                    fan_in = int(np.prod(shape[1:]))
                scale = math.sqrt(2.0 / max(fan_in, 1))
                entry[wname] = jax.random.normal(sub, shape, dtype) * scale
        params[l.name] = entry
    return params


def _param_owner(graph: LayerGraph, l: LayerNode) -> str:
    return l.shares_weights_with or l.name


# ---------------------------------------------------------------------------
# Per-layer forward / backward (layer basis: F, CG, CD as separate callables)
# ---------------------------------------------------------------------------

def _conv2d_fwd(x, w, b, stride, padding):
    # x: (B, C, H, W), w: (O, I, K, K)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding.upper(), dimension_numbers=dn)
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def _pool2d_fwd(x, ksize, stride):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, 1, ksize, ksize), (1, 1, stride, stride), "VALID")


def _lstm_cell(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def layer_forward(l: LayerNode, xs: List[jax.Array],
                  p: Optional[Dict[str, jax.Array]],
                  state: Optional[Dict[str, jax.Array]] = None
                  ) -> Tuple[jax.Array, Any]:
    """Forward one layer; returns (output, saved-context for backward).

    The saved context honours the lifespan analysis: weighted layers save
    inputs (F+CG), in-place activations save only their OUTPUT (F+CD),
    views save nothing.
    """
    a = l.attrs
    x = xs[0]
    if l.kind == "linear":
        y = x @ p["w"]
        if "b" in p:
            y = y + p["b"]
        return y, (x,)
    if l.kind == "conv2d":
        y = _conv2d_fwd(x, p["w"], p.get("b"), a.get("stride", 1),
                        a.get("padding", "same"))
        return y, (x,)
    if l.kind == "activation":
        y = inplace.apply_activation(a["fn"], x)
        return y, (y,)     # output-only residual: the in-place property
    if l.kind == "batchnorm":
        mean = jnp.mean(x, axis=0, keepdims=True)
        var = jnp.var(x, axis=0, keepdims=True)
        inv_std = jax.lax.rsqrt(var + 1e-5)
        y = p["gamma"] * (x - mean) * inv_std + p["beta"]
        return y, (y, inv_std)   # output-based residual (paper §3)
    if l.kind == "flatten":
        return x.reshape(x.shape[0], -1), (x.shape,)
    if l.kind == "reshape":
        return x.reshape((x.shape[0],) + tuple(a["out_shape"])), (x.shape,)
    if l.kind == "pool2d":
        y = _pool2d_fwd(x, a["ksize"], a.get("stride", a["ksize"]))
        return y, (x,)   # backward needs the argmax source only (F+CD input)
    if l.kind == "add":
        y = xs[0]
        for other in xs[1:]:
            y = y + other
        return y, (len(xs),)
    if l.kind == "concat":
        axis = a.get("axis", -1)
        return jnp.concatenate(xs, axis=axis), ([x.shape[axis] for x in xs], axis)
    if l.kind == "multiout":
        return x, ()
    if l.kind == "embedding":
        idx = x.astype(jnp.int32)
        flat = idx[..., 0] if idx.ndim > 1 else idx
        return jnp.take(p["w"], flat, axis=0), (flat,)
    if l.kind == "lstm":
        h = jnp.zeros(x.shape[:-1] + (a["hidden"],), x.dtype) if state is None \
            else state["h"]
        c = jnp.zeros_like(h) if state is None else state["c"]
        h_new, c_new = _lstm_cell(x, h, c, p["wx"], p["wh"], p["b"])
        return h_new, (x, h, c)   # backward recomputes gates; outputs unused
    raise ValueError(f"forward not implemented for {l.kind}")


def layer_calc_gradient(l: LayerNode, ctx: Any, dy: jax.Array,
                        p: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """CG phase: weight gradients from saved context + incoming derivative."""
    if l.kind == "linear":
        (x,) = ctx
        g = {"w": x.reshape(-1, x.shape[-1]).T @ dy.reshape(-1, dy.shape[-1])}
        if "b" in p:
            g["b"] = dy.reshape(-1, dy.shape[-1]).sum(0)
        return g
    if l.kind == "conv2d":
        (x,) = ctx
        # dW via autodiff of the conv primitive w.r.t. w only (keeps the
        # layer-basis structure; XLA emits the standard conv-grad kernel).
        a = l.attrs
        _, vjp = jax.vjp(
            lambda w: _conv2d_fwd(x, w, None, a.get("stride", 1),
                                  a.get("padding", "same")), p["w"])
        g = {"w": vjp(dy)[0]}
        if "b" in p:
            g["b"] = dy.sum(axis=(0, 2, 3))
        return g
    if l.kind == "batchnorm":
        y, inv_std = ctx
        gamma, beta = p["gamma"], p["beta"]
        xhat = (y - beta) / jnp.where(gamma == 0, 1.0, gamma)
        return {"gamma": jnp.sum(dy * xhat, axis=0), "beta": jnp.sum(dy, axis=0)}
    if l.kind == "embedding":
        (idx,) = ctx
        g = jnp.zeros(p["w"].shape, dy.dtype)
        flat_idx = idx.reshape(-1) if idx.ndim > 1 else idx
        return {"w": g.at[flat_idx].add(dy.reshape(flat_idx.shape[0], -1))}
    if l.kind == "lstm":
        x, h0, c0 = ctx
        def f(wx, wh, b):
            h, _ = _lstm_cell(x, h0, c0, wx, wh, b)
            return h
        _, vjp = jax.vjp(f, p["wx"], p["wh"], p["b"])
        gwx, gwh, gb = vjp(dy)
        return {"wx": gwx, "wh": gwh, "b": gb}
    return {}


def layer_calc_derivative(l: LayerNode, ctx: Any, dy: jax.Array,
                          p: Optional[Dict[str, jax.Array]]) -> List[jax.Array]:
    """CD phase: derivative(s) w.r.t. the layer's input(s)."""
    a = l.attrs
    if l.kind == "linear":
        return [dy @ p["w"].T]
    if l.kind == "conv2d":
        (x,) = ctx
        _, vjp = jax.vjp(
            lambda xx: _conv2d_fwd(xx, p["w"], None, a.get("stride", 1),
                                   a.get("padding", "same")), x)
        return [vjp(dy)[0]]
    if l.kind == "activation":
        (y,) = ctx
        return [inplace.deriv_from_output(a["fn"], y, dy)]
    if l.kind == "batchnorm":
        y, inv_std = ctx
        gamma, beta = p["gamma"], p["beta"]
        n = y.shape[0]
        xhat = (y - beta) / jnp.where(gamma == 0, 1.0, gamma)
        dxhat = dy * gamma
        s1 = jnp.sum(dxhat, axis=0, keepdims=True)
        s2 = jnp.sum(dxhat * xhat, axis=0, keepdims=True)
        return [(inv_std / n) * (n * dxhat - s1 - xhat * s2)]
    if l.kind in ("flatten", "reshape"):
        (shape,) = ctx
        return [dy.reshape(shape)]
    if l.kind == "pool2d":
        (x,) = ctx
        k, s = a["ksize"], a.get("stride", a["ksize"])
        _, vjp = jax.vjp(lambda xx: _pool2d_fwd(xx, k, s), x)
        return [vjp(dy)[0]]
    if l.kind == "add":
        (n,) = ctx
        return [dy] * n
    if l.kind == "concat":
        sizes, axis = ctx
        splits = np.cumsum(sizes)[:-1].tolist()
        return list(jnp.split(dy, splits, axis=axis))
    if l.kind == "multiout":
        return [dy]
    if l.kind == "embedding":
        return []  # integer inputs: no derivative
    if l.kind == "lstm":
        x, h0, c0 = ctx
        def f(xx):
            h, _ = _lstm_cell(xx, h0, c0, p["wx"], p["wh"], p["b"])
            return h
        _, vjp = jax.vjp(f, x)
        return [vjp(dy)[0]]
    raise ValueError(f"calc_derivative not implemented for {l.kind}")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_forward(kind: str, pred: jax.Array, label: jax.Array) -> jax.Array:
    if kind == "loss_mse":
        return jnp.mean((pred - label) ** 2)
    if kind == "loss_ce":
        logp = jax.nn.log_softmax(pred, axis=-1)
        return -jnp.mean(jnp.sum(label * logp, axis=-1))
    raise ValueError(kind)


def loss_derivative(kind: str, pred: jax.Array, label: jax.Array) -> jax.Array:
    n = pred.size if kind == "loss_mse" else pred.shape[0]
    if kind == "loss_mse":
        return 2.0 * (pred - label) / n
    if kind == "loss_ce":
        # combined softmax+CE derivative (the Loss realizer removed softmax)
        return (jax.nn.softmax(pred, axis=-1) - label) / n
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The planned training step
# ---------------------------------------------------------------------------

def planned_loss_and_grads(graph: LayerGraph,
                           params: Dict[str, Dict[str, jax.Array]],
                           x: jax.Array, label: jax.Array
                           ) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]]]:
    """One layer-basis training iteration: F sweep, then CG/CD sweep.

    Returns (loss, grads) with grads keyed by parameter-owner layer name;
    E-shared (unrolled) layers accumulate into their owner's entry.
    """
    acts: Dict[str, jax.Array] = {"__input__": x}
    ctxs: Dict[str, Any] = {}
    loss_node = None
    loss_val = None

    # ---- Forward (EO 0..N-1) ------------------------------------------------
    for l in graph.layers:
        if l.kind in ("loss_mse", "loss_ce"):
            loss_node = l
            loss_val = loss_forward(l.kind, acts[l.inputs[0]], label)
            continue
        xs = [acts[i] for i in l.inputs]
        p = params.get(_param_owner(graph, l))
        y, ctx = layer_forward(l, xs, p)
        acts[l.name] = y
        ctxs[l.name] = ctx

    # ---- Backward (EO N..3N): CG then CD per layer, reverse order ----------
    derivs: Dict[str, jax.Array] = {}
    pred_name = loss_node.inputs[0]
    derivs[pred_name] = loss_derivative(loss_node.kind, acts[pred_name], label)

    grads: Dict[str, Dict[str, jax.Array]] = {}
    for l in reversed(graph.layers):
        if l.kind in ("loss_mse", "loss_ce"):
            continue
        dy = derivs.pop(l.name, None)   # Backward lifespan: consumed here
        if dy is None:
            continue  # dead derivative (pruned subgraph)
        p = params.get(_param_owner(graph, l))
        # CG phase
        if l.trainable and l.weight_shapes():
            g = layer_calc_gradient(l, ctxs[l.name], dy, p)
            owner = _param_owner(graph, l)
            if owner in grads:
                grads[owner] = {k: grads[owner][k] + g[k] for k in g}
            else:
                grads[owner] = g
        # CD phase — skipped when no upstream layer needs the derivative
        # (first layer / frozen backbone: dead-derivative pruning).
        upstream_needed = [
            i for i in l.inputs if i != "__input__" and _needs_deriv(graph, i)
        ]
        if upstream_needed:
            dxs = layer_calc_derivative(l, ctxs[l.name], dy, p)
            for inp, dx in zip(l.inputs, dxs):
                if inp == "__input__" or inp not in upstream_needed:
                    continue
                if inp in derivs:
                    derivs[inp] = derivs[inp] + dx   # fan-out accumulation
                else:
                    derivs[inp] = dx
    return loss_val, grads


def _needs_deriv(graph: LayerGraph, name: str) -> bool:
    from repro.core.graph import WEIGHTED_KINDS, _has_trainable_upstream
    node = graph.layer(name)
    if node.kind in WEIGHTED_KINDS and node.trainable and node.weight_shapes():
        return True
    return _has_trainable_upstream(graph, node)


# ---------------------------------------------------------------------------
# Whole-graph reference (conventional tape autodiff) for validation
# ---------------------------------------------------------------------------

def reference_forward(graph: LayerGraph,
                      params: Dict[str, Dict[str, jax.Array]],
                      x: jax.Array) -> jax.Array:
    acts: Dict[str, jax.Array] = {"__input__": x}
    out = None
    for l in graph.layers:
        if l.kind in ("loss_mse", "loss_ce"):
            out = acts[l.inputs[0]]
            continue
        xs = [acts[i] for i in l.inputs]
        p = params.get(_param_owner(graph, l))
        y, _ = layer_forward(l, xs, p)
        acts[l.name] = y
    return out if out is not None else acts[graph.layers[-1].name]


def reference_loss_and_grads(graph: LayerGraph,
                             params: Dict[str, Dict[str, jax.Array]],
                             x: jax.Array, label: jax.Array):
    loss_kind = next(l.kind for l in graph.layers if l.kind.startswith("loss"))
    trainable_owners = {
        _param_owner(graph, l) for l in graph.layers
        if l.trainable and l.weight_shapes()
    }
    train_p = {k: v for k, v in params.items() if k in trainable_owners}
    frozen_p = {k: v for k, v in params.items() if k not in trainable_owners}

    def loss_fn(tp):
        pred = reference_forward(graph, {**frozen_p, **tp}, x)
        return loss_forward(loss_kind, pred, label)

    loss, grads = jax.value_and_grad(loss_fn)(train_p)
    return loss, grads


def sgd_update(params, grads, lr=1e-2):
    out = {}
    for lname, entry in params.items():
        if lname in grads:
            out[lname] = {k: v - lr * grads[lname][k] for k, v in entry.items()}
        else:
            out[lname] = entry
    return out


# ---------------------------------------------------------------------------
# Proactive swap execution (NNTrainer §6): replay the compiled op list
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SwapExecStats:
    """What the swap executor actually did during one iteration."""
    swap_outs: int = 0
    prefetches: int = 0
    inplace_prefetches: int = 0    # re-residencies that needed no copy
    dma_bytes: int = 0             # device<->host bytes moved
    late_swap_ins: int = 0         # schedule misses: access before prefetch
    hbm_high_water: int = 0        # peak resident planned-activation bytes
    host_high_water: int = 0       # peak resident host-pool bytes
    planned_peak: Optional[int] = None   # SwapAwarePlan's residency bound
    planned_host_pool: Optional[int] = None  # packed host arena bound
    peak_inflight_prefetch: int = 0      # double-buffer occupancy peak
    # the ops actually executed, in order — equals the compiled
    # ExecutionSchedule.ops exactly when no schedule miss occurred
    replayed_ops: Tuple = ()


class _HbmTracker:
    """High-water-mark accounting over the planned activation bytes."""

    def __init__(self):
        self.current = 0
        self.high_water = 0

    def alloc(self, nbytes: int) -> None:
        self.current += nbytes
        self.high_water = max(self.high_water, self.current)

    def free(self, nbytes: int) -> None:
        self.current -= nbytes


class _ActivationStore:
    """Layer-output store with device/host tiers and post-merge alias groups.

    Keys are layer names; bytes are accounted per *owner* tensor (the
    post-merge ``X:`` CREATE owner), so an in-place activation output that
    aliases its producer's storage is neither double-counted nor separately
    swapped — swapping an owner moves every alias with it, exactly like one
    arena region moving to host.  The store holds no scheduling logic: the
    executor drives it by replaying the compiled
    :class:`repro.core.plan.ExecutionSchedule` op by op.
    """

    def __init__(self, ordered: OrderedTensors, hbm: _HbmTracker,
                 host_pool: Optional[_HbmTracker] = None):
        self.ordered = ordered
        self.hbm = hbm
        self.host_pool = host_pool or _HbmTracker()
        self.device: Dict[str, jax.Array] = {}
        self.host: Dict[str, np.ndarray] = {}
        self.members: Dict[str, Set[str]] = {}     # owner -> layer names
        self.alive: Set[str] = set()               # owners holding HBM bytes
        self._owner_cache: Dict[str, Optional[str]] = {}

    def owner_of(self, lname: str) -> Optional[str]:
        """The planned X: owner accounting this output's bytes, if any."""
        if lname in self._owner_cache:
            return self._owner_cache[lname]
        owner = self.ordered.owner(f"X:{lname}")
        spec = self.ordered.tensors.get(owner)
        tracked = (spec is not None and spec.create_mode == CreateMode.CREATE
                   and spec.merged_into is None)
        self._owner_cache[lname] = owner if tracked else None
        return self._owner_cache[lname]

    def put(self, lname: str, y: jax.Array) -> None:
        self.device[lname] = y
        owner = self.owner_of(lname)
        if owner is None:
            return
        self.members.setdefault(owner, set()).add(lname)
        if owner not in self.alive:
            self.alive.add(owner)
            self.hbm.alloc(self.ordered.tensors[owner].nbytes)

    def get(self, lname: str, stats: SwapExecStats) -> jax.Array:
        if lname in self.device:
            return self.device[lname]
        owner = self.owner_of(lname)
        if owner is not None and lname in self.host:
            # The schedule was wrong (or margins too tight): blocking swap-in.
            stats.late_swap_ins += 1
            self.swap_in(owner, stats)
            return self.device[lname]
        raise KeyError(f"activation {lname!r} neither on device nor host")

    def swap_out(self, owner: str, stats: SwapExecStats) -> None:
        nbytes = self.ordered.tensors[owner].nbytes
        for m in self.members.get(owner, ()):
            if m in self.device:
                self.host[m] = np.asarray(self.device.pop(m))
        self.alive.discard(owner)
        self.hbm.free(nbytes)
        self.host_pool.alloc(nbytes)
        stats.swap_outs += 1
        stats.dma_bytes += nbytes

    def swap_in(self, owner: str, stats: SwapExecStats) -> None:
        nbytes = self.ordered.tensors[owner].nbytes
        for m in self.members.get(owner, ()):
            if m in self.host:
                self.device[m] = jnp.asarray(self.host.pop(m))
        self.alive.add(owner)
        self.hbm.alloc(nbytes)
        self.host_pool.free(nbytes)
        stats.prefetches += 1
        stats.dma_bytes += nbytes

    def free_owner(self, owner: str) -> None:
        on_host = False
        for m in self.members.get(owner, ()):
            self.device.pop(m, None)
            on_host |= self.host.pop(m, None) is not None
        if on_host:
            self.host_pool.free(self.ordered.tensors[owner].nbytes)
        if owner in self.alive:
            self.alive.discard(owner)
            self.hbm.free(self.ordered.tensors[owner].nbytes)


def swap_planned_loss_and_grads(
    graph: LayerGraph,
    params: Dict[str, Dict[str, jax.Array]],
    x: jax.Array, label: jax.Array, *,
    schedule: OffloadSchedule,
    ordered: Optional[OrderedTensors] = None,
    plan: Optional["SwapAwarePlan"] = None,  # noqa: F821
    lowered: Optional["ExecutionSchedule"] = None,  # noqa: F821
) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]], SwapExecStats]:
    """One layer-basis iteration replaying the compiled op list.

    Identical numerics to :func:`planned_loss_and_grads` (arrays round-trip
    through host exactly), but walks the lowered
    :class:`repro.core.plan.ExecutionSchedule` directly: every ``Compute``,
    ``SwapOut``, ``Prefetch`` and ``Free`` was decided at compile time, so
    the executor holds no scheduling policy — it replays ops and accounts
    HBM / host-pool residency high-water marks.  When no ``lowered``
    schedule is supplied (hand-wired callers) it is derived here from
    ``schedule``/``plan``.  With a :class:`SwapAwarePlan`, asserts the
    measured high-water marks never exceed the planned residency peak and
    the packed host pool.
    """
    from repro.core.plan import (Compute, Free, Prefetch, SwapOut,
                                 lower_schedule)
    if ordered is None:
        ordered = compute_execution_order(graph, int(x.shape[0]))
    if lowered is None:
        lowered = lower_schedule(ordered, schedule, plan)
    stats = SwapExecStats()
    stats.inplace_prefetches = sum(
        1 for d in schedule.decisions if d.inplace)
    hbm = _HbmTracker()
    store = _ActivationStore(ordered, hbm)
    store.device["__input__"] = x

    def resolve_ctx(ctx: Any) -> Any:
        return tuple(
            store.get(e[1], stats)
            if isinstance(e, tuple) and len(e) == 2 and e[0] == "@act" else e
            for e in ctx
        )

    ctxs: Dict[str, Any] = {}
    derivs: Dict[str, jax.Array] = {}
    pending_dxs: Dict[str, List[Tuple[str, jax.Array]]] = {}
    pending_cd: Dict[str, Tuple[jax.Array, List[str]]] = {}
    grads: Dict[str, Dict[str, jax.Array]] = {}
    loss_val = None
    replayed: List[Any] = []
    inflight = 0
    done_at: Dict[int, int] = {}      # read EO -> prefetched bytes retiring
    retired_eo = -1

    for op in lowered.ops:
        if isinstance(op, Prefetch):
            if op.tensor in store.alive:
                continue  # late swap-in already brought it back
            store.swap_in(op.tensor, stats)
            inflight += op.nbytes
            done_at[op.read_eo] = done_at.get(op.read_eo, 0) + op.nbytes
            stats.peak_inflight_prefetch = max(
                stats.peak_inflight_prefetch, inflight)
            replayed.append(op)
        elif isinstance(op, Compute):
            # prefetches issued at earlier phases complete by their read
            # EO: retire their double-buffer slots at the phase boundary
            if op.eo > retired_eo:
                for eo in list(done_at):
                    if eo <= op.eo:
                        inflight -= done_at.pop(eo)
                retired_eo = op.eo
            l = graph.layer(op.layer)
            lname, kind = op.layer, op.kind
            if kind == "F":
                if l.kind in LOSS_KINDS:
                    loss_val = loss_forward(
                        l.kind, store.get(l.inputs[0], stats), label)
                else:
                    xs = [store.get(i, stats) for i in l.inputs]
                    p = params.get(_param_owner(graph, l))
                    y, ctx = layer_forward(l, xs, p)
                    store.put(lname, y)
                    # keep saved activations by *reference* into the store,
                    # so a swap moves the residual too (same bytes in a real
                    # arena)
                    sym = []
                    for e in ctx:
                        hit = next(
                            (i for i, xi in enumerate(xs) if e is xi), None)
                        if hit is not None:
                            sym.append(("@act", l.inputs[hit]))
                        elif e is y:
                            sym.append(("@act", lname))
                        else:
                            sym.append(e)
                    ctxs[lname] = tuple(sym)
            elif kind == "CG":
                if l.kind in LOSS_KINDS:
                    pred = l.inputs[0]
                    derivs[pred] = loss_derivative(
                        l.kind, store.get(pred, stats), label)
                else:
                    dy = derivs.pop(lname, None)
                    if dy is not None:
                        if l.trainable and l.weight_shapes():
                            p = params.get(_param_owner(graph, l))
                            g = layer_calc_gradient(
                                l, resolve_ctx(ctxs[lname]), dy, p)
                            owner = _param_owner(graph, l)
                            if owner in grads:
                                grads[owner] = {k: grads[owner][k] + g[k]
                                                for k in g}
                            else:
                                grads[owner] = g
                        upstream_needed = [
                            i for i in l.inputs
                            if i != "__input__" and _needs_deriv(graph, i)
                        ]
                        if not upstream_needed:
                            pass
                        elif l.kind in WEIGHTED_KINDS:
                            # A weighted layer's saved input has a F+CG
                            # lifespan — it is freed (or swapped) right
                            # after this phase — so its derivative is
                            # computed here, on the same resident context
                            # the CG just used, and *published* at the
                            # adjacent CD phase (EO_CD = EO_CG + 1).
                            p = params.get(_param_owner(graph, l))
                            dxs = layer_calc_derivative(
                                l, resolve_ctx(ctxs[lname]), dy, p)
                            pending_dxs[lname] = [
                                (inp, dx) for inp, dx in zip(l.inputs, dxs)
                                if inp != "__input__"
                                and inp in upstream_needed
                            ]
                        else:
                            # In-place / pool / view layers have F+CD
                            # contexts (e.g. max-pool argmax source,
                            # activation output) — residency and prefetches
                            # target the CD phase.
                            pending_cd[lname] = (dy, upstream_needed)
            else:  # CD: compute deferred derivatives, publish D:<inp>
                dxs_out = pending_dxs.pop(lname, [])
                if lname in pending_cd:
                    dy, upstream_needed = pending_cd.pop(lname)
                    p = params.get(_param_owner(graph, l))
                    dxs = layer_calc_derivative(
                        l, resolve_ctx(ctxs[lname]), dy, p)
                    dxs_out = [
                        (inp, dx) for inp, dx in zip(l.inputs, dxs)
                        if inp != "__input__" and inp in upstream_needed
                    ]
                for inp, dx in dxs_out:
                    if inp in derivs:
                        derivs[inp] = derivs[inp] + dx
                    else:
                        derivs[inp] = dx
            replayed.append(op)
        elif isinstance(op, SwapOut):
            if op.tensor in store.alive:
                store.swap_out(op.tensor, stats)
                replayed.append(op)
        elif isinstance(op, Free):
            store.free_owner(op.tensor)
            replayed.append(op)

    stats.hbm_high_water = hbm.high_water
    stats.host_high_water = store.host_pool.high_water
    stats.replayed_ops = tuple(replayed)
    if plan is not None:
        stats.planned_peak = plan.activation_residency_peak()
        stats.planned_host_pool = plan.host_pool_bytes
        if stats.hbm_high_water > stats.planned_peak:
            raise AssertionError(
                f"swap executor exceeded the planned residency peak: "
                f"{stats.hbm_high_water} > {stats.planned_peak} bytes")
        if stats.host_high_water > stats.planned_host_pool:
            raise AssertionError(
                f"swap executor exceeded the packed host pool: "
                f"{stats.host_high_water} > {stats.planned_host_pool} bytes")
    return loss_val, grads, stats
