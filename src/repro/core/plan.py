"""Unified memory-plan compile API: graph (or model config) -> executor.

NNTrainer's key property is that its memory optimisations are *transparent
to training algorithms*: the user declares a network, the framework derives
execution order, swap schedule and arena packing behind one compile step.
This module is that compile step for the reproduction.  Instead of
hand-wiring

    compute_execution_order -> plan_offload -> plan_memory_swapped
        -> plan_checkpoint_policy -> swap_planned_loss_and_grads

callers declare a :class:`MemoryPlanConfig` and call :func:`compile_plan`,
which runs the whole pipeline and returns a :class:`CompiledMemoryPlan` —
one object owning the schedule, the packed arenas, the remat/offload policy
and the executor entry point (``.loss_and_grads``).

Two input kinds are accepted:

* a :class:`repro.core.graph.LayerGraph` — the layer-basis path: EO
  analysis, proactive-swap scheduling, swap-aware arena packing and the
  phase-ticked swap executor;
* a transformer-shaped ``ModelConfig`` — the TPU path: the joint
  keep/recompute/offload planner over tagged intermediates, lowered to a
  ``jax.checkpoint`` policy for the jitted train step.

Schedule/planner co-optimisation (ROADMAP item, now a behaviour of this
API): ``plan_offload`` picks swap candidates by byte-phase product *before*
packing, so some swaps vacate bytes the packer never needed — they pay two
DMA transfers and reclaim no packed peak.  After packing, the compile loop
drops every such non-load-bearing swap and re-plans, iterating to a fixed
point where (a) removing any remaining swap would raise the packed peak and
(b) the peak never exceeds the single-pass ``plan_memory_swapped`` result.
DMA traffic shrinks at equal peak — exactly the ``swap/vgg16`` diminishing-
returns observation.

The model-config path runs the same remat knapsack and swap scheduler as
*one* planner (ROADMAP's "swap the remat knapsack jointly"): every tagged
intermediate gets a three-way keep / recompute / offload decision priced by
the :class:`MemoryPlanConfig` hardware cost model (``dma_gbps`` host
bandwidth vs ``device_tflops`` recompute throughput) under the per-layer
HBM budget — see :func:`repro.core.remat_policy.plan_joint_policy`.  The
resulting :class:`CompiledMemoryPlan` reports honest prices for both
eviction lanes (``dma_bytes`` covers model plans too, not just graph
schedules).  The deprecated ``offload_dropped`` knob survives as an alias
meaning "DMA is free" (offload everything that misses the budget).

Graph plans additionally lower to an :class:`ExecutionSchedule` — a flat
list of typed ops (:class:`Compute`, :class:`SwapOut`, :class:`Prefetch`,
:class:`Free`), each carrying the tensor name, its arena offset and its EO
index — which the layer-basis executor walks directly instead of
re-interpreting the :class:`OffloadSchedule` at run time.  Each
``SwapOut``/``Prefetch`` op names one stream-ready transfer, the staging
point for lowering onto real async device streams.

MemoryPlanConfig knob table
---------------------------

======================  =====================================================
knob (default)          meaning
======================  =====================================================
``planner``             device-arena allocator: sorting | bestfit |
(``"sorting"``)         segregated | buddy | worstcase
``host_planner``        pinned-host pool allocator (same registry); the
(``"sorting"``)         host pool is packed over offloaded-copy lifetimes
``swap`` (True)         enable proactive host swapping (False = plain plan)
``min_idle_phases``     minimum EO idle window for a swap candidate (4)
``min_bytes``           minimum tensor size worth a DMA descriptor (1 MiB)
``prefetch_margin``     phases before the post-gap read to prefetch (2)
``hbm_budget_bytes``    stop choosing candidates past this reclaim (None)
``cooptimize`` (True)   iterate schedule <-> packer to a fixed point
``remat`` (None)        model path: None = follow ``cfg.remat``
``remat_budget_bytes``  per-layer activation budget for the knapsack (None)
``offload`` (None)      model path: enable the priced offload eviction lane
``dma_gbps`` (None)     host-DMA bandwidth pricing the offload lane
``device_tflops``       device throughput pricing the recompute lane (None)
``offload_dropped``     DEPRECATED "DMA is free" alias (None)
``executor``            executor backend replaying the lowered schedule:
(``"sim"``)             sim (synchronous, deterministic stats) | async
                        (real ``jax.device_put`` device-stream transfers,
                        fenced at the consumer, overlap measured) |
                        jit_blocks (async transfers plus proven-fusable
                        Compute runs dispatched as single ``jax.jit``
                        calls)
``verify``              static verification of the lowered schedule
(``"error"``)           (``repro.core.verify``): "error" raises
                        ``ScheduleVerificationError`` on any violated
                        invariant, "warn" downgrades to warnings, "off"
                        skips (the report is folded into
                        ``report()["verify"]`` either way)
``deps`` (True)         static dependence analysis of the lowered schedule
                        (``repro.core.verify.deps``): build the happens-
                        before DAG, plan legal compute fusion and measure
                        per-transfer slack; summary lands in
                        ``report()["deps"]`` (False skips the analysis)
``optim_offload``       make optimizer state (AdamW moments) a planned
(False)                 resource: per-layer ``O:`` slots packed into their
                        own device region + compressed host pool, lowered
                        to ``OptPrefetch``/``OptSwapOut`` ops both
                        executor backends replay (see
                        ``repro.core.optim_offload``)
``optim_compress``      quantize offloaded optimizer host copies to int8
(True)                  block-scaled form (``optim/compression.py``
                        ``_q``/``_deq`` with error feedback); False keeps
                        fp32 host copies (exact, ~4x the host bytes)
======================  =====================================================

Static verification
-------------------

``compile_plan`` runs the :mod:`repro.core.verify` checker registry over
every lowered schedule before handing it to an executor: use-before-
resident, transfer races, arena aliasing (device *and* host pool — the
same sweep on both compile paths), double-free/leak, budget/alignment and
in-place-prefetch legality.  Findings are structured ``Diagnostic``
records; a failing check renders like::

    [error:use_before_resident] X:conv1: read at EO 11 while swapped out
        since EO 3 with no prefetch in between
    [error:arena_alias] op[7] X:conv1: Prefetch device offset 4096
        diverges from the packed placement (8192)

``report()["verify"]`` carries the machine-readable summary (``ok``,
``errors``, ``checks_run``, ``ops_scanned``, ``wall_time_s``); executor
backends refuse to replay a plan-backed schedule that has not passed.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.deprecation import warn_once
from repro.core.execution_order import OrderedTensors, compute_execution_order
from repro.core.graph import LayerGraph
from repro.core.offload import (OffloadSchedule, make_schedule,
                                offload_lowering, plan_offload)
from repro.core.planner import (Plan, SwapAwarePlan, get_planner,
                                plan_memory_swapped)
from repro.core.remat_policy import (RematPlan, plan_joint_policy,
                                     transformer_intermediates)


@dataclasses.dataclass(frozen=True)
class MemoryPlanConfig:
    """Declarative memory-plan configuration — every knob in one place.

    Arena / swap knobs (layer-graph path; see :mod:`repro.core.offload` for
    the knob reference):

    ``planner``          device-arena allocator: sorting | bestfit |
                         segregated | buddy | worstcase
    ``host_planner``     pinned-host pool allocator (same registry); packs
                         the offloaded copies' [swap_out, read] lifetimes
    ``swap``             enable proactive host swapping (False = plain plan)
    ``min_idle_phases``  minimum EO idle window for a swap candidate
    ``min_bytes``        minimum tensor size worth a DMA descriptor
    ``prefetch_margin``  phases before the post-gap read to start prefetch
    ``hbm_budget_bytes`` stop choosing candidates past this reclaim target
    ``cooptimize``       iterate schedule <-> packer to a fixed point,
                         dropping swaps whose vacated bytes reclaimed no
                         packed peak
    ``executor``         backend replaying the lowered ExecutionSchedule:
                         "sim" (synchronous replay, bit-for-bit stats,
                         the default), "async" (transfers issued as real
                         ``jax.device_put`` copies against the device's
                         host memory space, dispatched ahead of need and
                         fenced at the consumer; achieved overlap
                         reported) or "jit_blocks" (async transfers plus
                         proven-fusable Compute runs dispatched as single
                         ``jax.jit`` calls; admission through
                         ``schedules_equivalent``).  See
                         ``repro.core.exec.backends``.
    ``verify``           static schedule verification policy: "error"
                         (default — raise ScheduleVerificationError on any
                         violated memory-safety invariant), "warn"
                         (downgrade findings to warnings), "off" (skip).
                         See ``repro.core.verify``.
    ``deps``             run the static dependence analyser over the
                         lowered schedule (default True): dependence-DAG
                         edge counts, the fusion plan the jit_blocks
                         backend would execute, and per-transfer prefetch
                         slack, folded into ``report()["deps"]``.  See
                         ``repro.core.verify.deps``.
    ``optim_offload``    plan optimizer state (AdamW moments, 2x params)
                         as first-class ``O:`` slots: packed into a
                         separate device working region + compressed host
                         pool and lowered to typed ``OptPrefetch``/
                         ``OptSwapOut`` ops (default False — optimizer
                         state stays outside the plan, the historical
                         behaviour).  See ``repro.core.optim_offload``.
    ``optim_compress``   int8 block-scaled host copies for offloaded
                         optimizer slots, with error feedback keeping
                         updates unbiased (default True); False keeps
                         exact fp32 host copies

    Remat / offload knobs (model-config path — the joint planner):

    ``remat``              None = follow ``cfg.remat``; bool overrides
    ``remat_budget_bytes`` per-layer activation budget for the knapsack
                           (None = follow ``cfg.remat_budget_bytes``)
    ``offload``            enable the host-offload eviction lane so budget-
                           missing intermediates get a priced three-way
                           keep/recompute/offload decision instead of the
                           pure remat knapsack (None = follow ``cfg.offload``)
    ``dma_gbps``           host-DMA bandwidth (GB/s) pricing the offload
                           lane: one round trip costs 2*bytes/bandwidth
                           (None = follow ``cfg.dma_gbps``, else the
                           remat_policy default, 32 GB/s)
    ``device_tflops``      device throughput (TFLOP/s) pricing the recompute
                           lane (None = follow ``cfg.device_tflops``, else
                           the remat_policy default, 200 TFLOP/s)
    ``offload_dropped``    DEPRECATED alias meaning "DMA is free": True
                           offloads *every* budget-missing intermediate
                           regardless of whether recomputing it would be
                           cheaper; False forces the offload lane off.
                           Prefer ``offload`` + the hardware knobs.
    """

    planner: str = "sorting"
    host_planner: str = "sorting"
    swap: bool = True
    min_idle_phases: int = 4
    min_bytes: int = 1 << 20
    prefetch_margin: int = 2
    hbm_budget_bytes: Optional[int] = None
    cooptimize: bool = True
    executor: str = "sim"
    verify: str = "error"
    deps: bool = True
    optim_offload: bool = False
    optim_compress: bool = True

    remat: Optional[bool] = None
    remat_budget_bytes: Optional[int] = None
    offload: Optional[bool] = None
    dma_gbps: Optional[float] = None
    device_tflops: Optional[float] = None
    offload_dropped: Optional[bool] = None

    def cache_key(self) -> Tuple[Any, ...]:
        """Stable hashable key covering EVERY knob, field-order invariant.

        Compile caches (the serving plan cache, autotuner memos) must key
        on the *full* config: two tenants whose configs differ in any knob
        — planner, host_planner, budget, executor, verify, ... — may get
        materially different plans, so sharing a cache slot between them
        would silently serve one tenant the other's QoS.  Sorting by field
        name keeps the key stable under dataclass field reordering."""
        return tuple(
            (f.name, getattr(self, f.name))
            for f in sorted(dataclasses.fields(self), key=lambda f: f.name))


@dataclasses.dataclass(frozen=True)
class CooptStats:
    """What the schedule/planner co-optimisation fixed point did."""

    rounds: int                      # full drop-scan passes (>= 1)
    dropped: Tuple[str, ...]         # swaps removed as non-load-bearing
    single_pass_peak_bytes: int      # arena peak before co-optimisation
    single_pass_dma_bytes: int       # DMA traffic before co-optimisation


# ---------------------------------------------------------------------------
# ExecutionSchedule: the lowered, executor-facing IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compute:
    """Run one layer phase (``kind`` is "F" / "CG" / "CD") at EO ``eo``."""
    eo: int
    layer: str
    kind: str


@dataclasses.dataclass(frozen=True)
class SwapOut:
    """Background D2H DMA during phase ``eo``: copy ``tensor`` from device
    arena offset ``device_offset`` to host-pool offset ``host_offset`` and
    release the device bytes when the phase completes."""
    eo: int
    tensor: str
    nbytes: int
    device_offset: int
    host_offset: int


@dataclasses.dataclass(frozen=True)
class Prefetch:
    """H2D DMA issued at the start of phase ``eo``: copy ``tensor`` back
    from host-pool offset ``host_offset`` into device arena offset
    ``device_offset``; the transfer must complete by ``read_eo`` (the
    double-buffer slot retires there)."""
    eo: int
    tensor: str
    nbytes: int
    device_offset: int
    host_offset: int
    read_eo: int


@dataclasses.dataclass(frozen=True)
class Free:
    """Release ``tensor``'s arena bytes after its last access (phase ``eo``)."""
    eo: int
    tensor: str
    nbytes: int
    device_offset: int


@dataclasses.dataclass(frozen=True)
class OptPrefetch:
    """H2D DMA issued at phase ``eo``: copy ``tensor``'s (an ``O:<layer>``
    optimizer slot) compressed host copy — ``host_nbytes`` int8+scale bytes
    at host offset ``host_offset`` — into the optimizer working region at
    ``device_offset`` and dequantize into the ``nbytes`` fp32 working
    buffer; must be consumable by the layer's CG phase ``read_eo`` (where
    the optimizer update reads the moments).

    Deliberately NOT a :class:`Prefetch` subclass: optimizer slots live in
    their own device region and host pool, so every activation-arena sweep
    (reuse edges, residency checks, transfer accounting) must stay blind to
    them — ``isinstance`` walks over the activation op types skip these by
    construction."""
    eo: int
    tensor: str
    nbytes: int
    device_offset: int
    host_offset: int
    host_nbytes: int
    read_eo: int


@dataclasses.dataclass(frozen=True)
class OptSwapOut:
    """D2H DMA during phase ``eo`` (the phase after the layer's CG update):
    copy the updated ``nbytes`` fp32 optimizer working state at
    ``device_offset`` back to the host, where it is re-quantized (with
    error feedback) into the ``host_nbytes`` compressed slot at
    ``host_offset``, then release the working-region bytes."""
    eo: int
    tensor: str
    nbytes: int
    device_offset: int
    host_offset: int
    host_nbytes: int


# Within one EO phase: prefetches start the phase (activation, then
# optimizer), compute runs, the background swap-outs drain at the end
# (optimizer state right after the update, then activations), then expired
# tensors are freed.  Only the relative order matters; the integers for
# the PR-4 op types keep their original relative order so every existing
# lowered op list sorts identically.
_OP_RANK = {Prefetch: 0, OptPrefetch: 1, Compute: 2, OptSwapOut: 3,
            SwapOut: 4, Free: 5}

ScheduleOp = Union[Compute, SwapOut, Prefetch, Free, OptPrefetch, OptSwapOut]


@dataclasses.dataclass(frozen=True)
class ExecutionSchedule:
    """The lowered memory plan: one flat op list the executor walks.

    Every scheduling decision is resolved at compile time — which tensor
    moves, when, between which arena offsets — so the executor carries no
    policy of its own: it replays the ops in order.  In-place-prefetch
    decisions emit no ops (no data moves for them); their re-residency is a
    plan-level fact.  Each ``SwapOut``/``Prefetch`` names one stream-ready
    transfer: the staging point for the async double-buffer lowering.
    """

    ops: Tuple[ScheduleOp, ...]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for op in self.ops:
            key = type(op).__name__.lower()
            out[key] = out.get(key, 0) + 1
        return out

    def transfers(self) -> Tuple[ScheduleOp, ...]:
        """The DMA ops only, in issue order."""
        return tuple(op for op in self.ops
                     if isinstance(op, (SwapOut, Prefetch)))


def lower_schedule(ordered: OrderedTensors, schedule: OffloadSchedule,
                   plan: Optional[Union[Plan, SwapAwarePlan]] = None
                   ) -> ExecutionSchedule:
    """Lower (EO analysis, swap schedule, packed plan) to the flat op list.

    ``plan`` provides arena offsets; without one (hand-wired callers) the
    offsets are -1 ("unplaced").  Only ``X:`` decisions lower to transfer
    ops: ``S:`` scratch tensors never enter the layer-output store, so
    their swap is plan-level only (arena residency), nothing to move.
    In-place decisions lower to nothing — their bytes never move.
    """
    swap_aware = isinstance(plan, SwapAwarePlan)

    def device_offset(name: str, *, post: bool) -> int:
        if swap_aware:
            rs = plan.residencies.get(name)
            if rs:
                ordered_rs = sorted(rs, key=lambda r: r.min_eo)
                return ordered_rs[-1 if post else 0].offset
        elif isinstance(plan, Plan) and name in plan.placements:
            return plan.placements[name].offset
        return -1

    def host_offset(name: str) -> int:
        if swap_aware:
            hp = plan.host.placements.get(name + "@host")
            if hp is not None:
                return hp.offset
        return -1

    ops: List[ScheduleOp] = [
        Compute(eo=eo, layer=lname, kind=kind)
        for eo, lname, kind in ordered.phase_schedule()
    ]
    for d in schedule.decisions:
        if not d.vacates or d.inplace or not d.name.startswith("X:"):
            continue
        if d.name not in ordered.tensors:
            raise ValueError(
                f"offload schedule references {d.name!r}, which the "
                f"execution-order analysis does not know — schedule and "
                f"ordered tensors come from different graphs?")
        ops.append(SwapOut(eo=d.swap_out_eo, tensor=d.name, nbytes=d.nbytes,
                           device_offset=device_offset(d.name, post=False),
                           host_offset=host_offset(d.name)))
        ops.append(Prefetch(eo=d.prefetch_at_eo, tensor=d.name,
                            nbytes=d.nbytes,
                            device_offset=device_offset(d.name, post=True),
                            host_offset=host_offset(d.name),
                            read_eo=d.read_eo))
    for t in ordered.planned_tensors():
        if t.name.startswith("X:"):
            ops.append(Free(eo=t.max_eo, tensor=t.name, nbytes=t.nbytes,
                            device_offset=device_offset(t.name, post=True)))
    optim = getattr(plan, "optim", None)
    if optim is not None:
        # optimizer slots: one prefetch (compressed host copy -> fp32
        # working buffer, ready by the layer's CG update) and one swap-out
        # (updated state re-quantized back to the host slot) per slot; the
        # offsets index the optimizer plan's OWN device region / host pool,
        # not the activation arenas
        for s in optim.slots:
            dev = optim.device.placements[s.name].offset
            host = optim.host.placements[s.name + "@host"].offset
            ops.append(OptPrefetch(
                eo=s.prefetch_eo, tensor=s.name, nbytes=s.nbytes,
                device_offset=dev, host_offset=host,
                host_nbytes=s.host_nbytes, read_eo=s.read_eo))
            ops.append(OptSwapOut(
                eo=s.swapout_eo, tensor=s.name, nbytes=s.nbytes,
                device_offset=dev, host_offset=host,
                host_nbytes=s.host_nbytes))
    ops.sort(key=lambda op: (op.eo, _OP_RANK[type(op)],
                             getattr(op, "tensor", ""),
                             getattr(op, "layer", "")))
    return ExecutionSchedule(ops=tuple(ops))


@dataclasses.dataclass
class CompiledMemoryPlan:
    """Everything one compile step produced, behind one handle.

    ``source`` is "graph" (layer-basis path: ``ordered``/``schedule``/
    ``plan`` populated, ``loss_and_grads`` runnable) or "model"
    (config path: ``remat_plan`` populated, ``offload_policy`` installable
    in a jitted step).
    """

    config: MemoryPlanConfig
    source: str
    graph: Optional[LayerGraph] = None
    ordered: Optional[OrderedTensors] = None
    schedule: Optional[OffloadSchedule] = None
    plan: Optional[Union[Plan, SwapAwarePlan]] = None   # device arena
    baseline: Optional[Plan] = None                      # no-swap, same planner
    coopt: Optional[CooptStats] = None
    batch: Optional[int] = None
    # the lowered, executor-facing op list (graph path)
    lowered: Optional[ExecutionSchedule] = None

    model_config: Any = None
    remat_plan: Optional[RematPlan] = None
    batch_tokens: Optional[int] = None

    # what the last ``loss_and_grads`` execution reported (backend name,
    # transfer counts, achieved overlap for the async backend); None until
    # the compiled plan has been executed at least once
    exec_report: Optional[Dict[str, Any]] = None

    # what static verification proved (repro.core.verify); None only when
    # config.verify == "off"
    verify_report: Any = None

    # what the static dependence analyser measured over the lowered
    # schedule (repro.core.verify.deps): DAG edge counts, the fusion plan
    # the jit_blocks backend would execute, per-transfer prefetch slack;
    # None when config.deps is False or there is no lowered schedule
    deps_report: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------- queries
    @property
    def peak_bytes(self) -> int:
        """Planned device peak: packed arena bytes (graph) or the knapsack's
        kept-intermediate bytes across layers (model)."""
        if self.plan is not None:
            return self.plan.arena_bytes
        if self.remat_plan is not None and self.model_config is not None:
            return (self.remat_plan.saved_bytes_per_layer
                    * self.model_config.n_layers)
        return 0

    @property
    def host_pool_bytes(self) -> int:
        return self.plan.host_pool_bytes \
            if isinstance(self.plan, SwapAwarePlan) else 0

    @property
    def dma_bytes(self) -> int:
        """Total device<->host traffic: the swap schedule's (graph path) or
        the offloaded intermediates' round trips across layers (model)."""
        if self.schedule is not None:
            return self.schedule.dma_bytes
        if self.remat_plan is not None and self.model_config is not None:
            return (self.remat_plan.offload_dma_bytes_per_layer
                    * self.model_config.n_layers)
        return 0

    @property
    def hbm_bytes_saved(self) -> int:
        return self.plan.hbm_bytes_saved \
            if isinstance(self.plan, SwapAwarePlan) else 0

    def swapped_names(self) -> Tuple[str, ...]:
        return self.plan.swapped_names() \
            if isinstance(self.plan, SwapAwarePlan) else ()

    @property
    def inplace_prefetch_count(self) -> int:
        """Swaps whose bytes survived in place: no host slot, no DMA."""
        return self.plan.inplace_prefetch_count \
            if isinstance(self.plan, SwapAwarePlan) else 0

    @property
    def optim_plan(self):
        """The packed optimizer-state offload plan
        (:class:`repro.core.optim_offload.OptimPlan`), or None when
        ``config.optim_offload`` is off."""
        return getattr(self.plan, "optim", None)

    @property
    def optim_device_bytes(self) -> int:
        """Device bytes the optimizer state needs under this plan: the
        packed working-region peak when offloaded, 0 when the plan does not
        manage optimizer state (the historical behaviour — optimizer state
        then lives outside every arena and budget)."""
        op = self.optim_plan
        return op.device_peak_bytes if op is not None else 0

    @property
    def device_utilization(self) -> Optional[float]:
        if isinstance(self.plan, SwapAwarePlan):
            return self.plan.device.utilization()
        if self.plan is not None:
            return self.plan.utilization()
        return None

    @property
    def host_utilization(self) -> Optional[float]:
        return self.plan.host.utilization() \
            if isinstance(self.plan, SwapAwarePlan) else None

    @property
    def offload_policy(self):
        """The ``jax.checkpoint`` policy realising this plan's keep/offload
        decisions, or None when no policy applies.

        Only model-config plans produce one: their decisions are keyed by
        ``checkpoint_name`` tags XLA can match.  Graph plans execute their
        swap schedule through the layer-basis executor
        (``loss_and_grads``) instead — their arena tensor names would
        match no tag, so no policy is fabricated for them."""
        if self.remat_plan is not None:
            return self.remat_plan.policy()
        return None

    # ------------------------------------------------------------ executor
    def init_params(self, rng):
        """He-init parameters for the compiled graph (graph path only)."""
        self._require_graph("init_params")
        from repro.core.exec.layers import init_params
        return init_params(self.graph, rng)

    def loss_and_grads(self, params, x, label, *, executor=None, mask=None,
                       engine=None):
        """One layer-basis training iteration under this plan.

        Replays the lowered op list on the configured executor backend
        (``config.executor``; the ``executor=`` argument overrides per
        call — a registry name or an ``ExecutorBackend`` instance).  An
        empty schedule degrades to the plain planned walk; the HBM
        high-water mark is asserted against the packed residency peak on
        every backend.  ``mask`` is an optional (batch,) sample mask for
        pad-to-bucket batches: masked rows contribute an exactly-zero loss
        derivative, so grads match the unpadded batch (the serving path's
        bucket padding).  The backend's post-run summary (transfer counts,
        and for ``"async"`` the achieved overlap vs the planned
        ``peak_inflight_prefetch``) lands in ``self.exec_report`` and is
        folded into :meth:`report`.  Returns ``(loss, grads,
        SwapExecStats)``.

        ``engine`` optionally injects a :class:`TransferEngine` into the
        replay backends (``"sim"``/``"async"``) — e.g. a bus-paced engine
        for emulated-hardware benchmarks; the jit-fused backend manages
        its own engine and rejects the override.
        """
        self._require_graph("loss_and_grads")
        from repro.core.exec.backends import get_backend
        backend = get_backend(
            executor if executor is not None else self.config.executor)
        extra = {} if engine is None else {"engine": engine}
        out = backend.run(
            self.graph, params, x, label,
            schedule=self.schedule,
            ordered=self.ordered,
            plan=self.plan if isinstance(self.plan, SwapAwarePlan) else None,
            lowered=self.lowered,
            mask=mask,
            **extra,
        )
        self.exec_report = backend.report()
        return out

    def _require_graph(self, what: str) -> None:
        if self.source != "graph" or self.graph is None:
            raise TypeError(
                f"{what} needs a plan compiled from a LayerGraph; this plan "
                f"was compiled from a model config — install "
                f".offload_policy in the jitted step instead")

    # ------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """Machine-readable summary (the BENCH_swap.json row shape)."""
        out: Dict[str, Any] = {
            "source": self.source,
            "planner": self.config.planner,
            # the backend that actually executed (a per-call executor=
            # override wins over the configured knob); the config knob
            # until the plan has run
            "executor": ((self.exec_report or {}).get("backend")
                         or self.config.executor),
            "peak_bytes": self.peak_bytes,
            "host_pool_bytes": self.host_pool_bytes,
            "dma_bytes": self.dma_bytes,
            "hbm_bytes_saved": self.hbm_bytes_saved,
            "n_swaps": len(self.swapped_names()),
        }
        if self.source == "graph":
            out["graph"] = self.graph.name
            out["batch"] = self.batch
            out["baseline_peak_bytes"] = self.baseline.arena_bytes
            out["host_planner"] = self.config.host_planner
            out["inplace_prefetch_count"] = self.inplace_prefetch_count
            if self.device_utilization is not None:
                out["device_utilization"] = self.device_utilization
            if self.host_utilization is not None:
                out["host_utilization"] = self.host_utilization
            if self.lowered is not None:
                out["schedule_ops"] = self.lowered.counts()
            if self.optim_plan is not None:
                out["optim"] = self.optim_plan.summary()
            if self.exec_report is not None:
                # what the last execution measured, incl. the async
                # backend's achieved overlap vs peak_inflight_prefetch
                out["exec"] = dict(self.exec_report)
        if self.verify_report is not None:
            out["verify"] = self.verify_report.summary()
        if self.deps_report is not None:
            out["deps"] = dict(self.deps_report)
        if self.coopt is not None:
            out["coopt_rounds"] = self.coopt.rounds
            out["coopt_dropped"] = list(self.coopt.dropped)
            out["single_pass_peak_bytes"] = self.coopt.single_pass_peak_bytes
            out["single_pass_dma_bytes"] = self.coopt.single_pass_dma_bytes
        if self.remat_plan is not None:
            rp = self.remat_plan
            out["remat_saved"] = list(rp.saved)
            out["remat_dropped"] = list(rp.dropped)
            out["remat_offloaded"] = list(rp.offloaded)
            out["remat_decisions"] = rp.decisions()
            out["saved_bytes_per_layer"] = rp.saved_bytes_per_layer
            out["recompute_flops_per_layer"] = rp.recompute_flops_per_layer
            out["offload_dma_bytes_per_layer"] = rp.offload_dma_bytes_per_layer
            out["est_step_time_s_per_layer"] = rp.est_step_time_s_per_layer
            if rp.offloaded:
                # how the offload decisions actually lower on this JAX:
                # "fallback_save" means the policy degrades to plain saves
                # and the planned HBM budget will be exceeded
                out["offload_lowering"] = offload_lowering()
        return out


# ---------------------------------------------------------------------------
# Schedule/planner co-optimisation: iterate to a fixed point
# ---------------------------------------------------------------------------

def _cooptimize(ordered: OrderedTensors, plan: SwapAwarePlan, planner: str,
                host_planner: str
                ) -> Tuple[SwapAwarePlan, int, List[str]]:
    """Drop swaps whose vacated bytes reclaimed no packed peak; re-plan.

    A swap is non-load-bearing when re-packing *without* it yields the same
    (or a lower) arena peak: its two DMA transfers buy nothing.  In-place
    decisions are never scan candidates — they already move no data, so
    dropping them saves nothing and only removes planner freedom.  An
    accepted drop continues the scan from the *next* decision (restarting
    from the first would cost O(n^2) full re-packs per fixed point); one
    more full pass runs after any pass that dropped something, so the loop
    only stops when a complete scan accepts nothing.  The decision set
    strictly shrinks and the peak is monotone non-increasing — never above
    the single-pass input plan.  At the fixed point every remaining
    data-moving swap is load-bearing: removing any one of them would raise
    the packed peak.
    """
    rounds = 0
    dropped: List[str] = []
    improved = True
    while improved:
        rounds += 1
        improved = False
        for name in [d.name for d in plan.schedule.decisions
                     if not d.inplace]:
            # an earlier drop in this pass re-packed the arena and may have
            # re-flagged this decision as in-place — re-check the CURRENT
            # plan, not the pass-start snapshot, before trialing a drop
            cur = next((d for d in plan.schedule.decisions
                        if d.name == name), None)
            if cur is None or cur.inplace:
                continue
            rest = tuple(o for o in plan.schedule.decisions
                         if o.name != name)
            trial_plan = plan_memory_swapped(ordered, make_schedule(rest),
                                             planner=planner,
                                             host_planner=host_planner)
            if trial_plan.arena_bytes <= plan.arena_bytes:
                plan = trial_plan
                dropped.append(name)
                improved = True
    return plan, rounds, dropped


# ---------------------------------------------------------------------------
# Static verification hook
# ---------------------------------------------------------------------------

_VERIFY_MODES = ("error", "warn", "off")


def _apply_verify(cp: CompiledMemoryPlan) -> CompiledMemoryPlan:
    """Run the static verifier over a freshly compiled plan.

    Policy comes from ``config.verify``: ``"error"`` raises
    :class:`repro.core.verify.ScheduleVerificationError` on any error
    diagnostic, ``"warn"`` downgrades them to :class:`UserWarning`,
    ``"off"`` skips entirely.  A clean run marks the lowered schedule as
    verified so executor backends admit it without re-checking.

    The static dependence analyser (``config.deps``) rides the same hook:
    its summary — DAG edge counts, the fusion plan the jit_blocks backend
    would execute, per-transfer prefetch slack — lands in
    ``cp.deps_report`` (and ``report()["deps"]``) regardless of the
    verify policy."""
    if cp.config.deps and cp.lowered is not None:
        from repro.core.verify import deps_summary
        cp.deps_report = deps_summary(cp.lowered, cp.ordered, cp.plan)
    if cp.config.verify == "off":
        return cp
    from repro.core import verify as _verify
    report = _verify.verify_plan(cp)
    cp.verify_report = report
    if report.ok:
        if cp.lowered is not None:
            _verify.mark_verified(cp.lowered)
    elif cp.config.verify == "error":
        report.raise_if_errors()
    else:
        for d in report.errors():
            warnings.warn(f"schedule verification: {d.render()}",
                          UserWarning, stacklevel=4)
    return cp


def _check_verify_mode(config: MemoryPlanConfig) -> None:
    if config.verify not in _VERIFY_MODES:
        raise ValueError(
            f"unknown verify mode {config.verify!r}: choose from "
            f"{', '.join(_VERIFY_MODES)}")


# ---------------------------------------------------------------------------
# compile_plan: the single entry point
# ---------------------------------------------------------------------------

def compile_plan(graph_or_model, config: Optional[MemoryPlanConfig] = None,
                 *, batch: int = 32,
                 batch_tokens: Optional[int] = None) -> CompiledMemoryPlan:
    """Compile a memory plan from a declarative config — the one entry point.

    ``graph_or_model`` is either a :class:`LayerGraph` (``batch`` sizes the
    EO analysis) or a transformer-shaped ``ModelConfig`` (``batch_tokens``
    sizes the remat knapsack and is required).  ``config`` defaults to
    :class:`MemoryPlanConfig()`.
    """
    config = config or MemoryPlanConfig()
    if isinstance(graph_or_model, LayerGraph):
        return _compile_graph_plan(graph_or_model, config, batch)
    return _compile_model_plan(graph_or_model, config, batch_tokens)


def _compile_graph_plan(graph: LayerGraph, config: MemoryPlanConfig,
                        batch: int) -> CompiledMemoryPlan:
    # fail fast on planner- and executor-name typos, before any analysis
    from repro.core.exec.backends import get_backend
    get_planner(config.planner)
    get_planner(config.host_planner)
    get_backend(config.executor)
    _check_verify_mode(config)

    ordered = compute_execution_order(graph, batch)
    baseline = get_planner(config.planner).plan(ordered)

    optim_plan = None
    if config.optim_offload:
        from repro.core.optim_offload import plan_optim_offload
        optim_plan = plan_optim_offload(graph, ordered, config)

    if not config.swap:
        empty = make_schedule(())
        baseline.optim = optim_plan
        return _apply_verify(CompiledMemoryPlan(
            config=config, source="graph", graph=graph, ordered=ordered,
            schedule=empty, plan=baseline, baseline=baseline, batch=batch,
            lowered=lower_schedule(ordered, empty, baseline)))

    schedule = plan_offload(
        ordered,
        min_idle_phases=config.min_idle_phases,
        min_bytes=config.min_bytes,
        prefetch_margin=config.prefetch_margin,
        hbm_budget_bytes=config.hbm_budget_bytes,
    )
    plan = plan_memory_swapped(ordered, schedule, planner=config.planner,
                               host_planner=config.host_planner)
    # the swap-aware placement pass may have lowered some swaps to in-place
    # prefetches: the plan's rebuilt schedule is the authoritative one
    single_peak, single_dma = plan.arena_bytes, plan.schedule.dma_bytes

    coopt = None
    if config.cooptimize:
        plan, rounds, dropped = _cooptimize(
            ordered, plan, config.planner, config.host_planner)
        coopt = CooptStats(rounds=rounds, dropped=tuple(dropped),
                           single_pass_peak_bytes=single_peak,
                           single_pass_dma_bytes=single_dma)

    plan.optim = optim_plan
    return _apply_verify(CompiledMemoryPlan(
        config=config, source="graph", graph=graph, ordered=ordered,
        schedule=plan.schedule, plan=plan, baseline=baseline, coopt=coopt,
        batch=batch, lowered=lower_schedule(ordered, plan.schedule, plan)))


def _compile_model_plan(cfg, config: MemoryPlanConfig,
                        batch_tokens: Optional[int]) -> CompiledMemoryPlan:
    # the executor knob travels with the config even on the model path
    # (model plans install a checkpoint policy instead of running the
    # layer-basis executor) — still fail fast on typos
    from repro.core.exec.backends import get_backend
    get_backend(config.executor)
    _check_verify_mode(config)
    if batch_tokens is None:
        raise TypeError("compile_plan(model_config) requires batch_tokens=")
    remat_on = config.remat if config.remat is not None \
        else bool(getattr(cfg, "remat", False))
    if not remat_on:
        return _apply_verify(CompiledMemoryPlan(
            config=config, source="model", model_config=cfg,
            batch_tokens=batch_tokens))
    budget = config.remat_budget_bytes if config.remat_budget_bytes is not None \
        else getattr(cfg, "remat_budget_bytes", None)

    # Offload-lane resolution: the deprecated binary flag wins when set
    # (True = the old cost-blind behaviour, realised as free DMA); the
    # ``offload`` knob / ``cfg.offload`` enables the priced joint planner.
    free_dma = False
    if config.offload_dropped is not None:
        warn_once(
            "MemoryPlanConfig.offload_dropped is deprecated: True prices "
            "DMA as free and offloads every budget-missing intermediate; "
            "use MemoryPlanConfig(offload=True, dma_gbps=..., "
            "device_tflops=...) for the priced keep/recompute/offload "
            "decision", DeprecationWarning, stacklevel=3)
        offload_on = free_dma = bool(config.offload_dropped)
    else:
        offload_on = config.offload if config.offload is not None \
            else bool(getattr(cfg, "offload", False))
    dma_gbps = config.dma_gbps if config.dma_gbps is not None \
        else getattr(cfg, "dma_gbps", None)
    device_tflops = config.device_tflops if config.device_tflops is not None \
        else getattr(cfg, "device_tflops", None)

    inter = transformer_intermediates(
        batch_tokens=batch_tokens, d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff if getattr(cfg, "is_moe", False) else cfg.d_ff,
        n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        moe_experts_per_token=getattr(cfg, "top_k", 0),
    )
    if free_dma and budget is None:
        budget = 0   # legacy quirk: offload with no budget streams everything
    elif offload_on and budget is None:
        # keeping everything is cost-optimal without budget pressure, so a
        # budget-less "offload lane on" config offloads nothing — say so
        # instead of silently no-opping (the failure mode the old
        # offload-everything quirk existed to prevent)
        warnings.warn(
            "offload lane enabled but no per-layer HBM budget is set "
            "(remat_budget_bytes is None): keeping every intermediate is "
            "cost-optimal, so nothing will be offloaded; set a budget to "
            "create eviction pressure (or offload_dropped=True for the "
            "deprecated stream-everything behaviour)",
            UserWarning, stacklevel=3)
    remat_plan = plan_joint_policy(
        inter, budget, offload=offload_on,
        dma_gbps=math.inf if free_dma else dma_gbps,
        device_tflops=device_tflops)
    return _apply_verify(CompiledMemoryPlan(
        config=config, source="model", model_config=cfg,
        remat_plan=remat_plan, batch_tokens=batch_tokens))


# ---------------------------------------------------------------------------
# Budget-share compile: fit a plan inside one tenant's arena slice
# ---------------------------------------------------------------------------

class ArenaBudgetError(RuntimeError):
    """No plan configuration packed the graph inside the arena budget.

    Raised by :func:`compile_plan_under_budget` when even the most
    aggressive swap escalation leaves the packed device-arena peak above
    the caller's byte budget.  Carries the best (lowest-peak) attempt so
    admission controllers can report how far over budget the tenant is.
    """

    def __init__(self, msg: str, *, best_peak_bytes: int,
                 arena_budget_bytes: int):
        super().__init__(msg)
        self.best_peak_bytes = best_peak_bytes
        self.arena_budget_bytes = arena_budget_bytes


# Escalation ladder for compile_plan_under_budget: after the caller's own
# config, each rung swaps more aggressively (shorter idle windows, smaller
# DMA-worthy tensors, no reclaim cap).  Deterministic, so two tenants with
# the same (graph, batch, config, budget) always converge on the same plan
# — the property the serving compile cache relies on.
_BUDGET_ESCALATION: Tuple[Dict[str, Any], ...] = (
    {"min_idle_phases": 3, "min_bytes": 1 << 14, "hbm_budget_bytes": None},
    {"min_idle_phases": 2, "min_bytes": 1 << 12, "hbm_budget_bytes": None},
    {"min_idle_phases": 2, "min_bytes": 1 << 9, "prefetch_margin": 1,
     "hbm_budget_bytes": None, "planner": "bestfit"},
)


def compile_plan_under_budget(graph: LayerGraph,
                              config: Optional[MemoryPlanConfig] = None,
                              *, batch: int,
                              arena_budget_bytes: int) -> CompiledMemoryPlan:
    """Compile a graph plan whose packed device-arena peak fits a budget.

    The QoS lever of multi-tenant serving: N concurrent sessions split one
    device arena, so each session's plan must pack inside its share.  The
    caller's ``config`` is tried first; if its peak exceeds
    ``arena_budget_bytes`` the swap knobs escalate down the deterministic
    ladder (shorter idle windows, smaller ``min_bytes``, uncapped reclaim)
    until the plan fits.  Raises :class:`ArenaBudgetError` when even the
    most aggressive rung cannot fit — the admission controller's signal to
    reject the session instead of overcommitting the arena.
    """
    config = config or MemoryPlanConfig()
    best: Optional[CompiledMemoryPlan] = None
    tried: List[Tuple[str, int]] = []
    for overrides in ({},) + _BUDGET_ESCALATION:
        rung = dataclasses.replace(config, swap=True, **overrides) \
            if overrides else config
        cp = compile_plan(graph, rung, batch=batch)
        tried.append((f"idle={rung.min_idle_phases}/"
                      f"min_bytes={rung.min_bytes}", cp.peak_bytes))
        if cp.peak_bytes <= arena_budget_bytes:
            return cp
        if best is None or cp.peak_bytes < best.peak_bytes:
            best = cp
    attempts = ", ".join(f"{k}: peak={v}" for k, v in tried)
    raise ArenaBudgetError(
        f"{graph.name} batch={batch} cannot pack inside "
        f"{arena_budget_bytes} arena bytes ({attempts})",
        best_peak_bytes=best.peak_bytes,
        arena_budget_bytes=arena_budget_bytes)
