"""NNTrainer-style memory-planned training core, adapted to JAX/TPU.

The paper's contribution, as composable pieces:

* :mod:`repro.core.lifespan`        — tensor lifespans & create modes (Tables 2-3)
* :mod:`repro.core.graph`           — layer-basis graph IR + Realizers (Table 1)
* :mod:`repro.core.execution_order` — Algorithm 1 (EOs + MV/RV/E merging)
* :mod:`repro.core.planner`         — Algorithm 2 + best-fit planner (beyond paper)
* :mod:`repro.core.ideal`           — §3 ideal-memory calculator (Table 4)
* :mod:`repro.core.inplace`         — derivative-from-output activation calculus
* :mod:`repro.core.planned_exec`    — layer-basis F/CG/CD training executor
* :mod:`repro.core.remat_policy`    — lifespan analysis -> jax.checkpoint policy
* :mod:`repro.core.offload`         — EO-driven proactive-swap schedule (§6)

The offload schedule is consumed end-to-end: ``plan_memory_swapped`` plans
the arena with swapped tensors vacating their bytes mid-lifetime (plus a
host pool), and ``swap_planned_loss_and_grads`` executes the swaps during
the layer-basis walk with HBM high-water accounting.
"""

from repro.core.execution_order import compute_execution_order
from repro.core.ideal import ideal_memory
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.planner import SwapAwarePlan, plan_memory, plan_memory_swapped
from repro.core.remat_policy import plan_checkpoint_policy
from repro.core.offload import plan_offload
from repro.core.planned_exec import swap_planned_loss_and_grads

__all__ = [
    "CreateMode", "Lifespan", "TensorSpec", "SwapAwarePlan",
    "compute_execution_order", "ideal_memory", "plan_memory",
    "plan_memory_swapped", "plan_checkpoint_policy", "plan_offload",
    "swap_planned_loss_and_grads",
]
