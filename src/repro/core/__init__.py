"""NNTrainer-style memory-planned training core, adapted to JAX/TPU.

**Entry point:** :func:`repro.core.compile_plan`.  Declare the network (a
:class:`repro.core.graph.LayerGraph` or a transformer ``ModelConfig``) and
a :class:`repro.core.MemoryPlanConfig`; one compile step derives execution
order, proactive-swap schedule, swap-aware arena packing and the
remat/offload policy, iterates the schedule/planner co-optimisation to a
fixed point, and returns a :class:`repro.core.CompiledMemoryPlan` exposing
``.loss_and_grads()``, ``.offload_policy``, ``.peak_bytes`` and
``.report()``.  The memory machinery stays transparent to the training
algorithm — the paper's central property.

The pipeline stages remain importable as composable pieces:

* :mod:`repro.core.lifespan`        — tensor lifespans & create modes (Tables 2-3)
* :mod:`repro.core.graph`           — layer-basis graph IR + Realizers (Table 1)
* :mod:`repro.core.execution_order` — Algorithm 1 (EOs + MV/RV/E merging)
* :mod:`repro.core.planner`         — Algorithm 2 + best-fit planner (beyond paper)
* :mod:`repro.core.ideal`           — §3 ideal-memory calculator (Table 4)
* :mod:`repro.core.inplace`         — derivative-from-output activation calculus
* :mod:`repro.core.exec`            — executor subsystem: per-layer math
                                      (``exec.layers``), activation store +
                                      transfer engines (``exec.store``) and
                                      pluggable backends (``exec.backends``:
                                      SimulatedBackend | AsyncDeviceBackend,
                                      selected by ``MemoryPlanConfig.executor``;
                                      ``repro.core.planned_exec`` is a shim)
* :mod:`repro.core.remat_policy`    — joint keep/recompute/offload planner
                                      (priced by dma_gbps vs device_tflops)
                                      -> jax.checkpoint policy
* :mod:`repro.core.offload`         — EO-driven proactive-swap schedule (§6)
* :mod:`repro.core.plan`            — the compile facade + co-optimisation
* :mod:`repro.core.verify`          — static schedule verifier (CHECKS
                                      registry -> Diagnostic records; the
                                      correctness gate every backend
                                      replays behind)

Hand-wiring the stages (``compute_execution_order -> plan_offload ->
plan_memory_swapped -> swap_planned_loss_and_grads``) is **deprecated** for
callers — importing those names from this package still works (thin shims
below) but new code should go through :func:`compile_plan`, which also runs
the schedule/planner co-optimisation the free functions skip.
"""

from repro.core.deprecation import warn_once as _warn_once
from repro.core.exec.backends import (BACKENDS, AsyncDeviceBackend,
                                      ExecutorBackend, SimulatedBackend,
                                      get_backend)
from repro.core.plan import (ArenaBudgetError, CompiledMemoryPlan, Compute,
                             CooptStats, ExecutionSchedule, Free,
                             MemoryPlanConfig, Prefetch, SwapOut,
                             compile_plan, compile_plan_under_budget,
                             lower_schedule)
from repro.core.planner import PLANNERS, ArenaAllocator, get_planner
from repro.core.remat_policy import (RematPlan, plan_joint_policy,
                                     plan_step_time_s)
from repro.core.verify import (CHECKS, Diagnostic,
                               ScheduleVerificationError, VerifyReport,
                               verify_plan, verify_schedule)

__all__ = [
    # the compile API
    "MemoryPlanConfig", "CompiledMemoryPlan", "CooptStats", "compile_plan",
    "compile_plan_under_budget", "ArenaBudgetError",
    # the lowered executor-facing IR
    "ExecutionSchedule", "Compute", "SwapOut", "Prefetch", "Free",
    "lower_schedule",
    # the pluggable allocator layer (device arena + host pool)
    "ArenaAllocator", "PLANNERS", "get_planner",
    # the pluggable executor-backend layer (repro.core.exec)
    "ExecutorBackend", "SimulatedBackend", "AsyncDeviceBackend",
    "BACKENDS", "get_backend",
    # the static schedule verifier (repro.core.verify)
    "CHECKS", "Diagnostic", "VerifyReport", "ScheduleVerificationError",
    "verify_plan", "verify_schedule",
    # the joint keep/recompute/offload planner (model-config path internals,
    # exported for cost-model comparisons and tests)
    "RematPlan", "plan_joint_policy", "plan_step_time_s",
    # deprecated hand-wired entry points (resolved lazily, with a warning)
    "CreateMode", "Lifespan", "TensorSpec", "SwapAwarePlan",
    "compute_execution_order", "ideal_memory", "plan_memory",
    "plan_memory_swapped", "plan_checkpoint_policy", "plan_offload",
    "swap_planned_loss_and_grads",
]

# Deprecated package-level re-exports: name -> (module, attr).  Kept so old
# call sites importing the pipeline stages from ``repro.core`` keep working;
# each access warns once *per call site* (repro.core.deprecation.warn_once)
# toward compile_plan.
_DEPRECATED = {
    "CreateMode": ("repro.core.lifespan", "CreateMode"),
    "Lifespan": ("repro.core.lifespan", "Lifespan"),
    "TensorSpec": ("repro.core.lifespan", "TensorSpec"),
    "SwapAwarePlan": ("repro.core.planner", "SwapAwarePlan"),
    "compute_execution_order": ("repro.core.execution_order",
                                "compute_execution_order"),
    "ideal_memory": ("repro.core.ideal", "ideal_memory"),
    "plan_memory": ("repro.core.planner", "plan_memory"),
    "plan_memory_swapped": ("repro.core.planner", "plan_memory_swapped"),
    "plan_checkpoint_policy": ("repro.core.remat_policy",
                               "plan_checkpoint_policy"),
    "plan_offload": ("repro.core.offload", "plan_offload"),
    "swap_planned_loss_and_grads": ("repro.core.planned_exec",
                                    "swap_planned_loss_and_grads"),
}


def __getattr__(name: str):
    entry = _DEPRECATED.get(name)
    if entry is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    module_name, attr = entry
    _warn_once(
        f"importing {name!r} from repro.core is deprecated; use "
        f"repro.core.compile_plan (or import from {module_name} directly)",
        DeprecationWarning, stacklevel=2)
    import importlib
    return getattr(importlib.import_module(module_name), attr)
