"""Layer-operation-basis graph IR (NNTrainer §4, Figure 3, Table 1).

NNTrainer is a *layer-operation-basis* framework: the unit of scheduling is
a layer's forward / compute-gradient / compute-derivative phase, not an
individual tensor op.  This module defines the graph IR that the Compiler's
Realizers lower, Algorithm 1 orders, and the Memory Planner packs.

A ``LayerNode`` declares, for a given batch size, the tensors it *requests*
from the Tensor Pool — each annotated with a :class:`Lifespan` and a
:class:`CreateMode` (see ``lifespan.py``).  The request rules below encode
the paper's Figure 4/5/6 exactly:

* weighted layers (linear / conv / lstm / embedding) save their **input**
  for compute-gradient  → input lifespan F+CG;
* in-place activations & batch-norm compute their derivative from the
  **output** → output lifespan F+CD, output storage is an ``MV`` view of
  the input, and the input's buffer is thereby released (Fig. 5);
* flatten/reshape outputs are ``RV`` views — merged regardless of interval
  overlap because data integrity is guaranteed (Fig. 6);
* incoming derivatives have Backward lifespan; weight gradients Backward;
  weights Max; time-unrolled weights are shared via ``E``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.lifespan import CreateMode, Lifespan, TensorSpec

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

WEIGHTED_KINDS = ("linear", "conv2d", "conv1d", "lstm", "embedding", "batchnorm")
INPLACE_KINDS = ("activation", "batchnorm")   # derivative computable from output
VIEW_KINDS = ("flatten", "reshape")           # RV: spec changes, data does not
LOSS_KINDS = ("loss_mse", "loss_ce")


@dataclasses.dataclass
class LayerNode:
    """One layer in the compiled graph.

    ``attrs`` carries kind-specific attributes:
      linear:   in_features, out_features, bias(bool)
      conv2d:   in_ch, out_ch, ksize, stride, padding("same"|"valid"), im2col(bool)
      activation: fn ("sigmoid"|"relu"|"tanh"|"softmax")
      lstm:     in_features, hidden, seq_len (1 for a single cell step)
      embedding: vocab, dim
      flatten/reshape: out_shape (without batch)
      pool2d:   ksize, stride
      add/concat: (inputs define arity), concat: axis
      loss_*:   (label shape == input shape)
      slice:    trainable(bool) — backbone sections get trainable=False
    """

    name: str
    kind: str
    inputs: List[str] = dataclasses.field(default_factory=list)   # producer layer names
    attrs: Dict = dataclasses.field(default_factory=dict)
    # Output activation shape per single example (no batch dim).
    out_shape: Tuple[int, ...] = ()
    trainable: bool = True
    # Set by RecurrentRealizer: name of the layer whose weights this unrolled
    # copy shares (Tensor-sharing mode E).
    shares_weights_with: Optional[str] = None
    # Set for the first layer / frozen backbone boundary: compute-derivative
    # can be skipped (paper Fig. 4: L0's CD order is parenthesised).
    needs_input_derivative: bool = True

    def weight_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Parameter name -> shape, matching the executor's conventions."""
        a = self.attrs
        if self.kind == "linear":
            shapes = {"w": (a["in_features"], a["out_features"])}
            if a.get("bias", True):
                shapes["b"] = (a["out_features"],)
            return shapes
        if self.kind == "conv2d":
            shapes = {"w": (a["out_ch"], a["in_ch"], a["ksize"], a["ksize"])}
            if a.get("bias", True):
                shapes["b"] = (a["out_ch"],)
            return shapes
        if self.kind == "conv1d":
            shapes = {"w": (a["out_ch"], a["in_ch"], a["ksize"])}
            if a.get("bias", True):
                shapes["b"] = (a["out_ch"],)
            return shapes
        if self.kind == "lstm":
            i, h = a["in_features"], a["hidden"]
            return {"wx": (i, 4 * h), "wh": (h, 4 * h), "b": (4 * h,)}
        if self.kind == "embedding":
            return {"w": (a["vocab"], a["dim"])}
        if self.kind == "batchnorm":
            c = a["channels"]
            return {"gamma": (c,), "beta": (c,)}
        return {}

    def weight_nbytes(self) -> int:
        return sum(
            int(math.prod(s)) * 4 for s in self.weight_shapes().values()
        )


@dataclasses.dataclass
class LayerGraph:
    """A topologically-ordered list of layers plus graph inputs.

    ``input_shape`` is per-example (no batch).  ``label_shape`` likewise.
    """

    layers: List[LayerNode]
    input_shape: Tuple[int, ...]
    label_shape: Tuple[int, ...]
    name: str = "model"

    def layer(self, name: str) -> LayerNode:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def consumers(self, name: str) -> List[LayerNode]:
        return [l for l in self.layers if name in l.inputs]

    def validate(self) -> None:
        seen = {"__input__"}
        for l in self.layers:
            for inp in l.inputs:
                if inp not in seen:
                    raise ValueError(
                        f"layer {l.name}: input {inp!r} not yet produced "
                        "(graph must be topologically ordered)"
                    )
            seen.add(l.name)


# ---------------------------------------------------------------------------
# Shape inference
# ---------------------------------------------------------------------------

def _conv_out_hw(h: int, w: int, k: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "same":
        return (math.ceil(h / stride), math.ceil(w / stride))
    return ((h - k) // stride + 1, (w - k) // stride + 1)


def infer_shapes(graph: LayerGraph) -> Dict[str, Tuple[int, ...]]:
    """Per-example output shape for every layer (and ``__input__``)."""
    shapes: Dict[str, Tuple[int, ...]] = {"__input__": tuple(graph.input_shape)}
    for l in graph.layers:
        ins = [shapes[i] for i in l.inputs]
        a = l.attrs
        if l.kind == "input":
            out = ins[0]
        elif l.kind == "linear":
            out = ins[0][:-1] + (a["out_features"],)
        elif l.kind == "conv2d":
            c, h, w = ins[0]
            oh, ow = _conv_out_hw(h, w, a["ksize"], a.get("stride", 1),
                              a.get("padding", "same"))
            out = (a["out_ch"], oh, ow)
        elif l.kind == "conv1d":
            c, t = ins[0]
            out = (a["out_ch"], t)
        elif l.kind == "pool2d":
            c, h, w = ins[0]
            s = a.get("stride", a["ksize"])
            out = (c, h // s, w // s)
        elif l.kind in ("activation", "batchnorm", "dropout"):
            out = ins[0]
        elif l.kind in ("flatten",):
            out = (int(math.prod(ins[0])),)
        elif l.kind == "reshape":
            out = tuple(a["out_shape"])
        elif l.kind == "lstm":
            out = ins[0][:-1] + (a["hidden"],)
        elif l.kind == "embedding":
            out = ins[0] + (a["dim"],)
        elif l.kind == "add":
            out = ins[0]
        elif l.kind == "concat":
            axis = a.get("axis", -1)
            base = list(ins[0])
            base[axis] = sum(s[axis] for s in ins)
            out = tuple(base)
        elif l.kind == "multiout":
            out = ins[0]
        elif l.kind in LOSS_KINDS:
            out = ()  # scalar loss
        else:
            raise ValueError(f"unknown layer kind {l.kind!r}")
        l.out_shape = tuple(out)
        shapes[l.name] = tuple(out)
    return shapes


# ---------------------------------------------------------------------------
# Tensor requests (the Tensor Pool contents for one training iteration)
# ---------------------------------------------------------------------------

def _act_name(producer: str) -> str:
    return f"X:{producer}"


def _deriv_name(producer: str) -> str:
    return f"D:{producer}"


def tensor_requests(graph: LayerGraph, batch: int) -> List[Tuple[str, TensorSpec]]:
    """Enumerate every (requesting-layer, TensorSpec) pair for one iteration.

    Follows Figure 4's conventions.  Activation tensor ``X:<layer>`` is the
    output of ``<layer>`` (the graph input is ``X:__input__``); derivative
    tensor ``D:<layer>`` holds dLoss/d(output of <layer>).
    """
    infer_shapes(graph)
    shapes = {"__input__": graph.input_shape}
    for l in graph.layers:
        shapes[l.name] = l.out_shape

    reqs: List[Tuple[str, TensorSpec]] = []

    def act_spec(producer: str, lifespan: Lifespan, mode: CreateMode,
                 view_of: Optional[str] = None) -> TensorSpec:
        return TensorSpec(
            name=_act_name(producer),
            shape=(batch,) + tuple(shapes[producer]),
            lifespan=lifespan,
            create_mode=mode,
            view_of=view_of,
        )

    # Graph input: place-holder (external memory), saved through CG of its
    # consumers when they are weighted layers.
    first_consumer_weighted = any(
        l.kind in WEIGHTED_KINDS for l in graph.layers if "__input__" in l.inputs
    )
    reqs.append((
        graph.layers[0].name,
        act_spec(
            "__input__",
            Lifespan.FORWARD_GRAD if first_consumer_weighted else Lifespan.FORWARD,
            CreateMode.PLACEHOLDER,
        ),
    ))

    # Label: place-holder, needed by the loss layer during backward.
    reqs.append((
        graph.layers[-1].name,
        TensorSpec(
            name="X:__label__",
            shape=(batch,) + tuple(graph.label_shape),
            lifespan=Lifespan.FORWARD_BACKWARD,
            create_mode=CreateMode.PLACEHOLDER,
        ),
    ))

    for l in graph.layers:
        a = l.attrs
        # ---- output activation -------------------------------------------
        if l.kind in LOSS_KINDS:
            # Loss derivative overwrites the prediction in place (MV):
            # the Loss realizer guarantees d(pred) is computed from pred and
            # label only, so `D:<pred>` merges into `X:<pred>` (paper §5.1:
            # single-Linear ideal memory counts the prediction buffer once).
            pred = l.inputs[0]
            reqs.append((
                l.name,
                TensorSpec(
                    name=_deriv_name(pred),
                    shape=(batch,) + tuple(shapes[pred]),
                    lifespan=Lifespan.BACKWARD,
                    create_mode=CreateMode.MODIFY_VIEW,
                    view_of=_act_name(pred),
                ),
            ))
            # The predecessor reads this derivative during its own CG/CD —
            # register a second request under the predecessor's name so its
            # execution orders extend the tensor's live interval.
            if pred != "__input__":
                reqs.append((
                    pred,
                    TensorSpec(
                        name=_deriv_name(pred),
                        shape=(batch,) + tuple(shapes[pred]),
                        lifespan=Lifespan.BACKWARD,
                        create_mode=CreateMode.MODIFY_VIEW,
                        view_of=_act_name(pred),
                    ),
                ))
            continue

        if l.kind in ("activation",):
            # In-place: output is an MV view of the input activation; the
            # derivative is computed from the *output* (F + CD lifespan).
            reqs.append((
                l.name,
                act_spec(l.name, Lifespan.FORWARD_DERIV, CreateMode.MODIFY_VIEW,
                         view_of=_act_name(l.inputs[0])),
            ))
        elif l.kind == "multiout":
            # Pure fan-out: the output *is* the input (read-only view).
            reqs.append((
                l.name,
                act_spec(l.name, Lifespan.FORWARD, CreateMode.READONLY_VIEW,
                         view_of=_act_name(l.inputs[0])),
            ))
        elif l.kind in VIEW_KINDS:
            # Read-only view: merged unconditionally (integrity guaranteed).
            consumer_needs = _consumer_save_lifespan(graph, l)
            reqs.append((
                l.name,
                act_spec(l.name, consumer_needs, CreateMode.READONLY_VIEW,
                         view_of=_act_name(l.inputs[0])),
            ))
        else:
            consumer_needs = _consumer_save_lifespan(graph, l)
            reqs.append((l.name, act_spec(l.name, consumer_needs, CreateMode.CREATE)))

        # batchnorm is weighted *and* in-place-capable; model it as saving
        # its output (not input) for backward, like activations, but with a
        # CREATE'd output that allows the input to be freed by the planner
        # (the merge is only legal when the input has no later use).
        # ---- derivatives ---------------------------------------------------
        # D:<l> (derivative of l's output) is produced by the consumer's CD
        # and consumed by l's CG/CD — Backward lifespan.  Skipped entirely
        # when nothing upstream is trainable (dead-derivative pruning: the
        # backbone of a transfer-learning slice never materialises derivs).
        # When the unique consumer is an in-place activation, the incoming
        # derivative is overwritten elementwise (MV of the consumer's D);
        # flatten/reshape derivatives are pure reshapes (RV).
        consumed_by_loss = any(c.kind in LOSS_KINDS for c in graph.consumers(l.name))
        needs_out_deriv = (
            (l.kind in WEIGHTED_KINDS and l.trainable and bool(l.weight_shapes()))
            or _has_trainable_upstream(graph, l)
        )
        if not consumed_by_loss and graph.consumers(l.name) and needs_out_deriv:
            consumers = graph.consumers(l.name)
            dmode, dview = CreateMode.CREATE, None
            if len(consumers) == 1:
                c = consumers[0]
                if c.kind == "activation":
                    dmode, dview = CreateMode.MODIFY_VIEW, _deriv_name(c.name)
                elif c.kind in VIEW_KINDS:
                    dmode, dview = CreateMode.READONLY_VIEW, _deriv_name(c.name)
            reqs.append((
                l.name,
                TensorSpec(
                    name=_deriv_name(l.name),
                    shape=(batch,) + tuple(shapes[l.name]),
                    lifespan=Lifespan.BACKWARD,
                    create_mode=dmode,
                    view_of=dview,
                ),
            ))

        # ---- weights & gradients ------------------------------------------
        if l.kind in WEIGHTED_KINDS and l.weight_shapes():
            mode = CreateMode.EXTEND if l.shares_weights_with else CreateMode.CREATE
            target = l.shares_weights_with
            for wname, wshape in l.weight_shapes().items():
                reqs.append((
                    l.name,
                    TensorSpec(
                        name=f"W:{l.name}:{wname}",
                        shape=tuple(wshape),
                        lifespan=Lifespan.MAX,
                        create_mode=mode,
                        view_of=f"W:{target}:{wname}" if target else None,
                    ),
                ))
                if l.trainable:
                    # Gradient: Backward lifespan normally; Iteration lifespan
                    # when gradients accumulate across an unrolled recurrence
                    # (paper §5.2 Tacotron2: update once per iteration).
                    gls = (
                        Lifespan.ITERATION
                        if l.shares_weights_with or a.get("accumulate_grad")
                        else Lifespan.BACKWARD
                    )
                    gmode = CreateMode.EXTEND if l.shares_weights_with \
                else CreateMode.CREATE
                    reqs.append((
                        l.name,
                        TensorSpec(
                            name=f"G:{l.name}:{wname}",
                            shape=tuple(wshape),
                            lifespan=gls,
                            create_mode=gmode,
                            view_of=f"G:{target}:{wname}" if target else None,
                        ),
                    ))

        # ---- scratch: im2col for conv2d (paper §5.1 notes this overhead) --
        if l.kind == "conv2d" and a.get("im2col", False):
            c, h, w = shapes[l.inputs[0]]
            oh, ow = l.out_shape[1], l.out_shape[2]
            k = a["ksize"]
            reqs.append((
                l.name,
                TensorSpec(
                    name=f"S:{l.name}:im2col",
                    shape=(batch, oh * ow, c * k * k),
                    lifespan=Lifespan.FORWARD_GRAD,
                    create_mode=CreateMode.CREATE,
                ),
            ))
        # lstm gate scratch (saved for backward)
        if l.kind == "lstm":
            seq = a.get("seq_len", 1)
            reqs.append((
                l.name,
                TensorSpec(
                    name=f"S:{l.name}:gates",
                    shape=(batch, seq, 4 * a["hidden"]),
                    lifespan=Lifespan.FORWARD_GRAD,
                    create_mode=CreateMode.CREATE,
                ),
            ))
            reqs.append((
                l.name,
                TensorSpec(
                    name=f"S:{l.name}:cell",
                    shape=(batch, seq, a["hidden"]),
                    lifespan=Lifespan.FORWARD_GRAD,
                    create_mode=CreateMode.CREATE,
                ),
            ))
    return reqs


def _has_trainable_upstream(graph: LayerGraph, l: LayerNode) -> bool:
    """True if any (transitive) producer of ``l`` has trainable weights —
    i.e. the derivative of ``l``'s output must be propagated backward."""
    seen = set()
    stack = [i for i in l.inputs if i != "__input__"]
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        node = graph.layer(name)
        if node.kind in WEIGHTED_KINDS and node.trainable and node.weight_shapes():
            return True
        stack.extend(i for i in node.inputs if i != "__input__")
    return False


def _consumer_save_lifespan(graph: LayerGraph, l: LayerNode) -> Lifespan:
    """Lifespan of ``X:<l>`` based on what its consumers need.

    * consumed by a weighted layer          -> needed at that layer's CG
    * consumed by an in-place activation    -> F only (the MV merge takes over;
      the activation's derivative reads its *output*, never this input)
    * consumed by the loss                  -> needed through backward (the
      loss derivative is computed from it in place)
    * consumed by pool2d                    -> F + CD of the consumer
      (max-pool backward needs the argmax; modelled conservatively)
    """
    consumers = graph.consumers(l.name)
    if not consumers:
        return Lifespan.FORWARD
    needs_grad = any(c.kind in WEIGHTED_KINDS for c in consumers)
    is_loss = any(c.kind in LOSS_KINDS for c in consumers)
    needs_cd = any(c.kind in ("pool2d",) for c in consumers)
    if is_loss:
        return Lifespan.FORWARD_BACKWARD
    if needs_grad and needs_cd:
        return Lifespan.FORWARD_BACKWARD
    if needs_grad:
        return Lifespan.FORWARD_GRAD
    if needs_cd:
        return Lifespan.FORWARD_DERIV
    return Lifespan.FORWARD


# ---------------------------------------------------------------------------
# Realizers (Table 1) — graph → graph lowering passes
# ---------------------------------------------------------------------------

Realizer = Callable[[LayerGraph], LayerGraph]


def activation_realizer(graph: LayerGraph) -> LayerGraph:
    """Split ``activation=...`` attributes into standalone in-place layers."""
    out: List[LayerNode] = []
    rename: Dict[str, str] = {}
    for l in graph.layers:
        l.inputs = [rename.get(i, i) for i in l.inputs]
        act = l.attrs.pop("activation", None)
        out.append(l)
        if act:
            act_layer = LayerNode(
                name=f"{l.name}__act",
                kind="activation",
                inputs=[l.name],
                attrs={"fn": act},
            )
            out.append(act_layer)
            rename[l.name] = act_layer.name
    return LayerGraph(out, graph.input_shape, graph.label_shape, graph.name)


def flatten_realizer(graph: LayerGraph) -> LayerGraph:
    """Insert flatten before a linear layer following a spatial output."""
    out: List[LayerNode] = []
    rename: Dict[str, str] = {}
    shapes = infer_shapes(graph)
    for l in graph.layers:
        l.inputs = [rename.get(i, i) for i in l.inputs]
        if l.kind == "linear" and l.inputs:
            src = l.inputs[0]
            if len(shapes.get(src, ())) > 1:
                fl = LayerNode(name=f"{l.name}__flatten", kind="flatten", inputs=[src])
                out.append(fl)
                l.inputs = [fl.name] + l.inputs[1:]
        out.append(l)
    g = LayerGraph(out, graph.input_shape, graph.label_shape, graph.name)
    infer_shapes(g)
    return g


def loss_realizer(graph: LayerGraph) -> LayerGraph:
    """Cross-entropy: fold the preceding softmax activation into the loss
    (softmax+CE has a closed-form joint derivative — Table 1)."""
    out: List[LayerNode] = []
    removed: Dict[str, str] = {}
    layers = list(graph.layers)
    for idx, l in enumerate(layers):
        l.inputs = [removed.get(i, i) for i in l.inputs]
        if l.kind == "loss_ce":
            src = graph.layer(l.inputs[0]) if l.inputs[0] != "__input__" else None
            if src is not None and src.kind == "activation" \
                and src.attrs.get("fn") == "softmax":
                out.remove(src)
                removed[src.name] = src.inputs[0]
                l.inputs = [src.inputs[0]]
                l.attrs["from_logits"] = True
        out.append(l)
    return LayerGraph(out, graph.input_shape, graph.label_shape, graph.name)


def recurrent_realizer(graph: LayerGraph,
                       unroll: Optional[Dict[str, int]] = None) -> LayerGraph:
    """Unroll recurrent layers across time with E-shared weights (§5.2).

    ``unroll`` maps layer name -> number of time steps.  Each unrolled copy
    shares weights (CreateMode.EXTEND) and accumulates gradients with
    Iteration lifespan — the optimizer applies them once per iteration.
    """
    if not unroll:
        return graph
    out: List[LayerNode] = []
    rename: Dict[str, str] = {}
    for l in graph.layers:
        l.inputs = [rename.get(i, i) for i in l.inputs]
        steps = unroll.get(l.name, 0)
        if steps <= 1:
            out.append(l)
            continue
        prev = None
        first_name = f"{l.name}__t0"
        for t in range(steps):
            copy = LayerNode(
                name=f"{l.name}__t{t}",
                kind=l.kind,
                inputs=[prev] if prev else list(l.inputs),
                attrs=dict(l.attrs),
                trainable=l.trainable,
                shares_weights_with=None if t == 0 else first_name,
                needs_input_derivative=(t > 0) or l.needs_input_derivative,
            )
            out.append(copy)
            prev = copy.name
        rename[l.name] = prev
    g = LayerGraph(out, graph.input_shape, graph.label_shape, graph.name)
    infer_shapes(g)
    return g


def slice_realizer(graph: LayerGraph, freeze_until: Optional[str] = None) -> LayerGraph:
    """Transfer-learning slice: freeze the backbone up to ``freeze_until``.

    Frozen layers keep Forward-only activation lifespans (nothing saved for
    backward), drop gradient tensors, and the first trainable layer skips
    its input derivative — reproducing the paper's Fig. 12 transfer-learning
    memory savings.
    """
    if freeze_until is None:
        return graph
    frozen = True
    for l in graph.layers:
        if frozen:
            l.trainable = False
        if l.name == freeze_until:
            frozen = False
    # first trainable layer does not need dL/dX
    for l in graph.layers:
        if l.trainable and l.kind in WEIGHTED_KINDS:
            l.needs_input_derivative = False
            break
    return graph


def input_realizer(graph: LayerGraph) -> LayerGraph:
    """Ensure the first layer consumes ``__input__`` (Table 1 Input)."""
    if graph.layers and not graph.layers[0].inputs:
        graph.layers[0].inputs = ["__input__"]
    return graph


DEFAULT_REALIZERS: Sequence[Realizer] = (
    input_realizer,
    activation_realizer,
    flatten_realizer,
    loss_realizer,
)


def compile_graph(graph: LayerGraph,
                  realizers: Sequence[Realizer] = DEFAULT_REALIZERS,
                  unroll: Optional[Dict[str, int]] = None,
                  freeze_until: Optional[str] = None) -> LayerGraph:
    """The paper's *Compile* process: apply Realizers, validate ordering."""
    g = graph
    for r in realizers:
        g = r(g)
    g = recurrent_realizer(g, unroll)
    g = slice_realizer(g, freeze_until)
    infer_shapes(g)
    g.validate()
    return g
