"""Activation store, residency trackers and the transfer-engine seam.

The store owns *what lives where* (device tier, host tier, alias groups,
byte accounting); it holds no scheduling policy and no opinion about *how*
bytes move.  Data movement is delegated to a :class:`TransferEngine`:

* :class:`SyncHostEngine` — synchronous ``numpy`` round trips, the
  simulated-DMA behaviour the plan validation relies on;
* :class:`DeviceStreamEngine` — real ``jax.device_put`` copies between the
  device and its (pinned) host memory space, *dispatched* when the op is
  replayed and *fenced* only when a consumer reads the tensor, so the DMA
  overlaps the compute issued in between (NNTrainer §6's proactive swap on
  actual device streams).  The engine measures the overlap it achieved:
  how many fences found the transfer already complete, and the in-flight
  byte high-water mark to compare against the plan's
  ``peak_inflight_prefetch``.

Backends (:mod:`repro.core.exec.backends`) pick the engine; everything
else — alias groups, owner accounting, high-water marks — is shared.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execution_order import OrderedTensors
from repro.core.lifespan import CreateMode


@dataclasses.dataclass
class SwapExecStats:
    """What the swap executor actually did during one iteration."""
    swap_outs: int = 0
    prefetches: int = 0
    inplace_prefetches: int = 0    # re-residencies that needed no copy
    dma_bytes: int = 0             # device<->host bytes moved
    late_swap_ins: int = 0         # schedule misses: access before prefetch
    hbm_high_water: int = 0        # peak resident planned-activation bytes
    host_high_water: int = 0       # peak resident host-pool bytes
    planned_peak: Optional[int] = None   # SwapAwarePlan's residency bound
    planned_host_pool: Optional[int] = None  # packed host arena bound
    peak_inflight_prefetch: int = 0      # double-buffer occupancy peak
    # the ops actually executed, in order — equals the compiled
    # ExecutionSchedule.ops exactly when no schedule miss occurred (the
    # jit_blocks backend replays a proven-equivalent *fused* permutation
    # instead: computes of a block, then its deferred frees)
    replayed_ops: Tuple = ()
    # Python-level dispatches issued while replaying: one per op on the
    # per-op backends, one per fused block (plus one per unfused op) on
    # jit_blocks — the denominator of the dispatch-reduction claim
    dispatch_calls: int = 0
    # ---- backend-specific fields (defaults describe the simulated path) ----
    backend: str = "sim"
    # async engine: peak bytes issued on the device stream but not yet
    # fenced by a consumer — the measured double-buffer occupancy to hold
    # against the plan's ``peak_inflight_prefetch``
    inflight_high_water: int = 0
    fences: int = 0                # consumer-side waits on in-flight copies
    stalled_fences: int = 0        # fences that actually had to block
    # fraction of fences that found the transfer already complete (the DMA
    # fully overlapped compute); None when no real transfers were issued
    achieved_overlap: Optional[float] = None
    # debug sanitizer: per-op cross-checks of runtime residency against
    # the static verifier model (0 when the sanitizer is off)
    sanitizer_checks: int = 0
    # wall-clock seconds the backend spent replaying the op list — the
    # per-step timing the serving layer aggregates into per-session
    # steps/sec (0.0 until a run completes)
    wall_time_s: float = 0.0
    # ---- optimizer-state offload (repro.core.optim_offload) ----
    opt_swap_outs: int = 0         # OptSwapOut ops replayed
    opt_prefetches: int = 0        # OptPrefetch ops replayed
    # optimizer DMA: fp32 working state D2H + compressed host copy H2D
    opt_dma_bytes: int = 0
    opt_compressed_bytes: int = 0  # host-side bytes after quantization
    opt_device_high_water: int = 0 # peak resident optimizer working bytes
    # ---- measured bus-time split (device-stream engines only) ----
    # activation lane: seconds each prefetch spent in flight before its
    # consumer fence (hidden behind dispatched compute) vs seconds the
    # fence actually blocked (exposed on the critical path)
    hidden_dma_s: float = 0.0
    exposed_dma_s: float = 0.0
    # optimizer lane, same split: OptPrefetch H2D issued at its scheduled
    # EO and fenced at the first Compute of its read EO
    opt_hidden_dma_s: float = 0.0
    opt_exposed_dma_s: float = 0.0
    opt_fences: int = 0            # optimizer-lane consumer fences
    opt_stalled_fences: int = 0    # opt fences that actually had to block
    opt_inflight_high_water: int = 0  # peak issued-but-unfenced opt bytes
    # portion of hidden_dma_s that elapsed while *another* session held
    # the compute slot — credited by the phase-interleaved StepScheduler
    # (repro.serve.scheduler); 0.0 for single-session runs
    cross_hidden_dma_s: float = 0.0


class HbmTracker:
    """High-water-mark accounting over the planned activation bytes."""

    def __init__(self):
        self.current = 0
        self.high_water = 0

    def alloc(self, nbytes: int) -> None:
        self.current += nbytes
        self.high_water = max(self.high_water, self.current)

    def free(self, nbytes: int) -> None:
        self.current -= nbytes


class TransferEngine(Protocol):
    """How activation bytes move between the device and host tiers.

    ``swap_out``/``swap_in`` receive the member arrays of one owner group
    and return the handles of the destination tier; ``fence`` blocks until
    a previously issued ``swap_in`` of ``owner`` is complete (no-op for
    synchronous engines and for owners with nothing in flight); ``drain``
    fences everything still outstanding at the end of an iteration.
    """

    name: str

    def swap_out(self, owner: str, members: Dict[str, jax.Array],
                 nbytes: int) -> Dict[str, Any]: ...

    def swap_in(self, owner: str, members: Dict[str, Any],
                nbytes: int) -> Dict[str, jax.Array]: ...

    def fence(self, owner: str, stats: SwapExecStats) -> None: ...

    def drain(self, stats: SwapExecStats) -> None: ...

    # Optimizer-lane streaming (optional: engines without real streams
    # implement both as no-ops).  ``opt_swap_in`` issues the H2D copy of
    # one slot's compressed host bytes at its scheduled EO;
    # ``opt_fence`` blocks at the consuming Compute.
    def opt_swap_in(self, owner: str, nbytes: int, host_nbytes: int,
                    stats: SwapExecStats) -> None: ...

    def opt_fence(self, owner: str, stats: SwapExecStats) -> None: ...


class SyncHostEngine:
    """Synchronous host round trips (simulated DMA, bit-for-bit stable).

    ``np.asarray`` blocks until the device buffer is materialised on host;
    ``jnp.asarray`` blocks the other way.  Nothing is ever in flight, so
    fences are free and the measured overlap is undefined (None).

    ``bus_gbps`` (default None = off) applies the same emulated-bus model
    as :class:`DeviceStreamEngine`, but synchronously: a blocking engine
    occupies the bus for the transfer's full duration *at the transfer*,
    so every byte of bus time is exposed wall-clock.  This is the honest
    baseline cost the async engines exist to hide.  Numerics untouched.
    """

    name = "sync_host"

    def __init__(self, bus_gbps=None, bus_latency_s=0.0):
        import time as _time
        if bus_gbps is not None and bus_gbps <= 0:
            raise ValueError("bus_gbps must be positive (or None = off)")
        if bus_latency_s < 0:
            raise ValueError("bus_latency_s must be non-negative")
        self.bus_gbps = bus_gbps
        self.bus_latency_s = bus_latency_s
        self._sleep = _time.sleep

    def _bus_block(self, nbytes: int) -> None:
        # a blocking engine is queue-depth-1 storage I/O: every access
        # pays the full device latency, then the serial transfer
        if self.bus_gbps is not None and nbytes > 0:
            self._sleep(self.bus_latency_s
                        + nbytes / (self.bus_gbps * 1e9))

    def swap_out(self, owner: str, members: Dict[str, jax.Array],
                 nbytes: int) -> Dict[str, Any]:
        out = {m: np.asarray(a) for m, a in members.items()}
        self._bus_block(nbytes)
        return out

    def swap_in(self, owner: str, members: Dict[str, Any],
                nbytes: int) -> Dict[str, jax.Array]:
        arrays = {m: jnp.asarray(h) for m, h in members.items()}
        self._bus_block(nbytes)
        return arrays

    def fence(self, owner: str, stats: SwapExecStats) -> None:
        pass

    def drain(self, stats: SwapExecStats) -> None:
        pass

    def opt_swap_in(self, owner: str, nbytes: int, host_nbytes: int,
                    stats: SwapExecStats) -> None:
        # nothing is ever in flight, but the blocking bus still carries
        # the compressed optimizer image synchronously when paced
        self._bus_block(host_nbytes)

    def opt_fence(self, owner: str, stats: SwapExecStats) -> None:
        pass


def _host_memory_kind(device) -> Optional[str]:
    """The device's host memory space: pinned when the platform has one
    (TPU/GPU), the unpinned host space otherwise (CPU), None when the
    installed jax predates memory kinds."""
    try:
        kinds = {m.kind for m in device.addressable_memories()}
    except Exception:  # pragma: no cover - very old jax
        return None
    if "pinned_host" in kinds:
        return "pinned_host"
    if "unpinned_host" in kinds:
        return "unpinned_host"
    return None


class DeviceStreamEngine:
    """Async device-stream transfers via ``jax.device_put``.

    Swap-outs are dispatched as donated device->host copies the moment
    their op is replayed — donation releases the device buffer without a
    blocking copy-back.  Prefetches are dispatched host->device at their
    scheduled EO and left *in flight*; the consumer's read fences them.
    JAX's runtime orders a prefetch after its own swap-out automatically
    (data dependency), so no manual event chaining is needed.

    Measured stats:

    * ``inflight_high_water`` — peak bytes issued-but-not-fenced, the
      achieved double-buffer occupancy (compare: the plan's
      ``peak_inflight_prefetch``);
    * ``ready_fences / fences`` — the achieved overlap fraction: a fence
      that finds its transfer complete means the DMA fully hid behind the
      compute dispatched since the issue EO.

    ``bus_gbps`` (default None = off) emulates the paper's narrow
    storage/host bus on hardware that has none (a CPU host, where
    ``device_put`` is a memcpy): every transfer occupies one serialized
    bus for ``nbytes / bus_gbps`` seconds from issue, and a fence that
    arrives before its transfer's completion time sleeps out the
    remainder (landing in ``exposed_dma_s``, exactly like a real stall).
    A fence that arrives *after* completion pays nothing — so compute
    dispatched between issue and fence, whether the session's own or
    another session's under the phase-interleaved scheduler, genuinely
    hides the bus time in wall-clock terms.  The sim/async numerics are
    untouched; only the clock is.
    """

    name = "device_stream"

    def __init__(self, device=None, bus_gbps=None, bus_latency_s=0.0):
        import time as _time
        self._clock = _time.perf_counter
        self._sleep = _time.sleep
        if bus_gbps is not None and bus_gbps <= 0:
            raise ValueError("bus_gbps must be positive (or None = off)")
        if bus_latency_s < 0:
            raise ValueError("bus_latency_s must be non-negative")
        self.bus_gbps = bus_gbps
        self.bus_latency_s = bus_latency_s
        self._bus_free_at = 0.0      # emulated serialized-bus availability
        self.device = device if device is not None else jax.devices()[0]
        kind = _host_memory_kind(self.device)
        Single = jax.sharding.SingleDeviceSharding
        self.device_sharding = Single(self.device)
        self.host_sharding = (Single(self.device, memory_kind=kind)
                              if kind else Single(self.device))
        self.host_memory_kind = kind
        # owner -> (nbytes, arrays, issue timestamp, emulated ready time)
        self._inflight: Dict[str, Tuple[int, List[jax.Array], float,
                                        float]] = {}
        self.inflight_bytes = 0
        self.inflight_high_water = 0
        self.fences = 0
        self.ready_fences = 0
        self.stalled_fences = 0
        self.d2h_issued = 0
        self.h2d_issued = 0
        # optimizer lane: one reusable host-resident byte image per slot
        # (sized like the compressed copy the codec would store) so the
        # H2D prefetch moves real bus bytes at the scheduled EO
        self._opt_host: Dict[str, jax.Array] = {}
        self._opt_inflight: Dict[str, Tuple[int, jax.Array, float,
                                            float]] = {}
        self.opt_inflight_bytes = 0
        self.opt_inflight_high_water = 0

    def _bus_schedule(self, nbytes: int) -> float:
        """Reserve the emulated bus for ``nbytes``; returns the completion
        time (0.0 with pacing off).  The bus is serialized: a transfer
        starts when the previous one finishes, like one DMA queue.

        ``bus_latency_s`` models the storage access latency: a transfer
        issued to an *idle* bus pays it in full, but one queued behind
        an earlier transfer overlaps its access setup with that
        transfer's data movement — the amortization a deep DMA/NCQ queue
        buys and a blocking (queue-depth-1) engine never gets."""
        if self.bus_gbps is None:
            return 0.0
        start = max(self._clock() + self.bus_latency_s, self._bus_free_at)
        self._bus_free_at = start + nbytes / (self.bus_gbps * 1e9)
        return self._bus_free_at

    # ------------------------------------------------------------- issue
    def swap_out(self, owner: str, members: Dict[str, jax.Array],
                 nbytes: int) -> Dict[str, Any]:
        out = {}
        for m, a in members.items():
            out[m] = jax.device_put(a, self.host_sharding, donate=True)
            self.d2h_issued += 1
        # the d2h copy occupies the emulated bus too; its cost surfaces
        # through the completion times of the transfers queued behind it
        self._bus_schedule(nbytes)
        return out

    def swap_in(self, owner: str, members: Dict[str, Any],
                nbytes: int) -> Dict[str, jax.Array]:
        arrays = {}
        for m, h in members.items():
            arrays[m] = jax.device_put(h, self.device_sharding)
            self.h2d_issued += 1
        if arrays:
            self._inflight[owner] = (nbytes, list(arrays.values()),
                                     self._clock(),
                                     self._bus_schedule(nbytes))
            self.inflight_bytes += nbytes
            self.inflight_high_water = max(self.inflight_high_water,
                                           self.inflight_bytes)
        return arrays

    def opt_swap_in(self, owner: str, nbytes: int, host_nbytes: int,
                    stats: SwapExecStats) -> None:
        if owner in self._opt_inflight:      # already streaming this slot
            return
        host = self._opt_host.get(owner)
        if host is None or host.nbytes != host_nbytes:
            host = jax.device_put(
                np.zeros(max(1, host_nbytes), np.uint8), self.host_sharding)
            self._opt_host[owner] = host
        arr = jax.device_put(host, self.device_sharding)
        self.h2d_issued += 1
        self._opt_inflight[owner] = (host_nbytes, arr, self._clock(),
                                     self._bus_schedule(host_nbytes))
        self.opt_inflight_bytes += host_nbytes
        self.opt_inflight_high_water = max(self.opt_inflight_high_water,
                                           self.opt_inflight_bytes)

    # ------------------------------------------------------------- fence
    def fence(self, owner: str, stats: SwapExecStats) -> None:
        entry = self._inflight.pop(owner, None)
        if entry is None:
            return
        nbytes, arrays, issued, ready_at = entry
        t0 = self._clock()
        ready = all(a.is_ready() for a in arrays
                    if hasattr(a, "is_ready")) and t0 >= ready_at
        jax.block_until_ready(arrays)
        if ready_at > 0.0:
            left = ready_at - self._clock()
            if left > 0:
                self._sleep(left)        # emulated bus stall -> exposed
        self.inflight_bytes -= nbytes
        self.fences += 1
        stats.fences += 1
        stats.hidden_dma_s += t0 - issued
        stats.exposed_dma_s += self._clock() - t0
        if ready:
            self.ready_fences += 1
        else:
            self.stalled_fences += 1
            stats.stalled_fences += 1

    def opt_fence(self, owner: str, stats: SwapExecStats) -> None:
        entry = self._opt_inflight.pop(owner, None)
        if entry is None:
            return
        host_nbytes, arr, issued, ready_at = entry
        t0 = self._clock()
        ready = (arr.is_ready() if hasattr(arr, "is_ready") else True) \
            and t0 >= ready_at
        jax.block_until_ready(arr)
        if ready_at > 0.0:
            left = ready_at - self._clock()
            if left > 0:
                self._sleep(left)
        self.opt_inflight_bytes -= host_nbytes
        stats.opt_fences += 1
        stats.opt_hidden_dma_s += t0 - issued
        stats.opt_exposed_dma_s += self._clock() - t0
        if not ready:
            stats.opt_stalled_fences += 1

    def drain(self, stats: SwapExecStats) -> None:
        for owner in list(self._inflight):
            self.fence(owner, stats)
        for owner in list(self._opt_inflight):
            self.opt_fence(owner, stats)


class SessionScopedEngine:
    """Per-session view over one shared :class:`DeviceStreamEngine`.

    The phase-interleaved scheduler (:mod:`repro.serve.scheduler`) runs N
    sessions' cursors through a *single* device-stream engine so one
    tenant's DMA can hide under another's compute.  Every session replays
    the same compiled plan, so owner names collide across sessions; this
    wrapper namespaces them with the session scope and tracks which
    transfers belong to this session, so ``drain`` (end of step, or an
    abort after a mid-step kill) fences only this session's in-flight
    copies and never another tenant's.

    Per-session ``inflight_bytes`` / high-water marks are kept here — the
    shared engine's counters aggregate the whole device, which is the
    wrong denominator for a per-session stats record.
    """

    name = "session_scoped"

    def __init__(self, inner: DeviceStreamEngine, scope: str):
        self.inner = inner
        self.scope = scope
        self.host_memory_kind = getattr(inner, "host_memory_kind", None)
        self._sizes: Dict[str, int] = {}       # outstanding owner -> bytes
        self._opt_sizes: Dict[str, int] = {}
        self.inflight_bytes = 0
        self.inflight_high_water = 0
        self.opt_inflight_bytes = 0
        self.opt_inflight_high_water = 0

    def _k(self, owner: str) -> str:
        return f"{self.scope}\x1f{owner}"

    def swap_out(self, owner: str, members: Dict[str, jax.Array],
                 nbytes: int) -> Dict[str, Any]:
        return self.inner.swap_out(self._k(owner), members, nbytes)

    def swap_in(self, owner: str, members: Dict[str, Any],
                nbytes: int) -> Dict[str, jax.Array]:
        arrays = self.inner.swap_in(self._k(owner), members, nbytes)
        if arrays:
            self._sizes[owner] = nbytes
            self.inflight_bytes += nbytes
            self.inflight_high_water = max(self.inflight_high_water,
                                           self.inflight_bytes)
        return arrays

    def fence(self, owner: str, stats: SwapExecStats) -> None:
        self.inner.fence(self._k(owner), stats)
        nbytes = self._sizes.pop(owner, None)
        if nbytes is not None:
            self.inflight_bytes -= nbytes

    def opt_swap_in(self, owner: str, nbytes: int, host_nbytes: int,
                    stats: SwapExecStats) -> None:
        if owner in self._opt_sizes:
            return
        self.inner.opt_swap_in(self._k(owner), nbytes, host_nbytes, stats)
        self._opt_sizes[owner] = host_nbytes
        self.opt_inflight_bytes += host_nbytes
        self.opt_inflight_high_water = max(self.opt_inflight_high_water,
                                           self.opt_inflight_bytes)

    def opt_fence(self, owner: str, stats: SwapExecStats) -> None:
        self.inner.opt_fence(self._k(owner), stats)
        host_nbytes = self._opt_sizes.pop(owner, None)
        if host_nbytes is not None:
            self.opt_inflight_bytes -= host_nbytes

    def drain(self, stats: SwapExecStats) -> None:
        """Fence everything *this session* still has in flight."""
        for owner in list(self._sizes):
            self.fence(owner, stats)
        for owner in list(self._opt_sizes):
            self.opt_fence(owner, stats)

    @property
    def has_inflight(self) -> bool:
        return bool(self._sizes or self._opt_sizes)

    @property
    def next_ready_at(self) -> float:
        """Emulated-bus completion time of this session's *oldest*
        in-flight transfer (0.0 when nothing is pacing): the scheduler's
        stall-risk signal.  Prefetches are issued and consumed in EO
        order, so the next fence this session hits is approximately its
        oldest outstanding transfer — if that one is complete, the next
        phase advance cannot stall, however deep the issue-ahead is."""
        oldest = float("inf")
        for owner in self._sizes:
            entry = self.inner._inflight.get(self._k(owner))
            if entry is not None:
                oldest = min(oldest, entry[3])
        for owner in self._opt_sizes:
            entry = self.inner._opt_inflight.get(self._k(owner))
            if entry is not None:
                oldest = min(oldest, entry[3])
        return 0.0 if oldest == float("inf") else oldest


class ActivationStore:
    """Layer-output store with device/host tiers and post-merge alias groups.

    Keys are layer names; bytes are accounted per *owner* tensor (the
    post-merge ``X:`` CREATE owner), so an in-place activation output that
    aliases its producer's storage is neither double-counted nor separately
    swapped — swapping an owner moves every alias with it, exactly like one
    arena region moving to host.  The store holds no scheduling logic: the
    executor drives it by replaying the compiled
    :class:`repro.core.plan.ExecutionSchedule` op by op, and the wired
    :class:`TransferEngine` decides whether the bytes move synchronously
    or on a real device stream.
    """

    def __init__(self, ordered: OrderedTensors, hbm: HbmTracker,
                 host_pool: Optional[HbmTracker] = None,
                 engine: Optional[TransferEngine] = None):
        self.ordered = ordered
        self.hbm = hbm
        self.host_pool = host_pool or HbmTracker()
        self.engine = engine or SyncHostEngine()
        self.device: Dict[str, jax.Array] = {}
        self.host: Dict[str, Any] = {}
        self.members: Dict[str, Set[str]] = {}     # owner -> layer names
        self.alive: Set[str] = set()               # owners holding HBM bytes
        self._owner_cache: Dict[str, Optional[str]] = {}

    def owner_of(self, lname: str) -> Optional[str]:
        """The planned X: owner accounting this output's bytes, if any."""
        if lname in self._owner_cache:
            return self._owner_cache[lname]
        owner = self.ordered.owner(f"X:{lname}")
        spec = self.ordered.tensors.get(owner)
        tracked = (spec is not None and spec.create_mode == CreateMode.CREATE
                   and spec.merged_into is None)
        self._owner_cache[lname] = owner if tracked else None
        return self._owner_cache[lname]

    def put(self, lname: str, y: jax.Array) -> None:
        self.device[lname] = y
        owner = self.owner_of(lname)
        if owner is None:
            return
        self.members.setdefault(owner, set()).add(lname)
        if owner not in self.alive:
            self.alive.add(owner)
            self.hbm.alloc(self.ordered.tensors[owner].nbytes)

    def get(self, lname: str, stats: SwapExecStats) -> jax.Array:
        if lname in self.device:
            owner = self.owner_of(lname)
            if owner is not None:
                # consumer read: fence any prefetch still in flight for
                # this alias group (no-op on the synchronous engine)
                self.engine.fence(owner, stats)
            return self.device[lname]
        owner = self.owner_of(lname)
        if owner is not None and lname in self.host:
            # The schedule was wrong (or margins too tight): blocking swap-in.
            stats.late_swap_ins += 1
            self.swap_in(owner, stats)
            self.engine.fence(owner, stats)
            return self.device[lname]
        raise KeyError(f"activation {lname!r} neither on device nor host")

    def swap_out(self, owner: str, stats: SwapExecStats) -> None:
        nbytes = self.ordered.tensors[owner].nbytes
        moved = {}
        for m in self.members.get(owner, ()):
            if m in self.device:
                moved[m] = self.device.pop(m)
        self.host.update(self.engine.swap_out(owner, moved, nbytes))
        self.alive.discard(owner)
        self.hbm.free(nbytes)
        self.host_pool.alloc(nbytes)
        stats.swap_outs += 1
        stats.dma_bytes += nbytes

    def swap_in(self, owner: str, stats: SwapExecStats) -> None:
        nbytes = self.ordered.tensors[owner].nbytes
        moved = {}
        for m in self.members.get(owner, ()):
            if m in self.host:
                moved[m] = self.host.pop(m)
        self.device.update(self.engine.swap_in(owner, moved, nbytes))
        self.alive.add(owner)
        self.hbm.alloc(nbytes)
        self.host_pool.free(nbytes)
        stats.prefetches += 1
        stats.dma_bytes += nbytes

    def free_owner(self, owner: str) -> None:
        on_host = False
        for m in self.members.get(owner, ()):
            self.device.pop(m, None)
            on_host |= self.host.pop(m, None) is not None
        if on_host:
            self.host_pool.free(self.ordered.tensors[owner].nbytes)
        if owner in self.alive:
            self.alive.discard(owner)
            self.hbm.free(self.ordered.tensors[owner].nbytes)


# Backwards-compatible private aliases (the pre-subsystem names).
_HbmTracker = HbmTracker
_ActivationStore = ActivationStore
