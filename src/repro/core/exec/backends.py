"""Pluggable executor backends replaying the lowered ExecutionSchedule.

One interpreter, two realisations of its transfer ops:

* :class:`SimulatedBackend` (``"sim"``, the default) — synchronous host
  round trips through :class:`repro.core.exec.store.SyncHostEngine`;
  bit-for-bit the accounting the planner validation suite gates on;
* :class:`AsyncDeviceBackend` (``"async"``) — every ``SwapOut`` /
  ``Prefetch`` op is issued as a real ``jax.device_put`` against the
  device's (pinned) host memory space, *dispatched* at its scheduled EO
  and fenced only when the consumer computes, so DMA overlaps the compute
  in between (the ROADMAP "async double-buffer on real device streams"
  item).  Swap-outs donate their device buffer.  The backend measures
  ``inflight_high_water`` (achieved double-buffer occupancy) and the
  achieved-overlap fraction against the plan's
  ``peak_inflight_prefetch`` — see :meth:`AsyncDeviceBackend.report`.

Both backends replay the compiled op list *verbatim*:
``SwapExecStats.replayed_ops == lowered.ops`` is CI-gated per backend, so
a backend cannot silently skip or reorder a planned transfer.

Backends only replay *verified* schedules: a plan-backed schedule that has
not passed the static verifier (:mod:`repro.core.verify`) is verified on
admission and refused (``ScheduleVerificationError``) if unsound — the
runtime analogue of the ``compile_plan`` verify knob, so a schedule cannot
reach the device streams unchecked even when compile-time verification
was skipped.  A debug sanitizer mode (``sanitize=True`` on any backend
constructor, or ``REPRO_EXEC_SANITIZE=1``) additionally steps the
verifier's :class:`repro.core.verify.StaticResidencyModel` alongside the
real :class:`ActivationStore` and cross-checks device residency after
every replayed op.

Select a backend with ``MemoryPlanConfig(executor="sim" | "async")`` or by
passing ``executor=`` to :func:`swap_planned_loss_and_grads`; registry
lookups go through :func:`get_backend`.
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Protocol, Tuple, Union,\
    runtime_checkable

import jax

from repro.core.exec.layers import (_needs_deriv, _param_owner,
                                    layer_calc_derivative,
                                    layer_calc_gradient, layer_forward,
                                    loss_derivative, loss_forward)
from repro.core.exec.store import (ActivationStore, DeviceStreamEngine,
                                   HbmTracker, SwapExecStats, SyncHostEngine,
                                   TransferEngine)
from repro.core.execution_order import OrderedTensors, compute_execution_order
from repro.core.graph import LOSS_KINDS, WEIGHTED_KINDS, LayerGraph
from repro.core.offload import OffloadSchedule


@runtime_checkable
class ExecutorBackend(Protocol):
    """One way to execute a lowered :class:`ExecutionSchedule`.

    ``run`` performs one training iteration — replaying the op list
    verbatim — and returns ``(loss, grads, SwapExecStats)``; ``report``
    summarises what the last run did (transfer counts, high-water marks,
    and for real-stream backends the achieved overlap).
    """

    name: str

    def run(self, graph: LayerGraph, params, x, label, *,
            schedule: OffloadSchedule,
            ordered: Optional[OrderedTensors] = None,
            plan=None, lowered=None, mask=None
            ) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]],
                       SwapExecStats]: ...

    def report(self) -> Dict[str, Any]: ...


class _ReplayBackend:
    """Shared interpreter: walk the compiled op list, account residency.

    Subclasses choose the :class:`TransferEngine` wired into the store;
    everything else — layer math dispatch, alias-group accounting,
    high-water assertions, replay-equality bookkeeping — is common, so the
    two backends cannot drift apart semantically.
    """

    name = "replay"

    def __init__(self, *, sanitize: Optional[bool] = None):
        if sanitize is None:
            sanitize = os.environ.get("REPRO_EXEC_SANITIZE",
                                      "") not in ("", "0")
        self.sanitize = bool(sanitize)
        self._last_stats: Optional[SwapExecStats] = None
        self._planned_inflight: Optional[int] = None

    def make_engine(self) -> TransferEngine:
        raise NotImplementedError

    # ------------------------------------------------------------------ run
    def run(self, graph: LayerGraph, params, x, label, *,
            schedule: OffloadSchedule,
            ordered: Optional[OrderedTensors] = None,
            plan=None, lowered=None, mask=None):
        import time as _time

        from repro.core.plan import (Compute, Free, Prefetch, SwapOut,
                                     lower_schedule)
        from repro.core.verify import (StaticResidencyModel, is_verified,
                                       mark_verified, verify_schedule)
        if ordered is None:
            ordered = compute_execution_order(graph, int(x.shape[0]))
        if lowered is None:
            lowered = lower_schedule(ordered, schedule, plan)
        # admission check: a plan-backed schedule must have passed static
        # verification before any transfer op reaches a device stream —
        # verify on the spot if compile-time verification was skipped
        if plan is not None and not is_verified(lowered):
            verify_schedule(ordered, schedule, plan,
                            lowered).raise_if_errors()
            mark_verified(lowered)
        sanitizer = StaticResidencyModel(ordered) if self.sanitize else None
        t_run0 = _time.perf_counter()
        stats = SwapExecStats(backend=self.name)
        stats.inplace_prefetches = sum(
            1 for d in schedule.decisions if d.inplace)
        engine = self.make_engine()
        hbm = HbmTracker()
        store = ActivationStore(ordered, hbm, engine=engine)
        store.device["__input__"] = x

        def resolve_ctx(ctx: Any) -> Any:
            return tuple(
                store.get(e[1], stats)
                if isinstance(e, tuple) and len(e) == 2 and e[0] == "@act"
                else e
                for e in ctx
            )

        ctxs: Dict[str, Any] = {}
        derivs: Dict[str, jax.Array] = {}
        pending_dxs: Dict[str, List[Tuple[str, jax.Array]]] = {}
        pending_cd: Dict[str, Tuple[jax.Array, List[str]]] = {}
        grads: Dict[str, Dict[str, jax.Array]] = {}
        loss_val = None
        replayed: List[Any] = []
        inflight = 0
        done_at: Dict[int, int] = {}      # read EO -> prefetched bytes retiring
        retired_eo = -1

        for op_index, op in enumerate(lowered.ops):
            if isinstance(op, Prefetch):
                if op.tensor in store.alive:
                    continue  # late swap-in already brought it back
                store.swap_in(op.tensor, stats)
                inflight += op.nbytes
                done_at[op.read_eo] = done_at.get(op.read_eo, 0) + op.nbytes
                stats.peak_inflight_prefetch = max(
                    stats.peak_inflight_prefetch, inflight)
                replayed.append(op)
            elif isinstance(op, Compute):
                # prefetches issued at earlier phases complete by their read
                # EO: retire their double-buffer slots at the phase boundary
                if op.eo > retired_eo:
                    for eo in list(done_at):
                        if eo <= op.eo:
                            inflight -= done_at.pop(eo)
                    retired_eo = op.eo
                l = graph.layer(op.layer)
                lname, kind = op.layer, op.kind
                if kind == "F":
                    if l.kind in LOSS_KINDS:
                        loss_val = loss_forward(
                            l.kind, store.get(l.inputs[0], stats), label,
                            mask)
                    else:
                        xs = [store.get(i, stats) for i in l.inputs]
                        p = params.get(_param_owner(graph, l))
                        y, ctx = layer_forward(l, xs, p)
                        store.put(lname, y)
                        # keep saved activations by *reference* into the
                        # store, so a swap moves the residual too (same
                        # bytes in a real arena)
                        sym = []
                        for e in ctx:
                            hit = next(
                                (i for i, xi in enumerate(xs) if e is xi),
                                None)
                            if hit is not None:
                                sym.append(("@act", l.inputs[hit]))
                            elif e is y:
                                sym.append(("@act", lname))
                            else:
                                sym.append(e)
                        ctxs[lname] = tuple(sym)
                elif kind == "CG":
                    if l.kind in LOSS_KINDS:
                        pred = l.inputs[0]
                        derivs[pred] = loss_derivative(
                            l.kind, store.get(pred, stats), label, mask)
                    else:
                        dy = derivs.pop(lname, None)
                        if dy is not None:
                            if l.trainable and l.weight_shapes():
                                p = params.get(_param_owner(graph, l))
                                g = layer_calc_gradient(
                                    l, resolve_ctx(ctxs[lname]), dy, p)
                                owner = _param_owner(graph, l)
                                if owner in grads:
                                    grads[owner] = {k: grads[owner][k] + g[k]
                                                    for k in g}
                                else:
                                    grads[owner] = g
                            upstream_needed = [
                                i for i in l.inputs
                                if i != "__input__" and _needs_deriv(graph, i)
                            ]
                            if not upstream_needed:
                                pass
                            elif l.kind in WEIGHTED_KINDS:
                                # A weighted layer's saved input has a F+CG
                                # lifespan — it is freed (or swapped) right
                                # after this phase — so its derivative is
                                # computed here, on the same resident
                                # context the CG just used, and *published*
                                # at the adjacent CD phase
                                # (EO_CD = EO_CG + 1).
                                p = params.get(_param_owner(graph, l))
                                dxs = layer_calc_derivative(
                                    l, resolve_ctx(ctxs[lname]), dy, p)
                                pending_dxs[lname] = [
                                    (inp, dx)
                                    for inp, dx in zip(l.inputs, dxs)
                                    if inp != "__input__"
                                    and inp in upstream_needed
                                ]
                            else:
                                # In-place / pool / view layers have F+CD
                                # contexts (e.g. max-pool argmax source,
                                # activation output) — residency and
                                # prefetches target the CD phase.
                                pending_cd[lname] = (dy, upstream_needed)
                else:  # CD: compute deferred derivatives, publish D:<inp>
                    dxs_out = pending_dxs.pop(lname, [])
                    if lname in pending_cd:
                        dy, upstream_needed = pending_cd.pop(lname)
                        p = params.get(_param_owner(graph, l))
                        dxs = layer_calc_derivative(
                            l, resolve_ctx(ctxs[lname]), dy, p)
                        dxs_out = [
                            (inp, dx) for inp, dx in zip(l.inputs, dxs)
                            if inp != "__input__" and inp in upstream_needed
                        ]
                    for inp, dx in dxs_out:
                        if inp in derivs:
                            derivs[inp] = derivs[inp] + dx
                        else:
                            derivs[inp] = dx
                replayed.append(op)
            elif isinstance(op, SwapOut):
                if op.tensor in store.alive:
                    store.swap_out(op.tensor, stats)
                    replayed.append(op)
            elif isinstance(op, Free):
                store.free_owner(op.tensor)
                replayed.append(op)
            if sanitizer is not None:
                sanitizer.step(op)
                sanitizer.cross_check(store.alive, op_index)
                stats.sanitizer_checks += 1

        engine.drain(stats)
        stats.wall_time_s = _time.perf_counter() - t_run0
        stats.hbm_high_water = hbm.high_water
        stats.host_high_water = store.host_pool.high_water
        stats.replayed_ops = tuple(replayed)
        self._finalize_stats(stats, engine)
        self._last_stats = stats
        self._planned_inflight = schedule.peak_inflight_prefetch
        if plan is not None:
            stats.planned_peak = plan.activation_residency_peak()
            stats.planned_host_pool = plan.host_pool_bytes
            if stats.hbm_high_water > stats.planned_peak:
                raise AssertionError(
                    f"swap executor exceeded the planned residency peak: "
                    f"{stats.hbm_high_water} > {stats.planned_peak} bytes")
            if stats.host_high_water > stats.planned_host_pool:
                raise AssertionError(
                    f"swap executor exceeded the packed host pool: "
                    f"{stats.host_high_water} > {stats.planned_host_pool} "
                    f"bytes")
        return loss_val, grads, stats

    def _finalize_stats(self, stats: SwapExecStats,
                        engine: TransferEngine) -> None:
        pass

    # --------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """Summary of the last :meth:`run` (transfer counts + high waters)."""
        if self._last_stats is None:
            raise RuntimeError(
                f"{type(self).__name__}.report() needs a completed run()")
        s = self._last_stats
        return {
            "backend": s.backend,
            "swap_outs": s.swap_outs,
            "prefetches": s.prefetches,
            "dma_bytes": s.dma_bytes,
            "late_swap_ins": s.late_swap_ins,
            "hbm_high_water": s.hbm_high_water,
            "host_high_water": s.host_high_water,
            "peak_inflight_prefetch": s.peak_inflight_prefetch,
            "planned_peak_inflight_prefetch": self._planned_inflight,
            "sanitizer_checks": s.sanitizer_checks,
            "wall_time_s": s.wall_time_s,
        }


class SimulatedBackend(_ReplayBackend):
    """Today's synchronous replay — the default executor backend.

    Every transfer op blocks until its bytes land, so scheduling effects
    are fully deterministic and the measured stats are bit-for-bit the
    values the planner-validation tests have always asserted."""

    name = "sim"

    def make_engine(self) -> TransferEngine:
        return SyncHostEngine()


class AsyncDeviceBackend(_ReplayBackend):
    """Issue the compiled transfer ops on real device streams.

    ``SwapOut`` lowers to ``jax.device_put(arr, <host memory>, donate=True)``
    dispatched (not awaited) during its scheduled phase; ``Prefetch``
    lowers to the host->device put issued ``prefetch_margin`` phases ahead
    of the read and fenced only when the consuming compute actually touches
    the tensor.  On platforms with a ``pinned_host`` memory space (TPU,
    GPU) the copies are genuine DMA against pinned memory; on CPU the
    ``unpinned_host`` space keeps the same dispatch/fence structure for
    testing.  ``report()`` carries the achieved overlap."""

    name = "async"

    def __init__(self, device=None, *, sanitize: Optional[bool] = None):
        super().__init__(sanitize=sanitize)
        self.device = device
        self._last_engine: Optional[DeviceStreamEngine] = None

    def make_engine(self) -> TransferEngine:
        self._last_engine = DeviceStreamEngine(self.device)
        return self._last_engine

    def _finalize_stats(self, stats: SwapExecStats,
                        engine: TransferEngine) -> None:
        assert isinstance(engine, DeviceStreamEngine)
        stats.inflight_high_water = engine.inflight_high_water
        stats.fences = engine.fences
        stats.stalled_fences = engine.stalled_fences
        stats.achieved_overlap = (engine.ready_fences / engine.fences
                                  if engine.fences else None)

    def report(self) -> Dict[str, Any]:
        out = super().report()
        s = self._last_stats
        planned = self._planned_inflight
        out.update({
            "host_memory_kind": (self._last_engine.host_memory_kind
                                 if self._last_engine else None),
            "inflight_high_water": s.inflight_high_water,
            "fences": s.fences,
            "stalled_fences": s.stalled_fences,
            "achieved_overlap": s.achieved_overlap,
            # measured double-buffer occupancy vs what the plan budgeted —
            # <= 1.0 means the stream never held more than planned
            "inflight_vs_planned": (s.inflight_high_water / planned
                                    if planned else None),
        })
        return out


# Registry: MemoryPlanConfig.executor values -> backend factories.
BACKENDS = {
    SimulatedBackend.name: SimulatedBackend,
    AsyncDeviceBackend.name: AsyncDeviceBackend,
}


def get_backend(executor: Union[str, ExecutorBackend, None]
                ) -> ExecutorBackend:
    """Resolve an executor selection to a backend instance.

    ``None`` means the default (``"sim"``); a string is looked up in
    :data:`BACKENDS` (unknown names raise with the valid options); an
    :class:`ExecutorBackend` instance passes through untouched, the hook
    for custom backends."""
    if executor is None:
        executor = SimulatedBackend.name
    if isinstance(executor, str):
        cls = BACKENDS.get(executor)
        if cls is None:
            raise ValueError(
                f"unknown executor backend {executor!r}; "
                f"valid: {sorted(BACKENDS)}")
        return cls()
    if isinstance(executor, ExecutorBackend):
        return executor
    raise TypeError(
        f"executor must be a backend name {sorted(BACKENDS)} or an "
        f"ExecutorBackend instance, got {type(executor).__name__}")


def swap_planned_loss_and_grads(
    graph: LayerGraph,
    params: Dict[str, Dict[str, jax.Array]],
    x: jax.Array, label: jax.Array, *,
    schedule: OffloadSchedule,
    ordered: Optional[OrderedTensors] = None,
    plan: Optional["SwapAwarePlan"] = None,  # noqa: F821
    lowered: Optional["ExecutionSchedule"] = None,  # noqa: F821
    executor: Union[str, ExecutorBackend, None] = None,
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]], SwapExecStats]:
    """One layer-basis iteration replaying the compiled op list.

    Identical numerics to :func:`repro.core.exec.layers.planned_loss_and_grads`
    (arrays round-trip through host exactly), but walks the lowered
    :class:`repro.core.plan.ExecutionSchedule` directly: every ``Compute``,
    ``SwapOut``, ``Prefetch`` and ``Free`` was decided at compile time, so
    the executor holds no scheduling policy — it replays ops and accounts
    HBM / host-pool residency high-water marks.  When no ``lowered``
    schedule is supplied (hand-wired callers) it is derived here from
    ``schedule``/``plan``.  With a :class:`SwapAwarePlan`, asserts the
    measured high-water marks never exceed the planned residency peak and
    the packed host pool.  ``executor`` picks the backend ("sim" default,
    "async" for real device streams) — see :func:`get_backend`.
    """
    return get_backend(executor).run(
        graph, params, x, label, schedule=schedule, ordered=ordered,
        plan=plan, lowered=lowered, mask=mask)
