"""Pluggable executor backends replaying the lowered ExecutionSchedule.

One interpreter, two realisations of its transfer ops:

* :class:`SimulatedBackend` (``"sim"``, the default) — synchronous host
  round trips through :class:`repro.core.exec.store.SyncHostEngine`;
  bit-for-bit the accounting the planner validation suite gates on;
* :class:`AsyncDeviceBackend` (``"async"``) — every ``SwapOut`` /
  ``Prefetch`` op is issued as a real ``jax.device_put`` against the
  device's (pinned) host memory space, *dispatched* at its scheduled EO
  and fenced only when the consumer computes, so DMA overlaps the compute
  in between (the ROADMAP "async double-buffer on real device streams"
  item).  Swap-outs donate their device buffer.  The backend measures
  ``inflight_high_water`` (achieved double-buffer occupancy) and the
  achieved-overlap fraction against the plan's
  ``peak_inflight_prefetch`` — see :meth:`AsyncDeviceBackend.report`.
* :class:`JitBlocksBackend` (``"jit_blocks"``) — async transfers plus
  jit-fused compute dispatch: the static dependence prover
  (:mod:`repro.core.verify.deps`) partitions the op list into
  fusion-legal ``Compute`` runs and each run replays as a *single*
  ``jax.jit`` call, collapsing the per-op Python dispatch loop.

The ``sim`` and ``async`` backends replay the compiled op list
*verbatim*: ``SwapExecStats.replayed_ops == lowered.ops`` is CI-gated per
backend, so a backend cannot silently skip or reorder a planned transfer.
``jit_blocks`` replays a *proven-equivalent permutation* instead — same
op multiset, every dependence edge preserved — and is admitted only after
:func:`repro.core.verify.schedules_equivalent` signs off on its fused
replay stream; CI gates that proof rather than positional equality.

Backends only replay *verified* schedules: a plan-backed schedule that has
not passed the static verifier (:mod:`repro.core.verify`) is verified on
admission and refused (``ScheduleVerificationError``) if unsound — the
runtime analogue of the ``compile_plan`` verify knob, so a schedule cannot
reach the device streams unchecked even when compile-time verification
was skipped.  A debug sanitizer mode (``sanitize=True`` on any backend
constructor, or ``REPRO_EXEC_SANITIZE=1``) additionally steps the
verifier's :class:`repro.core.verify.StaticResidencyModel` alongside the
real :class:`ActivationStore` and cross-checks device residency after
every replayed op.

Select a backend with ``MemoryPlanConfig(executor="sim" | "async")`` or by
passing ``executor=`` to :func:`swap_planned_loss_and_grads`; registry
lookups go through :func:`get_backend`.
"""

from __future__ import annotations

import dataclasses
import os
import weakref
from typing import Any, Dict, List, Optional, Protocol, Tuple, Union,\
    runtime_checkable

import jax
import numpy as np

from repro.core.exec.layers import (_needs_deriv, _param_owner,
                                    layer_calc_derivative,
                                    layer_calc_gradient, layer_forward,
                                    loss_derivative, loss_forward)
from repro.core.exec.store import (ActivationStore, DeviceStreamEngine,
                                   HbmTracker, SwapExecStats, SyncHostEngine,
                                   TransferEngine)
from repro.core.execution_order import OrderedTensors, compute_execution_order
from repro.core.graph import LOSS_KINDS, WEIGHTED_KINDS, LayerGraph
from repro.core.offload import OffloadSchedule


@runtime_checkable
class ExecutorBackend(Protocol):
    """One way to execute a lowered :class:`ExecutionSchedule`.

    ``run`` performs one training iteration — replaying the op list
    verbatim — and returns ``(loss, grads, SwapExecStats)``; ``report``
    summarises what the last run did (transfer counts, high-water marks,
    and for real-stream backends the achieved overlap).
    """

    name: str

    def run(self, graph: LayerGraph, params, x, label, *,
            schedule: OffloadSchedule,
            ordered: Optional[OrderedTensors] = None,
            plan=None, lowered=None, mask=None
            ) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]],
                       SwapExecStats]: ...

    def report(self) -> Dict[str, Any]: ...


class _ComputeEnv:
    """The ``Compute``-op interpreter, decoupled from the ActivationStore.

    All layer math and backward-state threading (saved contexts, pending
    derivatives, gradient accumulation) lives here, parameterised over
    ``get``/``put`` activation accessors.  The per-op backends wire those
    to the live :class:`ActivationStore` (fencing on read); the
    ``jit_blocks`` backend wires them to a plain dict so a whole run of
    phases traces into one XLA computation.  One interpreter, both
    realisations — the two paths cannot drift apart semantically.
    """

    def __init__(self, graph: LayerGraph, params, label, mask, *, get, put):
        self.graph = graph
        self.params = params
        self.label = label
        self.mask = mask
        self.get = get          # (layer name) -> activation array
        self.put = put          # (layer name, array) -> None
        self.ctxs: Dict[str, Any] = {}
        self.derivs: Dict[str, jax.Array] = {}
        self.pending_dxs: Dict[str, List[Tuple[str, jax.Array]]] = {}
        self.pending_cd: Dict[str, Tuple[jax.Array, List[str]]] = {}
        self.grads: Dict[str, Dict[str, jax.Array]] = {}
        self.loss_val = None

    def resolve_ctx(self, ctx: Any) -> Any:
        return tuple(
            self.get(e[1])
            if isinstance(e, tuple) and len(e) == 2 and e[0] == "@act"
            else e
            for e in ctx
        )

    def read_names(self, op) -> List[str]:
        """Activation names this Compute may read — the consumer-fence set
        (its layer inputs plus its own output, which backward ctxs
        reference)."""
        return list(self.graph.layer(op.layer).inputs) + [op.layer]

    def step(self, op) -> None:
        """Execute one ``Compute`` op (kind "F" / "CG" / "CD")."""
        graph, params, label, mask = \
            self.graph, self.params, self.label, self.mask
        l = graph.layer(op.layer)
        lname, kind = op.layer, op.kind
        if kind == "F":
            if l.kind in LOSS_KINDS:
                self.loss_val = loss_forward(
                    l.kind, self.get(l.inputs[0]), label, mask)
            else:
                xs = [self.get(i) for i in l.inputs]
                p = params.get(_param_owner(graph, l))
                y, ctx = layer_forward(l, xs, p)
                self.put(lname, y)
                # keep saved activations by *reference* into the
                # store, so a swap moves the residual too (same
                # bytes in a real arena)
                sym = []
                for e in ctx:
                    hit = next(
                        (i for i, xi in enumerate(xs) if e is xi),
                        None)
                    if hit is not None:
                        sym.append(("@act", l.inputs[hit]))
                    elif e is y:
                        sym.append(("@act", lname))
                    else:
                        sym.append(e)
                self.ctxs[lname] = tuple(sym)
        elif kind == "CG":
            if l.kind in LOSS_KINDS:
                pred = l.inputs[0]
                self.derivs[pred] = loss_derivative(
                    l.kind, self.get(pred), label, mask)
            else:
                dy = self.derivs.pop(lname, None)
                if dy is not None:
                    if l.trainable and l.weight_shapes():
                        p = params.get(_param_owner(graph, l))
                        g = layer_calc_gradient(
                            l, self.resolve_ctx(self.ctxs[lname]), dy, p)
                        owner = _param_owner(graph, l)
                        if owner in self.grads:
                            self.grads[owner] = {
                                k: self.grads[owner][k] + g[k] for k in g}
                        else:
                            self.grads[owner] = g
                    upstream_needed = [
                        i for i in l.inputs
                        if i != "__input__" and _needs_deriv(graph, i)
                    ]
                    if not upstream_needed:
                        pass
                    elif l.kind in WEIGHTED_KINDS:
                        # A weighted layer's saved input has a F+CG
                        # lifespan — it is freed (or swapped) right
                        # after this phase — so its derivative is
                        # computed here, on the same resident
                        # context the CG just used, and *published*
                        # at the adjacent CD phase
                        # (EO_CD = EO_CG + 1).
                        p = params.get(_param_owner(graph, l))
                        dxs = layer_calc_derivative(
                            l, self.resolve_ctx(self.ctxs[lname]), dy, p)
                        self.pending_dxs[lname] = [
                            (inp, dx)
                            for inp, dx in zip(l.inputs, dxs)
                            if inp != "__input__"
                            and inp in upstream_needed
                        ]
                    else:
                        # In-place / pool / view layers have F+CD
                        # contexts (e.g. max-pool argmax source,
                        # activation output) — residency and
                        # prefetches target the CD phase.
                        self.pending_cd[lname] = (dy, upstream_needed)
        else:  # CD: compute deferred derivatives, publish D:<inp>
            dxs_out = self.pending_dxs.pop(lname, [])
            if lname in self.pending_cd:
                dy, upstream_needed = self.pending_cd.pop(lname)
                p = params.get(_param_owner(graph, l))
                dxs = layer_calc_derivative(
                    l, self.resolve_ctx(self.ctxs[lname]), dy, p)
                dxs_out = [
                    (inp, dx) for inp, dx in zip(l.inputs, dxs)
                    if inp != "__input__" and inp in upstream_needed
                ]
            for inp, dx in dxs_out:
                if inp in self.derivs:
                    self.derivs[inp] = self.derivs[inp] + dx
                else:
                    self.derivs[inp] = dx


def _check_opt_high_water(plan, stats: SwapExecStats) -> None:
    """Assert the replayed optimizer residency against the packed region
    (the optimizer-lane analogue of the activation residency-peak gate)."""
    optim = getattr(plan, "optim", None)
    if optim is not None \
            and stats.opt_device_high_water > optim.device_peak_bytes:
        raise AssertionError(
            f"optimizer working region exceeded the packed peak: "
            f"{stats.opt_device_high_water} > {optim.device_peak_bytes} "
            f"bytes")


class ScheduleCursor:
    """Resumable replay of one lowered schedule, preemptible at phase
    boundaries.

    Produced by :meth:`_ReplayBackend.start` (which runs the same verified
    admission as :meth:`run` — a cursor never exists for an unverified
    plan-backed schedule).  :meth:`advance` executes exactly one *phase*
    (every op sharing one EO: prefetches, the compute, swap-outs, frees)
    and returns True while phases remain; the phase boundary is the
    natural preemption point the serve-layer :class:`StepScheduler`
    round-robins sessions at, because all of this phase's DMA has been
    *issued* but need not be *fenced* until a later phase computes.

    After the last phase, :meth:`result` returns ``(loss, grads, stats)``
    with the same end-of-run drain, high-water assertions and stats
    finalisation ``run()`` has always performed.  :meth:`abort` abandons a
    step mid-flight: this cursor's in-flight transfers are fenced (so a
    shared engine holds no dangling references into the dead store) and
    every activation reference is dropped, making the session's arena
    share immediately reusable.

    ``stats.wall_time_s`` accumulates only the time spent *inside*
    ``advance``/``result`` — under interleaving, the wall-clock a session
    spends preempted is other tenants' compute, not this step's cost.
    """

    def __init__(self, backend: "_ReplayBackend", graph: LayerGraph,
                 params, x, label, *, schedule: OffloadSchedule,
                 ordered: OrderedTensors, plan, lowered, mask,
                 engine: TransferEngine, sanitizer, tag: str = ""):
        import time as _time

        self._clock = _time.perf_counter
        self.backend = backend
        self.graph = graph
        self.schedule = schedule
        self.ordered = ordered
        self.plan = plan
        self.lowered = lowered
        self.tag = tag
        self.engine = engine
        self.sanitizer = sanitizer
        self.stats = SwapExecStats(backend=backend.name)
        self.stats.inplace_prefetches = sum(
            1 for d in schedule.decisions if d.inplace)
        self.hbm = HbmTracker()
        self.store = ActivationStore(ordered, self.hbm, engine=engine)
        self.store.device["__input__"] = x
        self.env = _ComputeEnv(graph, params, label, mask,
                               get=lambda n: self.store.get(n, self.stats),
                               put=self.store.put)
        self._replayed: List[Any] = []
        self._inflight = 0
        self._opt_resident = 0
        self._done_at: Dict[int, int] = {}
        self._opt_fence_at: Dict[int, List[str]] = {}
        self._retired_eo = -1
        # phase groups: runs of ops sharing one EO, in schedule order
        self._phases: List[List[Tuple[int, Any]]] = []
        cur_eo = None
        for i, op in enumerate(lowered.ops):
            if cur_eo is None or op.eo != cur_eo:
                self._phases.append([])
                cur_eo = op.eo
            self._phases[-1].append((i, op))
        self._next_phase = 0
        self._finished = False
        self.aborted = False
        self.last_advance_s = 0.0
        self._result: Optional[Tuple] = None

    # ------------------------------------------------------------ driving
    @property
    def phases_total(self) -> int:
        return len(self._phases)

    @property
    def phases_done(self) -> int:
        return self._next_phase

    @property
    def has_inflight_dma(self) -> bool:
        """True while this cursor has issued-but-unfenced transfers —
        the condition under which another session's compute hides them."""
        return bool(getattr(self.engine, "has_inflight", False)
                    or getattr(self.engine, "inflight_bytes", 0)
                    or getattr(self.engine, "opt_inflight_bytes", 0))

    def advance(self) -> bool:
        """Execute one phase; True while more phases remain."""
        if self._finished:
            return False
        t0 = self._clock()
        for op_index, op in self._phases[self._next_phase]:
            self._exec_op(op, op_index)
        self._next_phase += 1
        self.last_advance_s = self._clock() - t0
        self.stats.wall_time_s += self.last_advance_s
        if self._next_phase >= len(self._phases):
            self._finish()
            return False
        return True

    def result(self):
        """``(loss, grads, stats)`` — only after the cursor is exhausted."""
        if not self._finished or self._result is None:
            raise RuntimeError(
                "ScheduleCursor.result() before the cursor finished"
                + (" (aborted)" if self.aborted else ""))
        return self._result

    def abort(self) -> None:
        """Abandon the step at a phase boundary (mid-step kill): fence this
        session's in-flight transfers and release every activation
        reference.  The cursor yields no result."""
        if self._finished:
            return
        self.engine.drain(self.stats)
        self.store.device.clear()
        self.store.host.clear()
        self.store.alive.clear()
        self._finished = True
        self.aborted = True

    # ----------------------------------------------------------- op body
    def _exec_op(self, op, op_index: int) -> None:
        from repro.core.plan import (Compute, Free, OptPrefetch, OptSwapOut,
                                     Prefetch, SwapOut)

        stats, store = self.stats, self.store
        if isinstance(op, OptPrefetch):
            # optimizer working state lands in its own device region; the
            # numerical dance (dequantize, AdamW update, EF requantize)
            # runs in repro.core.optim_offload — the replay accounts
            # residency/bus traffic and, on real-stream engines, issues
            # the H2D of the compressed host copy *now* and fences it at
            # the first Compute of its read EO, so the opt DMA hides
            # behind the compute dispatched in between
            self._opt_resident += op.nbytes
            stats.opt_device_high_water = max(
                stats.opt_device_high_water, self._opt_resident)
            stats.opt_prefetches += 1
            stats.opt_dma_bytes += op.host_nbytes
            self.engine.opt_swap_in(op.tensor, op.nbytes, op.host_nbytes,
                                    stats)
            self._opt_fence_at.setdefault(op.read_eo, []).append(op.tensor)
            self._replayed.append(op)
        elif isinstance(op, OptSwapOut):
            self._opt_resident -= op.nbytes
            stats.opt_swap_outs += 1
            stats.opt_dma_bytes += op.nbytes
            stats.opt_compressed_bytes += op.host_nbytes
            self._replayed.append(op)
        elif isinstance(op, Prefetch):
            if op.tensor in store.alive:
                return  # late swap-in already brought it back
            store.swap_in(op.tensor, stats)
            self._inflight += op.nbytes
            self._done_at[op.read_eo] = \
                self._done_at.get(op.read_eo, 0) + op.nbytes
            stats.peak_inflight_prefetch = max(
                stats.peak_inflight_prefetch, self._inflight)
            self._replayed.append(op)
        elif isinstance(op, Compute):
            # prefetches issued at earlier phases complete by their read
            # EO: retire their double-buffer slots at the phase boundary,
            # and fence optimizer slots whose read EO has arrived
            if op.eo > self._retired_eo:
                for eo in list(self._done_at):
                    if eo <= op.eo:
                        self._inflight -= self._done_at.pop(eo)
                for eo in list(self._opt_fence_at):
                    if eo <= op.eo:
                        for owner in self._opt_fence_at.pop(eo):
                            self.engine.opt_fence(owner, stats)
                self._retired_eo = op.eo
            self.env.step(op)
            self._replayed.append(op)
        elif isinstance(op, SwapOut):
            if op.tensor in store.alive:
                store.swap_out(op.tensor, stats)
                self._replayed.append(op)
        elif isinstance(op, Free):
            store.free_owner(op.tensor)
            self._replayed.append(op)
        if self.sanitizer is not None:
            self.sanitizer.step(op)
            self.sanitizer.cross_check(store.alive, op_index)
            stats.sanitizer_checks += 1

    # ---------------------------------------------------------- finalise
    def _finish(self) -> None:
        t0 = self._clock()
        stats, plan = self.stats, self.plan
        self.engine.drain(stats)
        stats.wall_time_s += self._clock() - t0
        stats.hbm_high_water = self.hbm.high_water
        stats.host_high_water = self.store.host_pool.high_water
        stats.replayed_ops = tuple(self._replayed)
        stats.dispatch_calls = len(self._replayed)
        self.backend._finalize_stats(stats, self.engine)
        self.backend._last_stats = stats
        self.backend._planned_inflight = self.schedule.peak_inflight_prefetch
        if plan is not None:
            stats.planned_peak = plan.activation_residency_peak()
            stats.planned_host_pool = plan.host_pool_bytes
            if stats.hbm_high_water > stats.planned_peak:
                raise AssertionError(
                    f"swap executor exceeded the planned residency peak: "
                    f"{stats.hbm_high_water} > {stats.planned_peak} bytes")
            if stats.host_high_water > stats.planned_host_pool:
                raise AssertionError(
                    f"swap executor exceeded the packed host pool: "
                    f"{stats.host_high_water} > {stats.planned_host_pool} "
                    f"bytes")
        _check_opt_high_water(plan, stats)
        self._finished = True
        self._result = (self.env.loss_val, self.env.grads, stats)


class _ReplayBackend:
    """Shared interpreter: walk the compiled op list, account residency.

    Subclasses choose the :class:`TransferEngine` wired into the store;
    everything else — layer math dispatch, alias-group accounting,
    high-water assertions, replay-equality bookkeeping — is common, so the
    two backends cannot drift apart semantically.
    """

    name = "replay"

    def __init__(self, *, sanitize: Optional[bool] = None):
        if sanitize is None:
            sanitize = os.environ.get("REPRO_EXEC_SANITIZE",
                                      "") not in ("", "0")
        self.sanitize = bool(sanitize)
        self._last_stats: Optional[SwapExecStats] = None
        self._planned_inflight: Optional[int] = None

    def make_engine(self) -> TransferEngine:
        raise NotImplementedError

    # ---------------------------------------------------------------- start
    def start(self, graph: LayerGraph, params, x, label, *,
              schedule: OffloadSchedule,
              ordered: Optional[OrderedTensors] = None,
              plan=None, lowered=None, mask=None,
              engine: Optional[TransferEngine] = None,
              tag: str = "") -> ScheduleCursor:
        """Admit a schedule and return a resumable :class:`ScheduleCursor`.

        This is the preemptible entry point the phase-interleaved serve
        scheduler drives: the same verified admission as :meth:`run`, but
        the caller chooses when each phase executes (and may supply a
        shared ``engine`` — e.g. a session-scoped view over one
        :class:`DeviceStreamEngine` — so several cursors' DMAs interleave
        on one device stream).
        """
        from repro.core.plan import lower_schedule
        from repro.core.verify import (StaticResidencyModel, is_verified,
                                       mark_verified, verify_schedule)
        if ordered is None:
            ordered = compute_execution_order(graph, int(x.shape[0]))
        if lowered is None:
            lowered = lower_schedule(ordered, schedule, plan)
        # admission check: a plan-backed schedule must have passed static
        # verification before any transfer op reaches a device stream —
        # verify on the spot if compile-time verification was skipped
        if plan is not None and not is_verified(lowered):
            verify_schedule(ordered, schedule, plan,
                            lowered).raise_if_errors()
            mark_verified(lowered)
        sanitizer = StaticResidencyModel(ordered) if self.sanitize else None
        if engine is None:
            engine = self.make_engine()
        return ScheduleCursor(self, graph, params, x, label,
                              schedule=schedule, ordered=ordered, plan=plan,
                              lowered=lowered, mask=mask, engine=engine,
                              sanitizer=sanitizer, tag=tag)

    # ------------------------------------------------------------------ run
    def run(self, graph: LayerGraph, params, x, label, *,
            schedule: OffloadSchedule,
            ordered: Optional[OrderedTensors] = None,
            plan=None, lowered=None, mask=None,
            engine: Optional[TransferEngine] = None):
        cursor = self.start(graph, params, x, label, schedule=schedule,
                            ordered=ordered, plan=plan, lowered=lowered,
                            mask=mask, engine=engine)
        while cursor.advance():
            pass
        return cursor.result()

    def _finalize_stats(self, stats: SwapExecStats,
                        engine: TransferEngine) -> None:
        pass

    # --------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """Summary of the last :meth:`run` (transfer counts + high waters)."""
        if self._last_stats is None:
            raise RuntimeError(
                f"{type(self).__name__}.report() needs a completed run()")
        s = self._last_stats
        return {
            "backend": s.backend,
            "swap_outs": s.swap_outs,
            "prefetches": s.prefetches,
            "dma_bytes": s.dma_bytes,
            "late_swap_ins": s.late_swap_ins,
            "hbm_high_water": s.hbm_high_water,
            "host_high_water": s.host_high_water,
            "peak_inflight_prefetch": s.peak_inflight_prefetch,
            "planned_peak_inflight_prefetch": self._planned_inflight,
            "sanitizer_checks": s.sanitizer_checks,
            "dispatch_calls": s.dispatch_calls,
            "replayed_op_count": len(s.replayed_ops),
            "wall_time_s": s.wall_time_s,
            "opt_swap_outs": s.opt_swap_outs,
            "opt_prefetches": s.opt_prefetches,
            "opt_dma_bytes": s.opt_dma_bytes,
            "opt_compressed_bytes": s.opt_compressed_bytes,
            "opt_device_high_water": s.opt_device_high_water,
        }


class SimulatedBackend(_ReplayBackend):
    """Today's synchronous replay — the default executor backend.

    Every transfer op blocks until its bytes land, so scheduling effects
    are fully deterministic and the measured stats are bit-for-bit the
    values the planner-validation tests have always asserted."""

    name = "sim"

    def make_engine(self) -> TransferEngine:
        return SyncHostEngine()


class AsyncDeviceBackend(_ReplayBackend):
    """Issue the compiled transfer ops on real device streams.

    ``SwapOut`` lowers to ``jax.device_put(arr, <host memory>, donate=True)``
    dispatched (not awaited) during its scheduled phase; ``Prefetch``
    lowers to the host->device put issued ``prefetch_margin`` phases ahead
    of the read and fenced only when the consuming compute actually touches
    the tensor.  On platforms with a ``pinned_host`` memory space (TPU,
    GPU) the copies are genuine DMA against pinned memory; on CPU the
    ``unpinned_host`` space keeps the same dispatch/fence structure for
    testing.  ``report()`` carries the achieved overlap."""

    name = "async"

    def __init__(self, device=None, *, sanitize: Optional[bool] = None):
        super().__init__(sanitize=sanitize)
        self.device = device
        self._last_engine: Optional[DeviceStreamEngine] = None

    def make_engine(self) -> TransferEngine:
        self._last_engine = DeviceStreamEngine(self.device)
        return self._last_engine

    def _finalize_stats(self, stats: SwapExecStats,
                        engine: TransferEngine) -> None:
        # fences/stalled_fences accumulate per call on the stats record
        # (so a session-scoped view over a shared engine still yields
        # per-session numbers); the engine contributes its in-flight
        # high-water marks — a SessionScopedEngine reports per-session
        # marks, a raw DeviceStreamEngine the whole stream's
        stats.inflight_high_water = getattr(engine, "inflight_high_water", 0)
        stats.opt_inflight_high_water = getattr(
            engine, "opt_inflight_high_water", 0)
        stats.achieved_overlap = (
            (stats.fences - stats.stalled_fences) / stats.fences
            if stats.fences else None)

    def report(self) -> Dict[str, Any]:
        out = super().report()
        s = self._last_stats
        planned = self._planned_inflight
        out.update({
            "host_memory_kind": (self._last_engine.host_memory_kind
                                 if self._last_engine else None),
            "inflight_high_water": s.inflight_high_water,
            "fences": s.fences,
            "stalled_fences": s.stalled_fences,
            "achieved_overlap": s.achieved_overlap,
            # measured double-buffer occupancy vs what the plan budgeted —
            # <= 1.0 means the stream never held more than planned
            "inflight_vs_planned": (s.inflight_high_water / planned
                                    if planned else None),
            # measured bus-time split: seconds the activation DMAs ran
            # hidden under dispatched compute vs seconds consumer fences
            # actually blocked — and the same split for the optimizer
            # lane, whose OptPrefetch H2D now streams on the real engine
            "hidden_dma_s": s.hidden_dma_s,
            "exposed_dma_s": s.exposed_dma_s,
            "opt_hidden_dma_s": s.opt_hidden_dma_s,
            "opt_exposed_dma_s": s.opt_exposed_dma_s,
            "opt_fences": s.opt_fences,
            "opt_stalled_fences": s.opt_stalled_fences,
            "opt_inflight_high_water": s.opt_inflight_high_water,
            "cross_hidden_dma_s": s.cross_hidden_dma_s,
        })
        return out


# ---------------------------------------------------------------------------
# jit_blocks: dispatch proven-fusable Compute runs as single XLA calls
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ArraySlot:
    """Skeleton placeholder for one array leaf of a flattened state."""

    index: int


def _flatten_state(obj, leaves: List[Any]):
    """Split a nested interpreter state into (skeleton, array leaves).

    ``jax.tree_util`` cannot flatten this state — saved ctx tuples mix
    arrays with strings, shape tuples and ``("@act", name)`` references —
    so these walkers treat arrays (and tracers) as leaves and everything
    else as static skeleton.  The skeleton contains no arrays, so two
    skeletons compare with ``==`` safely (the jit-cache validity check)."""
    if isinstance(obj, (jax.Array, np.ndarray)) or hasattr(obj, "aval"):
        leaves.append(obj)
        return _ArraySlot(len(leaves) - 1)
    if isinstance(obj, dict):
        return {k: _flatten_state(v, leaves) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return tuple(_flatten_state(v, leaves) for v in obj)
    if isinstance(obj, list):
        return [_flatten_state(v, leaves) for v in obj]
    return obj


def _unflatten_state(skel, leaves: List[Any]):
    if isinstance(skel, _ArraySlot):
        return leaves[skel.index]
    if isinstance(skel, dict):
        return {k: _unflatten_state(v, leaves) for k, v in skel.items()}
    if isinstance(skel, tuple):
        return tuple(_unflatten_state(v, leaves) for v in skel)
    if isinstance(skel, list):
        return [_unflatten_state(v, leaves) for v in skel]
    return skel


# Jitted block functions, keyed weakly by the lowered schedule (same
# lifetime discipline as the verifier's _VERIFIED registry): entry ->
# {(block index, mask is None): (jitted fn, input skeleton, out cell)}.
_FUSED_FN_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _make_block_fn(graph: LayerGraph, ops: Tuple[Any, ...],
                   compute_indices: Tuple[int, ...], in_skel,
                   out_cell: List[Any]):
    """Build the pure function tracing one fused block.

    Takes the flattened input state (device dict + backward state +
    params/label/mask), replays the block's ``Compute`` ops through
    :class:`_ComputeEnv` against a plain dict, and returns the flattened
    *delta*: newly produced device/ctx entries plus the whole (small)
    backward-state dicts.  The output skeleton is captured into
    ``out_cell`` at trace time."""

    def fn(leaves):
        state = _unflatten_state(in_skel, leaves)
        device = dict(state["device"])
        env = _ComputeEnv(graph, state["params"], state["label"],
                          state["mask"],
                          get=device.__getitem__, put=device.__setitem__)
        env.ctxs = dict(state["ctxs"])
        env.derivs = dict(state["derivs"])
        env.pending_dxs = dict(state["pending_dxs"])
        env.pending_cd = dict(state["pending_cd"])
        env.grads = dict(state["grads"])
        env.loss_val = state["loss"]
        before_dev, before_ctx = set(state["device"]), set(state["ctxs"])
        for ci in compute_indices:
            env.step(ops[ci])
        out = {
            "device": {k: v for k, v in device.items()
                       if k not in before_dev},
            "ctxs": {k: v for k, v in env.ctxs.items()
                     if k not in before_ctx},
            "derivs": env.derivs,
            "pending_dxs": env.pending_dxs,
            "pending_cd": env.pending_cd,
            "grads": env.grads,
            "loss": env.loss_val,
        }
        out_leaves: List[Any] = []
        out_cell.append(_flatten_state(out, out_leaves))
        return out_leaves

    return fn


class JitBlocksBackend(AsyncDeviceBackend):
    """Dispatch each proven-fusable Compute run as one jitted XLA call.

    On large graphs the per-op Python dispatch loop is the async
    backend's bottleneck and drowns the achieved-overlap measurement in
    interpreter noise (ROADMAP "Jit-fused compute dispatch").  This
    backend asks the static dependence prover
    (:mod:`repro.core.verify.deps`) for a :class:`FusionPlan` — maximal
    ``Compute`` runs crossing no transfer fence, no ``Free``-reuse hazard
    and no in-place-prefetch window — and replays each block as a single
    ``jax.jit`` call, giving every DMA a long XLA dispatch window to hide
    behind.

    Admission is strictly *prove-then-run*: beyond the base verifier
    gate, the fusion plan must pass :func:`verify_fusion` and the fused
    replay stream must pass :func:`schedules_equivalent` against the
    verified original — the backend never executes an op order the
    dependence DAG did not license.  Transfers and the ops between blocks
    stay eager (issue points unchanged), consumer fences run at block
    entry for every tensor the block reads, and the sanitizer
    cross-checks residency at block boundaries (op granularity inside a
    traced block does not exist at run time).  Jitted block functions are
    cached per lowered schedule (weak, like the verifier registry), so
    iteration 2+ pays one Python dispatch per block."""

    name = "jit_blocks"

    def run(self, graph: LayerGraph, params, x, label, *,
            schedule: OffloadSchedule,
            ordered: Optional[OrderedTensors] = None,
            plan=None, lowered=None, mask=None):
        import time as _time

        from repro.core.plan import (Compute, Free, OptPrefetch, OptSwapOut,
                                     Prefetch, SwapOut, lower_schedule)
        from repro.core.verify import (ScheduleVerificationError,
                                       StaticResidencyModel, is_verified,
                                       mark_verified, plan_fusion,
                                       replay_stream, schedules_equivalent,
                                       verify_fusion, verify_schedule)
        if ordered is None:
            ordered = compute_execution_order(graph, int(x.shape[0]))
        if lowered is None:
            lowered = lower_schedule(ordered, schedule, plan)
        if plan is not None and not is_verified(lowered):
            verify_schedule(ordered, schedule, plan,
                            lowered).raise_if_errors()
            mark_verified(lowered)
        # fusion admission: plan the blocks, re-prove them legal, and
        # prove the fused replay stream preserves every dependence edge
        # of the verified original — only then may a block dispatch
        fusion = plan_fusion(lowered, ordered, plan)
        fdiags = tuple(d for d in verify_fusion(fusion, lowered, ordered,
                                                plan)
                       if d.severity == "error")
        if fdiags:
            raise ScheduleVerificationError(fdiags)
        fused_stream = replay_stream(lowered, fusion)
        schedules_equivalent(lowered, fused_stream, ordered=ordered,
                             plan=plan).raise_if_errors()
        self._last_fusion = fusion

        sanitizer = StaticResidencyModel(ordered) if self.sanitize else None
        t_run0 = _time.perf_counter()
        stats = SwapExecStats(backend=self.name)
        stats.inplace_prefetches = sum(
            1 for d in schedule.decisions if d.inplace)
        engine = self.make_engine()
        hbm = HbmTracker()
        store = ActivationStore(ordered, hbm, engine=engine)
        store.device["__input__"] = x
        env = _ComputeEnv(graph, params, label, mask,
                          get=lambda n: store.get(n, stats),
                          put=store.put)
        ops = lowered.ops
        block_at: Dict[int, Any] = {min(b.op_indices): b
                                    for b in fusion.blocks}
        covered = {i for b in fusion.blocks for i in b.op_indices}
        cache = _FUSED_FN_CACHE.setdefault(lowered, {})

        replayed: List[Any] = []
        inflight = 0
        opt_resident = 0
        done_at: Dict[int, int] = {}
        retired_eo = -1

        def sanitize_step(op, op_index: int, *, cross: bool) -> None:
            if sanitizer is None:
                return
            sanitizer.step(op)
            if cross:
                sanitizer.cross_check(store.alive, op_index)
            stats.sanitizer_checks += 1

        for op_index, op in enumerate(ops):
            block = block_at.get(op_index)
            if block is not None:
                # retire double-buffer slots up to the block's last phase
                last_eo = ops[block.compute_indices[-1]].eo
                if last_eo > retired_eo:
                    for eo in list(done_at):
                        if eo <= last_eo:
                            inflight -= done_at.pop(eo)
                    retired_eo = last_eo
                self._exec_block(block, ops, graph, store, env, stats,
                                 params, label, mask, cache)
                stats.dispatch_calls += 1
                for ci in block.compute_indices:
                    replayed.append(ops[ci])
                    sanitize_step(ops[ci], ci, cross=False)
                for fi in block.free_indices:
                    store.free_owner(ops[fi].tensor)
                    replayed.append(ops[fi])
                    sanitize_step(ops[fi], fi,
                                  cross=fi == block.free_indices[-1])
                if sanitizer is not None and not block.free_indices:
                    sanitizer.cross_check(store.alive,
                                          block.compute_indices[-1])
                continue
            if op_index in covered:
                continue        # replayed as part of its block
            if isinstance(op, OptPrefetch):
                # optimizer ops never fuse (they are fences to the
                # dependence prover): eager accounting, one dispatch each
                opt_resident += op.nbytes
                stats.opt_device_high_water = max(
                    stats.opt_device_high_water, opt_resident)
                stats.opt_prefetches += 1
                stats.opt_dma_bytes += op.host_nbytes
                replayed.append(op)
                stats.dispatch_calls += 1
            elif isinstance(op, OptSwapOut):
                opt_resident -= op.nbytes
                stats.opt_swap_outs += 1
                stats.opt_dma_bytes += op.nbytes
                stats.opt_compressed_bytes += op.host_nbytes
                replayed.append(op)
                stats.dispatch_calls += 1
            elif isinstance(op, Prefetch):
                if op.tensor in store.alive:
                    continue
                store.swap_in(op.tensor, stats)
                inflight += op.nbytes
                done_at[op.read_eo] = done_at.get(op.read_eo, 0) + op.nbytes
                stats.peak_inflight_prefetch = max(
                    stats.peak_inflight_prefetch, inflight)
                replayed.append(op)
                stats.dispatch_calls += 1
            elif isinstance(op, Compute):
                if op.eo > retired_eo:
                    for eo in list(done_at):
                        if eo <= op.eo:
                            inflight -= done_at.pop(eo)
                    retired_eo = op.eo
                env.step(op)
                replayed.append(op)
                stats.dispatch_calls += 1
            elif isinstance(op, SwapOut):
                if op.tensor not in store.alive:
                    continue
                store.swap_out(op.tensor, stats)
                replayed.append(op)
                stats.dispatch_calls += 1
            elif isinstance(op, Free):
                store.free_owner(op.tensor)
                replayed.append(op)
                stats.dispatch_calls += 1
            sanitize_step(op, op_index, cross=True)

        engine.drain(stats)
        stats.wall_time_s = _time.perf_counter() - t_run0
        stats.hbm_high_water = hbm.high_water
        stats.host_high_water = store.host_pool.high_water
        stats.replayed_ops = tuple(replayed)
        self._finalize_stats(stats, engine)
        self._last_stats = stats
        self._planned_inflight = schedule.peak_inflight_prefetch
        if plan is not None:
            stats.planned_peak = plan.activation_residency_peak()
            stats.planned_host_pool = plan.host_pool_bytes
            if stats.hbm_high_water > stats.planned_peak:
                raise AssertionError(
                    f"swap executor exceeded the planned residency peak: "
                    f"{stats.hbm_high_water} > {stats.planned_peak} bytes")
            if stats.host_high_water > stats.planned_host_pool:
                raise AssertionError(
                    f"swap executor exceeded the packed host pool: "
                    f"{stats.host_high_water} > {stats.planned_host_pool} "
                    f"bytes")
        _check_opt_high_water(plan, stats)
        return env.loss_val, env.grads, stats

    def _exec_block(self, block, ops, graph, store, env, stats,
                    params, label, mask, cache) -> None:
        """Fence the block's inputs, then dispatch it as one jitted call
        and fold the produced state back into the live store."""
        # consumer fences: every tensor the block reads must have its
        # in-flight DMA fenced before the traced computation touches the
        # bytes.  Device-resident names only: read_names over-approximates
        # (a CG/CD lists all layer inputs even when its planned read is a
        # later phase), and fencing a host-resident name would late-swap
        # it in ahead of its scheduled Prefetch.  The verifier's
        # use_before_resident pass proves every tensor a block compute
        # actually reads was prefetched before the block (blocks contain
        # no transfers), i.e. is already in store.device here.
        for ci in block.compute_indices:
            for name in env.read_names(ops[ci]):
                if name in store.device:
                    store.get(name, stats)
        state = {
            "device": dict(store.device),
            "ctxs": env.ctxs,
            "derivs": env.derivs,
            "pending_dxs": env.pending_dxs,
            "pending_cd": env.pending_cd,
            "grads": env.grads,
            "loss": env.loss_val,
            "params": params,
            "label": label,
            "mask": mask,
        }
        leaves: List[Any] = []
        in_skel = _flatten_state(state, leaves)
        cache_key = (block.index, mask is None)
        entry = cache.get(cache_key)
        if entry is None or entry[1] != in_skel:
            out_cell: List[Any] = []
            fn = jax.jit(_make_block_fn(graph, ops,
                                        block.compute_indices, in_skel,
                                        out_cell))
            entry = (fn, in_skel, out_cell)
            cache[cache_key] = entry
        fn, _, out_cell = entry
        out_leaves = fn(leaves)
        out = _unflatten_state(out_cell[-1], list(out_leaves))
        for k, v in out["device"].items():
            store.put(k, v)
        env.ctxs.update(out["ctxs"])
        env.derivs = out["derivs"]
        env.pending_dxs = out["pending_dxs"]
        env.pending_cd = out["pending_cd"]
        env.grads = out["grads"]
        env.loss_val = out["loss"]

    def report(self) -> Dict[str, Any]:
        out = super().report()
        fusion = getattr(self, "_last_fusion", None)
        if fusion is not None:
            out["fusion"] = fusion.summary()
        return out


# Registry: MemoryPlanConfig.executor values -> backend factories.
BACKENDS = {
    SimulatedBackend.name: SimulatedBackend,
    AsyncDeviceBackend.name: AsyncDeviceBackend,
    JitBlocksBackend.name: JitBlocksBackend,
}


def get_backend(executor: Union[str, ExecutorBackend, None]
                ) -> ExecutorBackend:
    """Resolve an executor selection to a backend instance.

    ``None`` means the default (``"sim"``); a string is looked up in
    :data:`BACKENDS` (unknown names raise with the valid options); an
    :class:`ExecutorBackend` instance passes through untouched, the hook
    for custom backends."""
    if executor is None:
        executor = SimulatedBackend.name
    if isinstance(executor, str):
        cls = BACKENDS.get(executor)
        if cls is None:
            raise ValueError(
                f"unknown executor backend {executor!r}; "
                f"valid: {sorted(BACKENDS)}")
        return cls()
    if isinstance(executor, ExecutorBackend):
        return executor
    raise TypeError(
        f"executor must be a backend name {sorted(BACKENDS)} or an "
        f"ExecutorBackend instance, got {type(executor).__name__}")


def swap_planned_loss_and_grads(
    graph: LayerGraph,
    params: Dict[str, Dict[str, jax.Array]],
    x: jax.Array, label: jax.Array, *,
    schedule: OffloadSchedule,
    ordered: Optional[OrderedTensors] = None,
    plan: Optional["SwapAwarePlan"] = None,  # noqa: F821
    lowered: Optional["ExecutionSchedule"] = None,  # noqa: F821
    executor: Union[str, ExecutorBackend, None] = None,
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]], SwapExecStats]:
    """One layer-basis iteration replaying the compiled op list.

    Identical numerics to :func:`repro.core.exec.layers.planned_loss_and_grads`
    (arrays round-trip through host exactly), but walks the lowered
    :class:`repro.core.plan.ExecutionSchedule` directly: every ``Compute``,
    ``SwapOut``, ``Prefetch`` and ``Free`` was decided at compile time, so
    the executor holds no scheduling policy — it replays ops and accounts
    HBM / host-pool residency high-water marks.  When no ``lowered``
    schedule is supplied (hand-wired callers) it is derived here from
    ``schedule``/``plan``.  With a :class:`SwapAwarePlan`, asserts the
    measured high-water marks never exceed the planned residency peak and
    the packed host pool.  ``executor`` picks the backend ("sim" default,
    "async" for real device streams) — see :func:`get_backend`.
    """
    return get_backend(executor).run(
        graph, params, x, label, schedule=schedule, ordered=ordered,
        plan=plan, lowered=lowered, mask=mask)
