"""Pure per-layer forward / backward math (NNTrainer §3, Figure 2(b)).

The layer-operation basis decomposes training into per-layer Forward,
Compute-Gradient and Compute-Derivative callables; this module holds that
math and nothing else — no stores, no swap scheduling, no backends.  The
saved context of each layer honours the lifespan analysis: weighted layers
save inputs (F+CG), in-place activations save only their OUTPUT (F+CD),
views save nothing.

Also here: the plain (no-swap) layer-basis walk
:func:`planned_loss_and_grads` and the whole-graph ``jax.grad`` reference
(:func:`reference_loss_and_grads`) every executor backend is validated
against — the paper's own CI gate ("if a weight or activation value has an
error over 1e-4 the commit is rejected").
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inplace
from repro.core.graph import WEIGHTED_KINDS, LayerGraph, LayerNode


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(graph: LayerGraph, rng: jax.Array,
                dtype=jnp.float32) -> Dict[str, Dict[str, jax.Array]]:
    """He-init weights for every weighted layer; E-shared layers reuse the
    first unrolled copy's parameters (Tensor-sharing, CreateMode.EXTEND)."""
    params: Dict[str, Dict[str, jax.Array]] = {}
    for l in graph.layers:
        if l.shares_weights_with:
            continue  # storage owned by the first copy
        shapes = l.weight_shapes()
        if not shapes:
            continue
        entry = {}
        for wname, shape in shapes.items():
            rng, sub = jax.random.split(rng)
            if wname in ("b", "beta"):
                entry[wname] = jnp.zeros(shape, dtype)
            elif wname in ("gamma",):
                entry[wname] = jnp.ones(shape, dtype)
            else:
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                if l.kind in ("conv2d", "conv1d"):
                    fan_in = int(np.prod(shape[1:]))
                scale = math.sqrt(2.0 / max(fan_in, 1))
                entry[wname] = jax.random.normal(sub, shape, dtype) * scale
        params[l.name] = entry
    return params


def _param_owner(graph: LayerGraph, l: LayerNode) -> str:
    return l.shares_weights_with or l.name


# ---------------------------------------------------------------------------
# Per-layer forward / backward (layer basis: F, CG, CD as separate callables)
# ---------------------------------------------------------------------------

def _conv2d_fwd(x, w, b, stride, padding):
    # x: (B, C, H, W), w: (O, I, K, K)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding.upper(), dimension_numbers=dn)
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def _pool2d_fwd(x, ksize, stride):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, 1, ksize, ksize), (1, 1, stride, stride), "VALID")


def _lstm_cell(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def layer_forward(l: LayerNode, xs: List[jax.Array],
                  p: Optional[Dict[str, jax.Array]],
                  state: Optional[Dict[str, jax.Array]] = None
                  ) -> Tuple[jax.Array, Any]:
    """Forward one layer; returns (output, saved-context for backward).

    The saved context honours the lifespan analysis: weighted layers save
    inputs (F+CG), in-place activations save only their OUTPUT (F+CD),
    views save nothing.
    """
    a = l.attrs
    x = xs[0]
    if l.kind == "linear":
        y = x @ p["w"]
        if "b" in p:
            y = y + p["b"]
        return y, (x,)
    if l.kind == "conv2d":
        y = _conv2d_fwd(x, p["w"], p.get("b"), a.get("stride", 1),
                        a.get("padding", "same"))
        return y, (x,)
    if l.kind == "activation":
        y = inplace.apply_activation(a["fn"], x)
        return y, (y,)     # output-only residual: the in-place property
    if l.kind == "batchnorm":
        mean = jnp.mean(x, axis=0, keepdims=True)
        var = jnp.var(x, axis=0, keepdims=True)
        inv_std = jax.lax.rsqrt(var + 1e-5)
        y = p["gamma"] * (x - mean) * inv_std + p["beta"]
        return y, (y, inv_std)   # output-based residual (paper §3)
    if l.kind == "flatten":
        return x.reshape(x.shape[0], -1), (x.shape,)
    if l.kind == "reshape":
        return x.reshape((x.shape[0],) + tuple(a["out_shape"])), (x.shape,)
    if l.kind == "pool2d":
        y = _pool2d_fwd(x, a["ksize"], a.get("stride", a["ksize"]))
        return y, (x,)   # backward needs the argmax source only (F+CD input)
    if l.kind == "add":
        y = xs[0]
        for other in xs[1:]:
            y = y + other
        return y, (len(xs),)
    if l.kind == "concat":
        axis = a.get("axis", -1)
        return jnp.concatenate(xs, axis=axis), ([x.shape[axis] for x in xs], axis)
    if l.kind == "multiout":
        return x, ()
    if l.kind == "embedding":
        idx = x.astype(jnp.int32)
        flat = idx[..., 0] if idx.ndim > 1 else idx
        return jnp.take(p["w"], flat, axis=0), (flat,)
    if l.kind == "lstm":
        h = jnp.zeros(x.shape[:-1] + (a["hidden"],), x.dtype) if state is None \
            else state["h"]
        c = jnp.zeros_like(h) if state is None else state["c"]
        h_new, c_new = _lstm_cell(x, h, c, p["wx"], p["wh"], p["b"])
        return h_new, (x, h, c)   # backward recomputes gates; outputs unused
    raise ValueError(f"forward not implemented for {l.kind}")


def layer_calc_gradient(l: LayerNode, ctx: Any, dy: jax.Array,
                        p: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """CG phase: weight gradients from saved context + incoming derivative."""
    if l.kind == "linear":
        (x,) = ctx
        g = {"w": x.reshape(-1, x.shape[-1]).T @ dy.reshape(-1, dy.shape[-1])}
        if "b" in p:
            g["b"] = dy.reshape(-1, dy.shape[-1]).sum(0)
        return g
    if l.kind == "conv2d":
        (x,) = ctx
        # dW via autodiff of the conv primitive w.r.t. w only (keeps the
        # layer-basis structure; XLA emits the standard conv-grad kernel).
        a = l.attrs
        _, vjp = jax.vjp(
            lambda w: _conv2d_fwd(x, w, None, a.get("stride", 1),
                                  a.get("padding", "same")), p["w"])
        g = {"w": vjp(dy)[0]}
        if "b" in p:
            g["b"] = dy.sum(axis=(0, 2, 3))
        return g
    if l.kind == "batchnorm":
        y, inv_std = ctx
        gamma, beta = p["gamma"], p["beta"]
        xhat = (y - beta) / jnp.where(gamma == 0, 1.0, gamma)
        return {"gamma": jnp.sum(dy * xhat, axis=0), "beta": jnp.sum(dy, axis=0)}
    if l.kind == "embedding":
        (idx,) = ctx
        g = jnp.zeros(p["w"].shape, dy.dtype)
        flat_idx = idx.reshape(-1) if idx.ndim > 1 else idx
        return {"w": g.at[flat_idx].add(dy.reshape(flat_idx.shape[0], -1))}
    if l.kind == "lstm":
        x, h0, c0 = ctx
        def f(wx, wh, b):
            h, _ = _lstm_cell(x, h0, c0, wx, wh, b)
            return h
        _, vjp = jax.vjp(f, p["wx"], p["wh"], p["b"])
        gwx, gwh, gb = vjp(dy)
        return {"wx": gwx, "wh": gwh, "b": gb}
    return {}


def layer_calc_derivative(l: LayerNode, ctx: Any, dy: jax.Array,
                          p: Optional[Dict[str, jax.Array]]) -> List[jax.Array]:
    """CD phase: derivative(s) w.r.t. the layer's input(s)."""
    a = l.attrs
    if l.kind == "linear":
        return [dy @ p["w"].T]
    if l.kind == "conv2d":
        (x,) = ctx
        _, vjp = jax.vjp(
            lambda xx: _conv2d_fwd(xx, p["w"], None, a.get("stride", 1),
                                   a.get("padding", "same")), x)
        return [vjp(dy)[0]]
    if l.kind == "activation":
        (y,) = ctx
        return [inplace.deriv_from_output(a["fn"], y, dy)]
    if l.kind == "batchnorm":
        y, inv_std = ctx
        gamma, beta = p["gamma"], p["beta"]
        n = y.shape[0]
        xhat = (y - beta) / jnp.where(gamma == 0, 1.0, gamma)
        dxhat = dy * gamma
        s1 = jnp.sum(dxhat, axis=0, keepdims=True)
        s2 = jnp.sum(dxhat * xhat, axis=0, keepdims=True)
        return [(inv_std / n) * (n * dxhat - s1 - xhat * s2)]
    if l.kind in ("flatten", "reshape"):
        (shape,) = ctx
        return [dy.reshape(shape)]
    if l.kind == "pool2d":
        (x,) = ctx
        k, s = a["ksize"], a.get("stride", a["ksize"])
        _, vjp = jax.vjp(lambda xx: _pool2d_fwd(xx, k, s), x)
        return [vjp(dy)[0]]
    if l.kind == "add":
        (n,) = ctx
        return [dy] * n
    if l.kind == "concat":
        sizes, axis = ctx
        splits = np.cumsum(sizes)[:-1].tolist()
        return list(jnp.split(dy, splits, axis=axis))
    if l.kind == "multiout":
        return [dy]
    if l.kind == "embedding":
        return []  # integer inputs: no derivative
    if l.kind == "lstm":
        x, h0, c0 = ctx
        def f(xx):
            h, _ = _lstm_cell(xx, h0, c0, p["wx"], p["wh"], p["b"])
            return h
        _, vjp = jax.vjp(f, x)
        return [vjp(dy)[0]]
    raise ValueError(f"calc_derivative not implemented for {l.kind}")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _sample_mask(mask: jax.Array, pred: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Broadcastable per-sample mask and its real-sample count.

    ``mask`` is (B,) with 1.0 for real samples and 0.0 for pad rows (the
    serve path pads ragged batches up to their bucket).  Masked rows get an
    exactly-zero loss derivative, so every downstream gradient matches the
    unpadded batch bit-for-bit up to float association — provided no layer
    mixes samples across the batch dimension (true for every zoo graph;
    batchnorm would violate it).
    """
    m = jnp.asarray(mask, pred.dtype)
    return m.reshape((-1,) + (1,) * (pred.ndim - 1)), jnp.maximum(m.sum(), 1.0)


def loss_forward(kind: str, pred: jax.Array, label: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    if mask is None:
        if kind == "loss_mse":
            return jnp.mean((pred - label) ** 2)
        if kind == "loss_ce":
            logp = jax.nn.log_softmax(pred, axis=-1)
            return -jnp.mean(jnp.sum(label * logp, axis=-1))
        raise ValueError(kind)
    m, n_real = _sample_mask(mask, pred)
    if kind == "loss_mse":
        per_sample = pred.size // pred.shape[0]
        return jnp.sum(m * (pred - label) ** 2) / (n_real * per_sample)
    if kind == "loss_ce":
        logp = jax.nn.log_softmax(pred, axis=-1)
        per_sample_ce = jnp.sum(label * logp, axis=-1, keepdims=True)
        return -jnp.sum(m * per_sample_ce) / n_real
    raise ValueError(kind)


def loss_derivative(kind: str, pred: jax.Array, label: jax.Array,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    if mask is None:
        n = pred.size if kind == "loss_mse" else pred.shape[0]
        if kind == "loss_mse":
            return 2.0 * (pred - label) / n
        if kind == "loss_ce":
            # combined softmax+CE derivative (the Loss realizer removed
            # softmax)
            return (jax.nn.softmax(pred, axis=-1) - label) / n
        raise ValueError(kind)
    m, n_real = _sample_mask(mask, pred)
    if kind == "loss_mse":
        per_sample = pred.size // pred.shape[0]
        return 2.0 * m * (pred - label) / (n_real * per_sample)
    if kind == "loss_ce":
        return m * (jax.nn.softmax(pred, axis=-1) - label) / n_real
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The plain planned training step (no swap schedule)
# ---------------------------------------------------------------------------

def planned_loss_and_grads(graph: LayerGraph,
                           params: Dict[str, Dict[str, jax.Array]],
                           x: jax.Array, label: jax.Array,
                           mask: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, Dict[str, Dict[str, jax.Array]]]:
    """One layer-basis training iteration: F sweep, then CG/CD sweep.

    Returns (loss, grads) with grads keyed by parameter-owner layer name;
    E-shared (unrolled) layers accumulate into their owner's entry.
    """
    acts: Dict[str, jax.Array] = {"__input__": x}
    ctxs: Dict[str, Any] = {}
    loss_node = None
    loss_val = None

    # ---- Forward (EO 0..N-1) ------------------------------------------------
    for l in graph.layers:
        if l.kind in ("loss_mse", "loss_ce"):
            loss_node = l
            loss_val = loss_forward(l.kind, acts[l.inputs[0]], label, mask)
            continue
        xs = [acts[i] for i in l.inputs]
        p = params.get(_param_owner(graph, l))
        y, ctx = layer_forward(l, xs, p)
        acts[l.name] = y
        ctxs[l.name] = ctx

    # ---- Backward (EO N..3N): CG then CD per layer, reverse order ----------
    derivs: Dict[str, jax.Array] = {}
    pred_name = loss_node.inputs[0]
    derivs[pred_name] = loss_derivative(loss_node.kind, acts[pred_name],
                                        label, mask)

    grads: Dict[str, Dict[str, jax.Array]] = {}
    for l in reversed(graph.layers):
        if l.kind in ("loss_mse", "loss_ce"):
            continue
        dy = derivs.pop(l.name, None)   # Backward lifespan: consumed here
        if dy is None:
            continue  # dead derivative (pruned subgraph)
        p = params.get(_param_owner(graph, l))
        # CG phase
        if l.trainable and l.weight_shapes():
            g = layer_calc_gradient(l, ctxs[l.name], dy, p)
            owner = _param_owner(graph, l)
            if owner in grads:
                grads[owner] = {k: grads[owner][k] + g[k] for k in g}
            else:
                grads[owner] = g
        # CD phase — skipped when no upstream layer needs the derivative
        # (first layer / frozen backbone: dead-derivative pruning).
        upstream_needed = [
            i for i in l.inputs if i != "__input__" and _needs_deriv(graph, i)
        ]
        if upstream_needed:
            dxs = layer_calc_derivative(l, ctxs[l.name], dy, p)
            for inp, dx in zip(l.inputs, dxs):
                if inp == "__input__" or inp not in upstream_needed:
                    continue
                if inp in derivs:
                    derivs[inp] = derivs[inp] + dx   # fan-out accumulation
                else:
                    derivs[inp] = dx
    return loss_val, grads


def _needs_deriv(graph: LayerGraph, name: str) -> bool:
    from repro.core.graph import _has_trainable_upstream
    node = graph.layer(name)
    if node.kind in WEIGHTED_KINDS and node.trainable and node.weight_shapes():
        return True
    return _has_trainable_upstream(graph, node)


# ---------------------------------------------------------------------------
# Whole-graph reference (conventional tape autodiff) for validation
# ---------------------------------------------------------------------------

def reference_forward(graph: LayerGraph,
                      params: Dict[str, Dict[str, jax.Array]],
                      x: jax.Array) -> jax.Array:
    acts: Dict[str, jax.Array] = {"__input__": x}
    out = None
    for l in graph.layers:
        if l.kind in ("loss_mse", "loss_ce"):
            out = acts[l.inputs[0]]
            continue
        xs = [acts[i] for i in l.inputs]
        p = params.get(_param_owner(graph, l))
        y, _ = layer_forward(l, xs, p)
        acts[l.name] = y
    return out if out is not None else acts[graph.layers[-1].name]


def reference_loss_and_grads(graph: LayerGraph,
                             params: Dict[str, Dict[str, jax.Array]],
                             x: jax.Array, label: jax.Array,
                             mask: Optional[jax.Array] = None):
    loss_kind = next(l.kind for l in graph.layers if l.kind.startswith("loss"))
    trainable_owners = {
        _param_owner(graph, l) for l in graph.layers
        if l.trainable and l.weight_shapes()
    }
    train_p = {k: v for k, v in params.items() if k in trainable_owners}
    frozen_p = {k: v for k, v in params.items() if k not in trainable_owners}

    def loss_fn(tp):
        pred = reference_forward(graph, {**frozen_p, **tp}, x)
        return loss_forward(loss_kind, pred, label, mask)

    loss, grads = jax.value_and_grad(loss_fn)(train_p)
    return loss, grads


def sgd_update(params, grads, lr=1e-2):
    out = {}
    for lname, entry in params.items():
        if lname in grads:
            out[lname] = {k: v - lr * grads[lname][k] for k, v in entry.items()}
        else:
            out[lname] = entry
    return out
