"""Executor subsystem: layer math, activation store, pluggable backends.

The layer-operation-basis training executor (NNTrainer §3/§4, Figure 2(b))
split along its three concerns:

* :mod:`repro.core.exec.layers`   — pure per-layer F/CG/CD math, loss
  calculus, the plain planned walk and the ``jax.grad`` reference;
* :mod:`repro.core.exec.store`    — residency trackers + activation store
  with the :class:`TransferEngine` seam (sync host round trips vs real
  device-stream copies);
* :mod:`repro.core.exec.backends` — the :class:`ExecutorBackend` protocol
  and its two implementations, :class:`SimulatedBackend` (default) and
  :class:`AsyncDeviceBackend`, both replaying the compiled
  :class:`repro.core.plan.ExecutionSchedule` verbatim.

Select a backend declaratively via ``MemoryPlanConfig(executor=...)``;
``repro.core.planned_exec`` remains as a compatibility shim over this
package.
"""

from repro.core.exec.backends import (BACKENDS, AsyncDeviceBackend,
                                      ExecutorBackend, ScheduleCursor,
                                      SimulatedBackend, get_backend,
                                      swap_planned_loss_and_grads)
from repro.core.exec.layers import (init_params, layer_calc_derivative,
                                    layer_calc_gradient, layer_forward,
                                    loss_derivative, loss_forward,
                                    planned_loss_and_grads,
                                    reference_forward,
                                    reference_loss_and_grads, sgd_update)
from repro.core.exec.store import (ActivationStore, DeviceStreamEngine,
                                   HbmTracker, SessionScopedEngine,
                                   SwapExecStats, SyncHostEngine,
                                   TransferEngine)

__all__ = [
    # backends
    "ExecutorBackend", "SimulatedBackend", "AsyncDeviceBackend",
    "BACKENDS", "get_backend", "swap_planned_loss_and_grads",
    "ScheduleCursor",
    # store + engines
    "ActivationStore", "HbmTracker", "SwapExecStats", "TransferEngine",
    "SyncHostEngine", "DeviceStreamEngine", "SessionScopedEngine",
    # layer math
    "init_params", "layer_forward", "layer_calc_gradient",
    "layer_calc_derivative", "loss_forward", "loss_derivative",
    "planned_loss_and_grads", "reference_forward",
    "reference_loss_and_grads", "sgd_update",
]
