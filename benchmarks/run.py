"""Benchmark driver: one function per paper table/figure + kernel tiles +
roofline summary from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only table4,fig9]

Prints ``name,us_per_call,derived`` CSV (the middle column is KiB/MiB for
memory benchmarks, us for latency ones — unit noted in ``derived``).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.paper_tables import ALL as PAPER          # noqa: E402
from benchmarks.kernel_bench import ALL as KERNELS        # noqa: E402
from benchmarks import swap_bench                         # noqa: E402
from benchmarks.swap_bench import ALL as SWAP             # noqa: E402


def roofline_rows():
    from repro.launch.roofline import load_all
    rows = []
    for r in load_all():
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            r["t_compute_s"] * 1e6,
            f"t_mem={r['t_memory_s']*1e6:.0f}us "
            f"t_coll={r['t_collective_s']*1e6:.0f}us "
            f"dominant={r['dominant']} useful={r['useful_compute_ratio']:.2f} "
            f"roofline={r['roofline_fraction']:.2%}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="where to write BENCH_swap.json when swap benches "
                         "run (default: results/BENCH_swap.json)")
    args = ap.parse_args()

    benches = dict(PAPER)
    benches.update(KERNELS)
    benches.update(SWAP)
    benches["roofline"] = roofline_rows
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.2f},{row[2]}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR {e}")
    if swap_bench.JSON_RECORDS:
        path = swap_bench.dump_json(args.bench_json)
        print(f"# wrote {len(swap_bench.JSON_RECORDS)} records to {path}",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
