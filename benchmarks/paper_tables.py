"""Benchmarks reproducing the paper's tables/figures.

table4   — component ideal-memory sizes vs the published numbers
fig9     — peak memory: planned vs naive (tensor-basis) vs ideal, per case
fig10    — training latency of the component cases (layer-basis executor
           vs whole-graph jax.grad — the 'conventional framework' stand-in)
fig11    — memory & throughput vs batch size (Model A-Linear)
fig12    — application models: full training vs transfer-learning memory
fig14    — Tacotron2-style unrolled decoder: memory & per-sample latency

Each function returns a list of CSV rows: (name, us_per_call, derived).
The memory numbers are exact planner outputs (bytes known before
execution — the paper's headline property); latency numbers are measured
on this host's CPU.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ideal import PAPER_TABLE4_KIB, ideal_from_ordered
from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planned_exec import (init_params, planned_loss_and_grads,
                                     reference_loss_and_grads)
from repro.core.zoo import ZOO

Row = Tuple[str, float, str]


def _packed(graph, planner: str, batch: int):
    """One no-swap compile through the facade; returns the arena plan.

    These figures compare *packing* strategies, so swapping is disabled —
    the swap tradeoff has its own benchmark (swap_bench).
    """
    return compile_plan(graph, MemoryPlanConfig(planner=planner, swap=False),
                        batch=batch)


def _shrunk(name: str, width: int = 256):
    g = ZOO[name]()
    for l in g.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = width
    if g.input_shape == (150528,):
        object.__setattr__(g, "input_shape", (width,))
    from repro.core.graph import infer_shapes
    infer_shapes(g)
    return g


def table4() -> List[Row]:
    rows: List[Row] = []
    for name, paper_kib in PAPER_TABLE4_KIB.items():
        ideal = ideal_from_ordered(_packed(ZOO[name](), "sorting", 64).ordered)
        ratio = ideal.total_kib / paper_kib
        rows.append((f"table4/{name}", ideal.total_kib,
                     f"paper={paper_kib}KiB ratio={ratio:.4f}"))
    return rows


def fig9_peak_memory() -> List[Row]:
    rows: List[Row] = []
    for name in PAPER_TABLE4_KIB:
        sorting_cp = _packed(ZOO[name](), "sorting", 64)
        planned = sorting_cp.plan
        bestfit = _packed(ZOO[name](), "bestfit", 64).plan
        naive = _packed(ZOO[name](), "worstcase", 64).plan
        ideal = ideal_from_ordered(sorting_cp.ordered)
        rows.append((
            f"fig9/{name}", planned.total_bytes / 1024,
            f"ideal={ideal.total_kib:.0f}KiB "
            f"bestfit={bestfit.total_bytes/1024:.0f}KiB "
            f"naive={naive.total_bytes/1024:.0f}KiB "
            f"saving={1 - planned.total_bytes/naive.total_bytes:.1%}"))
    return rows


def _time_step(fn, *args, iters: int = 3) -> float:
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def fig10_latency() -> List[Row]:
    rows: List[Row] = []
    cases = ["model_a_linear", "model_b_linear", "model_c_linear", "model_d",
             "lenet5"]
    for name in cases:
        g = _shrunk(name)
        params = init_params(g, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(32,) + tuple(g.input_shape))
                        .astype(np.float32))
        y = jnp.asarray(rng.normal(size=(32,) + tuple(g.label_shape))
                        .astype(np.float32))
        planned = jax.jit(lambda p, xx, yy, g=g:
                          planned_loss_and_grads(g, p, xx, yy)[0])
        conv = jax.jit(lambda p, xx, yy, g=g:
                       reference_loss_and_grads(g, p, xx, yy)[0])
        t_p = _time_step(planned, params, x, y)
        t_c = _time_step(conv, params, x, y)
        rows.append((f"fig10/{name}", t_p,
                     f"conventional={t_c:.0f}us ratio={t_p/t_c:.2f}"))
    return rows


def fig11_batch_sweep() -> List[Row]:
    rows: List[Row] = []
    for batch in (8, 16, 32, 64, 128):
        plan = _packed(ZOO["model_a_linear"](), "bestfit", batch).plan
        naive = _packed(ZOO["model_a_linear"](), "worstcase", batch).plan
        rows.append((
            f"fig11/batch{batch}", plan.total_bytes / 2**20,
            f"naive={naive.total_bytes/2**20:.0f}MiB "
            f"fits512MiB={'yes' if plan.total_bytes < 512*2**20 else 'no'}"
            f"/naive={'yes' if naive.total_bytes < 512*2**20 else 'no'}"))
    return rows


def fig12_applications() -> List[Row]:
    rows: List[Row] = []
    for name in ("lenet5", "vgg16", "resnet18", "resnet18_transfer",
                 "product_rating"):
        plan = _packed(ZOO[name](), "bestfit", 32).plan
        naive = _packed(ZOO[name](), "worstcase", 32).plan
        rows.append((f"fig12/{name}", plan.total_bytes / 2**20,
                     f"naive={naive.total_bytes/2**20:.1f}MiB "
                     f"saving={1 - plan.total_bytes/naive.total_bytes:.1%}"))
    return rows


def fig14_tacotron() -> List[Row]:
    rows: List[Row] = []
    from repro.core.zoo import tacotron2_decoder
    for steps in (4, 8, 16):
        g = tacotron2_decoder(time_steps=steps, mel_dim=16, prenet_dim=64,
                              lstm_dim=64)
        plan = _packed(g, "bestfit", 16).plan
        naive = _packed(
            tacotron2_decoder(time_steps=steps, mel_dim=16, prenet_dim=64,
                              lstm_dim=64), "worstcase", 16).plan
        params = init_params(g, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        fn = jax.jit(lambda p, xx, yy, g=g:
                     planned_loss_and_grads(g, p, xx, yy)[0])
        t = _time_step(fn, params, x, y)
        rows.append((f"fig14/unroll{steps}", t,
                     f"planned={plan.total_bytes/2**20:.1f}MiB "
                     f"naive={naive.total_bytes/2**20:.1f}MiB "
                     f"saving={1 - plan.total_bytes/naive.total_bytes:.1%}"))
    return rows


ALL = {
    "table4": table4,
    "fig9": fig9_peak_memory,
    "fig10": fig10_latency,
    "fig11": fig11_batch_sweep,
    "fig12": fig12_applications,
    "fig14": fig14_tacotron,
}
