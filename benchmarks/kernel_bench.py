"""Kernel micro-benchmarks: interpret-mode correctness profile + analytic
roofline estimates for the TPU target (wall-clock on CPU interpret mode is
meaningless for TPU perf, so we report the modelled VMEM working set and
arithmetic intensity per kernel tile instead)."""

from __future__ import annotations

from typing import List, Tuple

Row = Tuple[str, float, str]


def flash_attention_tiles() -> List[Row]:
    rows: List[Row] = []
    d = 128
    for bq, bkv in ((256, 512), (512, 1024), (1024, 1024)):
        vmem = (2 * bq * d + 2 * bkv * d) * 2 + bq * d * 4 + 2 * bq * 4
        flops = 2 * bq * bkv * d * 2            # qk^T + pv
        hbm = (bq * d + 2 * bkv * d) * 2        # per tile visit
        rows.append((
            f"kern/flash_q{bq}_kv{bkv}", vmem / 1024,
            f"ai={flops/hbm:.0f}flops/B vmem={vmem/2**20:.2f}MiB "
            f"mxu_aligned={'yes' if bq % 128 == 0 and d % 128 == 0 else 'no'}"))
    return rows


def ssd_tiles() -> List[Row]:
    rows: List[Row] = []
    for q, n, p in ((128, 64, 64), (256, 64, 64), (256, 128, 64)):
        vmem = (q * p + 2 * q * n + 2 * q) * 4 + q * q * 4 + n * p * 4
        flops = 2 * q * q * n + 2 * q * q * p + 2 * q * n * p
        hbm = (q * p + 2 * q * n + n * p) * 4
        rows.append((f"kern/ssd_q{q}_n{n}_p{p}", vmem / 1024,
                     f"ai={flops/hbm:.0f}flops/B vmem={vmem/2**20:.2f}MiB"))
    return rows


def mlstm_tiles() -> List[Row]:
    rows: List[Row] = []
    for q, p in ((128, 64), (256, 64), (256, 128)):
        vmem = 3 * q * p * 4 + 2 * q * 4 + 2 * q * q * 4 + p * p * 4
        flops = 2 * q * q * p * 2 + 2 * q * p * p
        hbm = (3 * q * p + p * p) * 4
        rows.append((f"kern/mlstm_q{q}_p{p}", vmem / 1024,
                     f"ai={flops/hbm:.0f}flops/B vmem={vmem/2**20:.2f}MiB"))
    return rows


def swiglu_tiles() -> List[Row]:
    rows: List[Row] = []
    for m, f, k in ((256, 512, 512), (512, 512, 1024)):
        vmem = (m * k + 2 * k * f) * 2 + 2 * m * f * 4
        flops = 2 * m * k * f * 2
        # fused: x read once, h written once (no g/u round trip)
        hbm_fused = (m * k + 2 * k * f + m * f) * 2
        hbm_unfused = (2 * m * k + 2 * k * f + 5 * m * f) * 2
        rows.append((
            f"kern/swiglu_m{m}_f{f}_k{k}", vmem / 1024,
            f"ai_fused={flops/hbm_fused:.0f} ai_unfused={flops/hbm_unfused:.0f} "
            f"traffic_saved={1 - hbm_fused/hbm_unfused:.0%}"))
    return rows


ALL = {
    "kern_flash": flash_attention_tiles,
    "kern_ssd": ssd_tiles,
    "kern_mlstm": mlstm_tiles,
    "kern_swiglu": swiglu_tiles,
}
