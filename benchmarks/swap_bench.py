"""Proactive-swap benchmark: the paper's memory-vs-DMA-traffic tradeoff.

Sweeps the swap planner's two knobs over the zoo models:

* ``min_idle_phases`` — how long a tensor must sit idle to be swapped; low
  thresholds reclaim more HBM but pay more DMA traffic (§6's tradeoff);
* ``hbm_budget_bytes`` — stop swapping once this much HBM is reclaimed.

Each row reports the swap-aware device-arena peak (MiB, middle column)
against the no-swap baseline of the same planner, plus host-pool bytes and
total DMA traffic.  A final set of rows runs the swap executor end-to-end
on small models and reports *measured* high-water marks and DMA bytes,
proving schedule and execution agree (late_swap_ins must be 0).

    PYTHONPATH=src python -m benchmarks.run --only swap_tradeoff,swap_exec
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MIB = 1024.0 * 1024.0

PLAN_MODELS = (("vgg16", 32), ("resnet18", 32), ("lenet5", 64))
IDLE_SWEEP = (3, 6, 12)
BUDGET_FRACTIONS = (None, 0.5, 0.25)   # of the total swappable bytes


def bench_swap_tradeoff():
    from repro.core.execution_order import compute_execution_order
    from repro.core.offload import plan_offload
    from repro.core.planner import plan_memory, plan_memory_swapped
    from repro.core.zoo import ZOO

    rows = []
    for name, batch in PLAN_MODELS:
        ordered = compute_execution_order(ZOO[name](), batch)
        baseline = plan_memory(ordered, "sorting")
        for idle in IDLE_SWEEP:
            full = plan_offload(ordered, min_idle_phases=idle,
                                min_bytes=1 << 16)
            for frac in BUDGET_FRACTIONS:
                budget = (None if frac is None
                          else int(full.hbm_bytes_saved * frac))
                sched = plan_offload(ordered, min_idle_phases=idle,
                                     min_bytes=1 << 16,
                                     hbm_budget_bytes=budget)
                plan = plan_memory_swapped(ordered, sched)
                tag = "all" if frac is None else f"{int(frac * 100)}pct"
                rows.append((
                    f"swap/{name}/idle{idle}/{tag}",
                    plan.arena_bytes / MIB,
                    f"MiB_peak base={baseline.arena_bytes / MIB:.2f} "
                    f"saved={plan.hbm_bytes_saved / MIB:.2f} "
                    f"host={plan.host_pool_bytes / MIB:.2f} "
                    f"dma={sched.dma_bytes / MIB:.2f} "
                    f"nswap={len(plan.swapped_names())}"))
    return rows


EXEC_MODELS = (("lenet5", 16), ("model_b_conv2d", 8))


def bench_swap_exec():
    import jax
    import numpy as np

    from repro.core.execution_order import compute_execution_order
    from repro.core.offload import plan_offload
    from repro.core.planned_exec import (init_params,
                                         swap_planned_loss_and_grads)
    from repro.core.planner import plan_memory_swapped
    from repro.core.zoo import ZOO

    rows = []
    for name, batch in EXEC_MODELS:
        g = ZOO[name]()
        ordered = compute_execution_order(g, batch)
        sched = plan_offload(ordered, min_idle_phases=3, min_bytes=1 << 12)
        plan = plan_memory_swapped(ordered, sched)
        params = init_params(g, jax.random.PRNGKey(0))
        kx, ky = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
        y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
        if g.layers[-1].kind == "loss_ce":
            y = jax.nn.one_hot(np.argmax(np.asarray(y), -1), y.shape[-1])
        _, _, stats = swap_planned_loss_and_grads(
            g, params, x, y, schedule=sched, ordered=ordered, plan=plan)
        rows.append((
            f"swap_exec/{name}",
            stats.hbm_high_water / MIB,
            f"MiB_measured planned={stats.planned_peak / MIB:.2f} "
            f"dma={stats.dma_bytes / MIB:.2f} "
            f"swaps={stats.swap_outs}/{stats.prefetches} "
            f"late={stats.late_swap_ins}"))
    return rows


ALL = {
    "swap_tradeoff": bench_swap_tradeoff,
    "swap_exec": bench_swap_exec,
}
