"""Proactive-swap benchmark: the paper's memory-vs-DMA-traffic tradeoff.

All rows are produced through ``repro.core.compile_plan`` — the single
entry point from graph to executor — sweeping the declarative
:class:`MemoryPlanConfig` knobs over the zoo models:

* ``min_idle_phases`` — how long a tensor must sit idle to be swapped; low
  thresholds reclaim more HBM but pay more DMA traffic (§6's tradeoff);
* ``hbm_budget_bytes`` — stop swapping once this much HBM is reclaimed.

Each row reports the swap-aware device-arena peak (MiB, middle column)
against the no-swap baseline of the same planner, plus host-pool bytes,
total DMA traffic, and what the schedule/planner co-optimisation fixed
point dropped.  ``swap_model`` rows cover the model-config (TPU) path: the
joint keep/recompute/offload planner over transformer archs and budget
sweeps, with per-plan DMA bytes, decisions, and the estimated step-time
cost against the pure-remat and offload-everything alternatives.
``host_planner`` rows sweep the pinned-host pool's ArenaAllocator
(sorting | bestfit | segregated | buddy) and report packed bytes,
fragmentation and in-place-prefetch elisions against the legacy
pack-every-copy baseline.  A final set of rows runs the compiled plan's
executor end-to-end on small models — once per registered backend
(``sim`` synchronous replay, ``async`` real device-stream transfers) —
and reports *measured* high-water marks (HBM and host pool), DMA bytes,
per-backend step wall-clock (including a cut of the llama3.2-3b MLP
trunk, where real 3072x8192 matmuls dominate dispatch overhead),
and for the async backend the achieved overlap fraction and in-flight
byte high water vs the planned ``peak_inflight_prefetch``, proving
schedule and execution agree (late_swap_ins must be 0, replayed ops must
equal the compiled op list on every backend).  ``optim_offload`` rows
measure the tentpole acceptance: on vgg16 under AdamW the planned
optimizer working region vs the all-resident moments (``reduction_x``,
gated >= 3.0 in CI) and the offloaded update's parameter drift vs the
resident fp32 reference (EF-compressed within ``OPTIM_TOL_ABS``,
uncompressed to float noise).  ``verify`` rows time the
static schedule verifier (``repro.core.verify``) over the zoo x device
planner sweep and record its coverage (ops scanned, placements scanned,
checks run) so the gate's own cost stays on the perf trajectory.

Besides the CSV rows, every run collects machine-readable records; the
driver (``benchmarks/run.py``) writes them to ``results/BENCH_swap.json``
so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run --only swap_tradeoff,swap_exec
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Any, Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MIB = 1024.0 * 1024.0

PLAN_MODELS = (("vgg16", 32), ("resnet18", 32), ("lenet5", 64))
IDLE_SWEEP = (3, 6, 12)
BUDGET_FRACTIONS = (None, 0.5, 0.25)   # of the total swappable bytes

# Machine-readable rows accumulated by the bench functions during a run;
# ``dump_json`` writes them out (see benchmarks/run.py).
JSON_RECORDS: List[Dict[str, Any]] = []

DEFAULT_JSON_PATH = Path(__file__).resolve().parents[1] / "results" \
    / "BENCH_swap.json"


def dump_json(path=None) -> Path:
    """Write the collected records as BENCH_swap.json; returns the path."""
    path = Path(path) if path else DEFAULT_JSON_PATH
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"schema": "bench_swap/v1", "records": JSON_RECORDS}, indent=2))
    return path


def bench_swap_tradeoff():
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.zoo import ZOO

    rows = []
    for name, batch in PLAN_MODELS:
        graph = ZOO[name]()
        for idle in IDLE_SWEEP:
            # budget fractions are of the *full single-pass* swappable bytes
            full = compile_plan(
                graph, MemoryPlanConfig(min_idle_phases=idle,
                                        min_bytes=1 << 16,
                                        cooptimize=False), batch=batch)
            for frac in BUDGET_FRACTIONS:
                budget = (None if frac is None
                          else int(full.schedule.hbm_bytes_saved * frac))
                cp = compile_plan(
                    graph, MemoryPlanConfig(min_idle_phases=idle,
                                            min_bytes=1 << 16,
                                            hbm_budget_bytes=budget),
                    batch=batch)
                r = cp.report()
                tag = "all" if frac is None else f"{int(frac * 100)}pct"
                rows.append((
                    f"swap/{name}/idle{idle}/{tag}",
                    r["peak_bytes"] / MIB,
                    f"MiB_peak base={r['baseline_peak_bytes'] / MIB:.2f} "
                    f"saved={r['hbm_bytes_saved'] / MIB:.2f} "
                    f"host={r['host_pool_bytes'] / MIB:.2f} "
                    f"dma={r['dma_bytes'] / MIB:.2f} "
                    f"nswap={r['n_swaps']} "
                    f"coopt_dropped={len(r['coopt_dropped'])}"))
                JSON_RECORDS.append({
                    "bench": "swap_tradeoff", "model": name, "batch": batch,
                    "min_idle_phases": idle, "budget_fraction": frac, **r})
    return rows


# Model-config path: the joint keep/recompute/offload planner over
# transformer archs, swept over per-layer HBM budget fractions.  Each row
# reports the plan's DMA traffic (middle column) plus the estimated
# per-layer step-time cost of the joint plan against the two single-knob
# alternatives (pure remat, offload-everything) priced under the same
# hardware model — the model-path perf trajectory for BENCH_swap.json.
MODEL_PLAN_CASES = (("llama3.2-3b", 2048), ("granite-moe-1b-a400m", 2048))
MODEL_BUDGET_FRACTIONS = (0.5, 0.25, 0.0)
MODEL_HW = {"dma_gbps": 80.0, "device_tflops": 200.0}


def bench_swap_model():
    import warnings

    from repro.configs import ARCHS
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.remat_policy import (plan_step_time_s,
                                         transformer_intermediates)

    rows = []
    for arch, bt in MODEL_PLAN_CASES:
        cfg = ARCHS[arch]
        inter = transformer_intermediates(
            batch_tokens=bt, d_model=cfg.d_model,
            d_ff=cfg.moe_d_ff if cfg.is_moe else cfg.d_ff,
            n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, moe_experts_per_token=cfg.top_k)
        total = sum(i.bytes_per_layer for i in inter)
        for frac in MODEL_BUDGET_FRACTIONS:
            budget = int(total * frac)
            joint = compile_plan(cfg, MemoryPlanConfig(
                remat=True, remat_budget_bytes=budget, offload=True,
                **MODEL_HW), batch_tokens=bt)
            remat = compile_plan(cfg, MemoryPlanConfig(
                remat=True, remat_budget_bytes=budget, offload=False),
                batch_tokens=bt)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                offall = compile_plan(cfg, MemoryPlanConfig(
                    remat=True, remat_budget_bytes=budget,
                    offload_dropped=True), batch_tokens=bt)
            price = lambda cp: plan_step_time_s(  # noqa: E731
                cp.remat_plan, inter, **MODEL_HW)
            r = joint.report()
            rows.append((
                f"swap_model/{arch}/budget{int(frac * 100)}pct",
                joint.dma_bytes / MIB,
                f"MiB_dma est_joint={price(joint) * 1e3:.3f}ms/layer "
                f"est_remat={price(remat) * 1e3:.3f} "
                f"est_offall={price(offall) * 1e3:.3f} "
                f"keep={len(r['remat_saved'])} "
                f"rec={len(r['remat_dropped'])} "
                f"off={len(r['remat_offloaded'])}"))
            JSON_RECORDS.append({
                "bench": "swap_model", "model": arch, "batch_tokens": bt,
                "budget_fraction": frac, "budget_bytes_per_layer": budget,
                "est_step_time_s_per_layer_joint": price(joint),
                "est_step_time_s_per_layer_pure_remat": price(remat),
                "est_step_time_s_per_layer_offload_all": price(offall),
                **r})
    return rows


# Host-pool allocator sweep: pack the pinned-host pool with each registered
# ArenaAllocator and report bytes + fragmentation (1 - utilization) per
# planner, plus the in-place-prefetch elisions that removed copies from the
# pool entirely.  ``legacy_host_bytes`` is what the pre-allocator-layer
# code charged: a SortingPlanner pack over EVERY offloaded copy (elision
# ignored, reuse across disjoint windows included) — the baseline the
# fragmentation-aware pool must strictly beat.
HOST_PLANNERS = ("sorting", "bestfit", "segregated", "buddy")
# lenet5 at batch 16 keeps several ragged-size copies in the pool, so the
# class-rounding planners' internal padding is visible in the sweep
HOST_SWEEP_MODELS = (("vgg16", 32), ("resnet18", 32), ("lenet5", 16))


def bench_host_planner():
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.planner import legacy_host_pool_bytes
    from repro.core.zoo import ZOO

    rows = []
    for name, batch in HOST_SWEEP_MODELS:
        graph = ZOO[name]()
        for hp in HOST_PLANNERS:
            cp = compile_plan(
                graph, MemoryPlanConfig(planner="bestfit", host_planner=hp,
                                        min_idle_phases=3,
                                        min_bytes=1 << 12), batch=batch)
            r = cp.report()
            legacy = legacy_host_pool_bytes(cp.ordered, cp.schedule)
            rows.append((
                f"host_pool/{name}/{hp}",
                r["host_pool_bytes"] / MIB,
                f"MiB_host legacy={legacy / MIB:.2f} "
                f"frag={1.0 - r['host_utilization']:.3f} "
                f"inplace={r['inplace_prefetch_count']} "
                f"nswap={r['n_swaps']} dma={r['dma_bytes'] / MIB:.2f}"))
            JSON_RECORDS.append({
                "bench": "host_planner", "model": name, "batch": batch,
                "legacy_host_bytes": legacy, **r})
    return rows


EXEC_MODELS = (("lenet5", 16), ("model_b_conv2d", 8))
EXEC_BACKENDS = ("sim", "async", "jit_blocks")
# the llama3.2-3b MLP trunk, cut to a CI-executable depth: real 3072->8192
# matmuls, so the per-backend wall-clock column measures dispatch overhead
# against work large enough to dominate Python noise
TRUNK_LAYERS = 4
TRUNK_BATCH = 4


def bench_swap_exec():
    import collections

    import jax
    import numpy as np

    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.verify import schedules_equivalent
    from repro.core.zoo import ZOO, transformer_mlp_stack

    cases = [(name, ZOO[name](), batch) for name, batch in EXEC_MODELS]
    trunk = transformer_mlp_stack(n_layers=TRUNK_LAYERS)
    cases.append((trunk.name, trunk, TRUNK_BATCH))

    rows = []
    for name, g, batch in cases:
        # one compile per model: the plan is executor-independent, only the
        # replay backend differs (routed per run via the executor= override)
        cp = compile_plan(
            g, MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12),
            batch=batch)
        params = cp.init_params(jax.random.PRNGKey(0))
        kx, ky = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
        y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
        if g.layers[-1].kind == "loss_ce":
            y = jax.nn.one_hot(np.argmax(np.asarray(y), -1), y.shape[-1])
        for executor in EXEC_BACKENDS:
            _, _, stats = cp.loss_and_grads(params, x, y, executor=executor)
            # replay semantics differ per backend: sim/async replay the op
            # list verbatim; jit_blocks replays a proven-equivalent fused
            # permutation (same multiset, every dependence edge preserved)
            if executor == "jit_blocks":
                replay_match = (
                    collections.Counter(stats.replayed_ops)
                    == collections.Counter(cp.lowered.ops)
                    and schedules_equivalent(
                        cp.lowered, stats.replayed_ops,
                        ordered=cp.ordered, plan=cp.plan).ok)
            else:
                replay_match = stats.replayed_ops == cp.lowered.ops
            overlap = stats.achieved_overlap
            rows.append((
                f"swap_exec/{name}/{executor}",
                stats.hbm_high_water / MIB,
                f"MiB_measured planned={stats.planned_peak / MIB:.2f} "
                f"host={stats.host_high_water / MIB:.2f} "
                f"dma={stats.dma_bytes / MIB:.2f} "
                f"swaps={stats.swap_outs}/{stats.prefetches} "
                f"late={stats.late_swap_ins} replay_match={replay_match} "
                f"dispatch={stats.dispatch_calls}/{len(cp.lowered.ops)} "
                f"overlap={'n/a' if overlap is None else f'{overlap:.2f}'} "
                f"inflight_hw={stats.inflight_high_water / MIB:.2f} "
                f"wall={stats.wall_time_s * 1e3:.1f}ms"))
            JSON_RECORDS.append({
                "bench": "swap_exec", "model": name, "batch": batch,
                "executor": executor,
                "wall_time_s": stats.wall_time_s,
                "hbm_high_water": stats.hbm_high_water,
                "planned_peak": stats.planned_peak,
                "host_high_water": stats.host_high_water,
                "planned_host_pool": stats.planned_host_pool,
                "measured_dma_bytes": stats.dma_bytes,
                "swap_outs": stats.swap_outs, "prefetches": stats.prefetches,
                "late_swap_ins": stats.late_swap_ins,
                "replay_matches_compiled": replay_match,
                "replay_equivalent_modulo_fusion":
                    executor == "jit_blocks",
                # Python-level dispatch calls vs schedule length: the
                # jit_blocks win (one call per fused block) against the
                # per-op backends (one call per op)
                "dispatch_calls": stats.dispatch_calls,
                "schedule_op_count": len(cp.lowered.ops),
                "min_prefetch_slack_phases":
                    (cp.deps_report or {}).get("min_prefetch_slack_phases"),
                # the overlap row proper: what the backend achieved vs the
                # plan's double-buffer budget (exec_report also lands in
                # cp.report()["exec"] below)
                "achieved_overlap": stats.achieved_overlap,
                "inflight_high_water": stats.inflight_high_water,
                "planned_peak_inflight_prefetch":
                    cp.schedule.peak_inflight_prefetch,
                "stalled_fences": stats.stalled_fences,
                **cp.report()})
    return rows


# Tentpole acceptance bench: planner-managed optimizer-state offload on a
# zoo model under AdamW.  vgg16's 14.7M params carry ~114 MiB of fp32
# moments when resident; the plan packs their per-layer CG windows into a
# working region and the row measures the reduction plus the update
# accuracy of the int8-compressed (EF) host round-trip vs the resident
# fp32 AdamW reference.
OPTIM_MODEL = "vgg16"
OPTIM_BATCH = 4
OPTIM_STEPS = 3
# The established error-feedback tolerance: sqrt-space int8 quantization
# of v keeps the worst-case parameter drift bounded and *flat* across
# steps (~12 x lr, a one-time early offset EF then holds), vs the ~1e5 x
# lr explosion of linear int8.  The gate sits far above float noise and
# far below any explosion.
OPTIM_TOL_ABS = 2e-2
OPTIM_NOCOMPRESS_TOL = 1e-5


def bench_optim_offload():
    import collections
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.exec.store import SwapExecStats
    from repro.core.optim_offload import OptimRuntime, offloaded_update
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.verify import schedules_equivalent
    from repro.core.zoo import ZOO
    from repro.optim.optimizers import adamw

    g = ZOO[OPTIM_MODEL]()
    cp = compile_plan(
        g, MemoryPlanConfig(optim_offload=True, min_idle_phases=3,
                            min_bytes=1 << 12), batch=OPTIM_BATCH)
    summary = cp.optim_plan.summary()
    n_classes = g.label_shape[-1]

    def batch_at(seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (OPTIM_BATCH,) + tuple(g.input_shape))
        y = jax.nn.one_hot(
            jax.random.randint(ky, (OPTIM_BATCH,), 0, n_classes), n_classes)
        return x, y

    # every backend must replay the opt-extended schedule faithfully
    x0, y0 = batch_at(0)
    warm_params = cp.init_params(jax.random.PRNGKey(0))
    replay = {}
    for executor in EXEC_BACKENDS:
        _, _, stats = cp.loss_and_grads(x=x0, label=y0, params=warm_params,
                                        executor=executor)
        if executor == "jit_blocks":
            replay[executor] = (
                collections.Counter(stats.replayed_ops)
                == collections.Counter(cp.lowered.ops)
                and schedules_equivalent(
                    cp.lowered, stats.replayed_ops,
                    ordered=cp.ordered, plan=cp.plan).ok)
        else:
            replay[executor] = stats.replayed_ops == cp.lowered.ops

    # measured update accuracy: offloaded (compressed, EF) vs the resident
    # fp32 AdamW reference over OPTIM_STEPS steps of real vgg16 grads.
    # Both optimizers consume the *same* gradient stream (computed at the
    # reference trajectory) so the drift isolates the compression error —
    # re-deriving grads at each trajectory's own params would measure
    # chaotic loss-landscape divergence, not optimizer-state fidelity.
    params = cp.init_params(jax.random.PRNGKey(0))
    rt = OptimRuntime(cp.optim_plan, g)
    opt = adamw()
    opt_state = opt.init(params)
    ref_p, off_p = params, params
    opt_stats = SwapExecStats()
    drift = 0.0
    t0 = time.perf_counter()
    for step in range(OPTIM_STEPS):
        x, y = batch_at(100 + step)
        _, grads, _ = cp.loss_and_grads(ref_p, x, y, executor="sim")
        ref_p, opt_state = opt.update(grads, opt_state, ref_p)
        off_p = offloaded_update(rt, off_p, grads, opt_stats)
        drift = max(float(jnp.max(jnp.abs(ref_p[ln][wn] - off_p[ln][wn])))
                    for ln in ref_p for wn in ref_p[ln])
    wall = time.perf_counter() - t0

    # uncompressed offload must match the reference to float noise: the
    # compression, not the offload dance, is the only approximation
    cp_nc = compile_plan(
        g, MemoryPlanConfig(optim_offload=True, optim_compress=False,
                            min_idle_phases=3, min_bytes=1 << 12),
        batch=OPTIM_BATCH)
    rt_nc = OptimRuntime(cp_nc.optim_plan, g)
    x, y = batch_at(100)
    _, g1, _ = cp.loss_and_grads(params, x, y, executor="sim")
    p_ref1, _ = opt.update(g1, opt.init(params), params)
    p_nc1 = offloaded_update(rt_nc, params, g1)
    nc_err = max(float(jnp.max(jnp.abs(p_ref1[ln][wn] - p_nc1[ln][wn])))
                 for ln in p_ref1 for wn in p_ref1[ln])

    reduction = summary["reduction_x"]
    accuracy_ok = bool(drift <= OPTIM_TOL_ABS
                       and nc_err <= OPTIM_NOCOMPRESS_TOL)
    rows = [(
        f"optim_offload/{OPTIM_MODEL}/adamw",
        reduction,
        f"x_resident_reduction "
        f"resident={summary['resident_bytes'] / MIB:.1f}MiB "
        f"peak={summary['device_peak_bytes'] / MIB:.1f}MiB "
        f"host={summary['host_pool_bytes'] / MIB:.1f}MiB "
        f"(fp32 {summary['host_fp32_bytes'] / MIB:.1f}) "
        f"dma/step={summary['dma_bytes_per_step'] / MIB:.1f}MiB "
        f"drift={drift:.2e} (tol {OPTIM_TOL_ABS}) "
        f"nc_err={nc_err:.2e} accuracy_ok={accuracy_ok} "
        f"replay={'/'.join(str(replay[e]) for e in EXEC_BACKENDS)}")]
    JSON_RECORDS.append({
        "bench": "optim_offload", "model": OPTIM_MODEL,
        "batch": OPTIM_BATCH, "optimizer": "adamw", "steps": OPTIM_STEPS,
        **{f"optim_{k}": v for k, v in summary.items()},
        "reduction_x": reduction,
        "update_max_abs_drift": drift,
        "update_tolerance_abs": OPTIM_TOL_ABS,
        "nocompress_max_abs_err": nc_err,
        "nocompress_tolerance_abs": OPTIM_NOCOMPRESS_TOL,
        "update_accuracy_ok": accuracy_ok,
        "replay_matches_compiled": replay,
        "opt_dma_bytes_measured": opt_stats.opt_dma_bytes,
        "opt_compressed_bytes_measured": opt_stats.opt_compressed_bytes,
        "opt_swap_outs": opt_stats.opt_swap_outs,
        "opt_prefetches": opt_stats.opt_prefetches,
        "wall_time_s": wall,
    })
    return rows


# The fusion-prover scaling case: the llama3.2-3b MLP trunk (28 layers,
# hundreds of lowered ops).  Planning-only — the point is the *static*
# dispatch-count reduction plan_fusion licenses, measured without paying
# for a 3B-parameter forward pass in CI.
FUSION_MODEL_BUDGET_MIB = 6


def bench_fusion():
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.verify import (replay_stream, schedules_equivalent,
                                   verify_fusion)
    from repro.core.zoo import transformer_mlp_stack

    g = transformer_mlp_stack()
    cp = compile_plan(
        g, MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                            min_idle_phases=6, min_bytes=1 << 20,
                            cooptimize=False,
                            hbm_budget_bytes=FUSION_MODEL_BUDGET_MIB << 20),
        batch=32)
    deps = cp.deps_report
    fusion = deps["fusion"]
    per_op_dispatch = deps["n_ops"]          # one Python call per op
    reduction = per_op_dispatch / fusion["dispatch_calls"]
    # CI gates this proof, not just the ratio: the fused stream the plan
    # licenses must be dependence-equivalent to the compiled schedule
    from repro.core.verify import plan_fusion
    fp = plan_fusion(cp.lowered, cp.ordered, cp.plan)
    equivalent = schedules_equivalent(
        cp.lowered, replay_stream(cp.lowered, fp),
        ordered=cp.ordered, plan=cp.plan).ok
    legal = not any(d.severity == "error"
                    for d in verify_fusion(fp, cp.lowered, cp.ordered,
                                           cp.plan))
    row = (f"fusion/{g.name}", reduction,
           f"x_dispatch_reduction ops={deps['n_ops']} "
           f"blocks={fusion['n_blocks']} largest={fusion['largest_block']} "
           f"dispatch={fusion['dispatch_calls']} "
           f"equivalent={equivalent} legal={legal} "
           f"slack_min={deps['min_prefetch_slack_phases']}")
    JSON_RECORDS.append({
        "bench": "fusion", "model": g.name, "batch": 32,
        "dispatch_reduction": reduction,
        "per_op_dispatch_calls": per_op_dispatch,
        "fused_dispatch_calls": fusion["dispatch_calls"],
        "replay_equivalent": equivalent, "fusion_legal": legal,
        **cp.report()})
    return [row]


VERIFY_MODELS = (("vgg16", 32), ("resnet18", 32), ("lenet5", 16))
VERIFY_PLANNERS = ("sorting", "bestfit", "segregated", "buddy")


def bench_verify():
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.zoo import ZOO

    rows = []
    for name, batch in VERIFY_MODELS:
        graph = ZOO[name]()
        for planner in VERIFY_PLANNERS:
            cp = compile_plan(
                graph, MemoryPlanConfig(planner=planner,
                                        host_planner="segregated",
                                        min_idle_phases=3,
                                        min_bytes=1 << 12), batch=batch)
            s = cp.verify_report.summary()
            rows.append((
                f"verify/{name}/{planner}",
                s["wall_time_s"] * 1e3,
                f"ms_verify ok={s['ok']} ops={s['ops_scanned']} "
                f"placements={s['placements_scanned']} "
                f"checks={len(s['checks_run'])} "
                f"errors={s['errors']} warnings={s['warnings']}"))
            JSON_RECORDS.append({
                "bench": "verify", "model": name, "batch": batch,
                "planner": planner, **s})
    return rows


# Multi-tenant serving: N sessions over bucketed traffic through the
# shared-plan PersonalizationService vs a per-user-recompile baseline (no
# cross-tenant plan sharing — every user compiles its own plan per bucket,
# the naive server).  Both sides run the identical per-step math
# (ServablePersonalizer.train_step: planned replay + momentum SGD on the
# per-user slice) and both include their plan-compile time in the clock,
# since amortising the compile is exactly what the serving cache buys.
# Rows carry sessions, bucket count, cache hit rate, and both aggregate
# rates.
# resnet18_transfer is the paper's personalization shape — frozen backbone,
# trainable head — so steps are cheap relative to plan compiles and the
# cache's amortisation is what the row measures.  Users alternate buckets
# across rounds, so the no-sharing baseline compiles users x buckets plans.
SERVE_MODEL = "resnet18_transfer"
SERVE_USERS = 8
SERVE_ROUNDS = 2
SERVE_BUCKETS = (4, 8)


def bench_serve():
    import time

    import jax

    from repro.core.exec.layers import init_params
    from repro.core.plan import MemoryPlanConfig, compile_plan
    from repro.core.zoo import ZOO
    from repro.serve import PersonalizationService
    from repro.serve.buckets import choose_bucket, dummy_batch, pad_to_bucket
    from repro.serve.servable import ServablePersonalizer

    g = ZOO[SERVE_MODEL]()
    config = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)
    traffic = []
    for rnd in range(SERVE_ROUNDS):
        for u in range(SERVE_USERS):
            # each user walks the bucket ladder across rounds, one short
            # of the bucket size so every step exercises pad + mask
            bucket = SERVE_BUCKETS[(u + rnd) % len(SERVE_BUCKETS)]
            traffic.append((f"u{u}", bucket - 1, rnd * SERVE_USERS + u))

    # Pre-warm the process-global per-layer jit caches (replay + optimizer
    # update, every bucket) on throwaway state, so neither timed side pays
    # first-trace latency the other then inherits — the timed comparison
    # isolates what the serving cache actually shares: plan compiles.
    warm_sv = ServablePersonalizer(g)
    warm_params = init_params(g, jax.random.PRNGKey(0))
    for b in SERVE_BUCKETS:
        cp = compile_plan(g, config, batch=b)
        x, y = dummy_batch(g, b)
        cp.loss_and_grads(warm_params, x, y)
        sess = warm_sv.open_session(f"warm{b}", cp.peak_bytes)
        xp, yp, mask = pad_to_bucket(*dummy_batch(g, b - 1), b)
        warm_sv.train_step(sess, cp, xp, yp, mask=mask)

    # -- shared-plan serving path (compile cache + admission) -------------
    t0 = time.perf_counter()
    svc = PersonalizationService(
        g, buckets=SERVE_BUCKETS, max_live_sessions=SERVE_USERS,
        config=config)
    svc.warmup()
    ok = 0
    for user, n, seed in traffic:
        x, y = dummy_batch(g, n, seed=seed)
        ok += int(svc.submit(user, x, y).ok)
    t_shared = time.perf_counter() - t0
    shared_sps = ok / t_shared
    rep = svc.report()
    within = all(s["within_share"]
                 for s in rep["serve"]["sessions"].values())

    # -- per-user-recompile baseline: same per-step math, no plan sharing -
    t0 = time.perf_counter()
    base_sv = ServablePersonalizer(g)
    plans, done = {}, 0
    for user, n, seed in traffic:
        bucket = choose_bucket(n, SERVE_BUCKETS)
        sess = base_sv.sessions.get(user) \
            or base_sv.open_session(user, 0)
        if (user, bucket) not in plans:
            plans[(user, bucket)] = compile_plan(g, config, batch=bucket)
        x, y = dummy_batch(g, n, seed=seed)
        xp, yp, mask = pad_to_bucket(x, y, bucket)
        base_sv.train_step(sess, plans[(user, bucket)], xp, yp, mask=mask)
        done += 1
    t_base = time.perf_counter() - t0
    base_sps = done / t_base

    cache = rep["plan_cache"]
    rows = [(
        f"serve/{SERVE_MODEL}/shared_x{SERVE_USERS}",
        shared_sps,
        f"steps_per_s base={base_sps:.2f} "
        f"speedup={shared_sps / base_sps:.2f}x "
        f"hits={cache['hits']}/{cache['hits'] + cache['misses']} "
        f"sessions={SERVE_USERS} buckets={len(SERVE_BUCKETS)} "
        f"within_share={within} compiles_base={len(plans)}")]
    JSON_RECORDS.append({
        "bench": "serve", "model": SERVE_MODEL,
        "sessions": SERVE_USERS, "rounds": SERVE_ROUNDS,
        "buckets": list(SERVE_BUCKETS), "n_buckets": len(SERVE_BUCKETS),
        "steps_ok": ok,
        "cache_hits": cache["hits"], "cache_misses": cache["misses"],
        "cache_hit_rate": cache["hit_rate"],
        "aggregate_steps_per_sec_shared": shared_sps,
        "aggregate_steps_per_sec_recompile_baseline": base_sps,
        "baseline_compiles": len(plans),
        "all_sessions_within_share": within,
        "admission": rep["admission"],
        "deadlocks": rep["serve"]["deadlocks"],
    })
    return rows


# Phase-interleaved serving vs the synchronous FIFO baseline (the PR 7
# drain): identical traffic — 8 sessions walking 2 buckets over
# resnet18_transfer — through the same PersonalizationService twice, once
# with interleave=False (one session at a time, default sim executor: the
# historical serving path) and once with interleave=True (all admitted
# sessions' schedule cursors round-robined at phase boundaries over one
# shared DeviceStreamEngine, two QoS classes).  Plan compiles and jit
# warm-up happen before the clock on both sides — what the row measures is
# execution: with N cursors live, one tenant's SwapOut/Prefetch/OptPrefetch
# DMA hides under another tenant's compute, and the hidden time is
# *attributed* (cross_hidden_dma_s), not inferred.  An untimed correctness
# wave then re-runs all 8 sessions through a fresh scheduler and holds the
# acceptance bar: per-session grads == jax.grad to 1e-4, every measured
# HBM peak inside its QoS-priced arena share, zero verify errors, nonzero
# cross-session hidden DMA time.
CONC_MODEL = "resnet18_transfer"
CONC_USERS = 8
CONC_ROUNDS = 3
CONC_BUCKETS = (4, 8)
CONC_QOS = (("premium", 2.0, 2), ("standard", 1.0, 6))
CONC_GRAD_RTOL = 1e-4
CONC_GRAD_ATOL = 1e-5
# Emulated swap-bus hardware (a CPU host's device_put is a memcpy, so the
# paper's narrow storage/host bus is emulated by completion-time pacing in
# the engines — numerics untouched, only the clock).  UFS-class figures:
# ~200 MB/s effective bandwidth, ~4 ms queue-depth-1 access latency.  The
# synchronous baseline pays latency per blocking access; the async queued
# engine amortizes it whenever the bus queue is non-empty.
CONC_BUS_GBPS = 0.2
CONC_BUS_LATENCY_S = 0.004


def bench_serve_concurrent():
    import time

    import jax
    import numpy as np

    from repro.core.exec.layers import init_params, reference_loss_and_grads
    from repro.core.plan import MemoryPlanConfig
    from repro.core.verify import verify_interleaving
    from repro.core.zoo import ZOO
    from repro.serve import (PersonalizationService, QosClass, SessionWork,
                             StepScheduler)
    from repro.serve.buckets import dummy_batch

    g = ZOO[CONC_MODEL]()
    # optim_offload puts the OptPrefetch H2D lane on the same emulated bus,
    # so the row also measures hidden vs exposed *optimizer* DMA
    config = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12,
                              optim_offload=True)
    qos = tuple(QosClass(n, w, slots=s) for n, w, s in CONC_QOS)
    qos_of = {f"u{u}": ("premium" if u < CONC_QOS[0][2] else "standard")
              for u in range(CONC_USERS)}

    def traffic(rounds, first_round=0):
        out = []
        for rnd in range(first_round, first_round + rounds):
            for u in range(CONC_USERS):
                b = CONC_BUCKETS[(u + rnd) % len(CONC_BUCKETS)]
                out.append((f"u{u}", b - 1, rnd * CONC_USERS + u))
        return out

    def run(svc, reqs):
        for user, n, seed in reqs:
            x, y = dummy_batch(g, n, seed=seed)
            svc.enqueue(user, x, y, qos=qos_of[user])
        return sum(r.ok for r in svc.drain())

    services = {}
    for interleave in (False, True):
        svc = PersonalizationService(
            g, buckets=CONC_BUCKETS, max_live_sessions=CONC_USERS,
            config=config, qos=qos, interleave=interleave,
            bus_gbps=CONC_BUS_GBPS, bus_latency_s=CONC_BUS_LATENCY_S)
        svc.warmup()
        run(svc, traffic(1))          # untimed: admissions, compiles, jit
        services[interleave] = svc

    timed, ok = {}, {}
    for interleave in (False, True):
        reqs = traffic(CONC_ROUNDS, first_round=1)
        t0 = time.perf_counter()
        ok[interleave] = run(services[interleave], reqs)
        timed[interleave] = time.perf_counter() - t0
    fifo_sps = ok[False] / timed[False]
    inter_sps = ok[True] / timed[True]
    speedup = inter_sps / fifo_sps

    # -- untimed correctness wave: the acceptance bar ---------------------
    svc = services[True]
    sched = StepScheduler()
    works, refs = [], {}
    for i, user in enumerate(sorted(svc.admission.live)):
        bucket = CONC_BUCKETS[i % len(CONC_BUCKETS)]
        cp = svc.cache.get_or_compile(
            g, config, bucket=bucket,
            arena_budget_bytes=svc.admission.share_for(
                svc.admission.qos_of(user)))
        params = init_params(g, jax.random.PRNGKey(100 + i))
        x, y = dummy_batch(g, bucket, seed=200 + i)
        refs[user] = (params, x, y, cp)
        works.append(SessionWork(
            user=user, arrival=i + 1, qos=svc.admission.qos_of(user),
            weight=svc.admission.qos_class(svc.admission.qos_of(user)).weight,
            base_offset=svc.admission.base_offset(user),
            share_bytes=svc.admission.share_for(svc.admission.qos_of(user)),
            cp=cp, x=x, y=y, mask=None, params_fn=lambda p=params: p))
    outs = sched.run(works)
    grads_ok, within, peaks = True, True, {}
    for o in outs:
        params, x, y, cp = refs[o.user]
        _, ref_grads = reference_loss_and_grads(g, params, x, y)
        for a, b in zip(jax.tree_util.tree_leaves(o.grads),
                        jax.tree_util.tree_leaves(ref_grads)):
            if not np.allclose(np.asarray(a), np.asarray(b),
                               rtol=CONC_GRAD_RTOL, atol=CONC_GRAD_ATOL):
                grads_ok = False
        peaks[o.user] = o.stats.hbm_high_water
        w = next(w for w in works if w.user == o.user)
        within &= o.stats.hbm_high_water <= w.share_bytes
    wave = sched.report()
    # the measured peaks re-prove the partition (not just the planned ones)
    verify_errors = wave["verify_errors"] + len(
        verify_interleaving(svc.admission.arena_slices(peaks)).errors())
    # hidden-vs-exposed bus accounting comes from the *timed* interleaved
    # drain (the paced engine), where the overlap is wall-clock real
    timed_rep = services[True].report()["scheduler"]
    bus = (timed_rep["hidden_dma_s"] + timed_rep["exposed_dma_s"]
           + timed_rep["opt_hidden_dma_s"] + timed_rep["opt_exposed_dma_s"])
    overlap_fraction = min(1.0, (timed_rep["hidden_dma_s"]
                                 + timed_rep["opt_hidden_dma_s"])
                           / bus) if bus > 0 else 0.0
    cross_hidden = timed_rep["cross_hidden_dma_s"]

    rep = svc.report()
    rows = [(
        f"serve_concurrent/{CONC_MODEL}/x{CONC_USERS}",
        inter_sps,
        f"steps_per_s fifo={fifo_sps:.2f} speedup={speedup:.2f}x "
        f"overlap={overlap_fraction:.2f} "
        f"cross_hidden={cross_hidden * 1e3:.1f}ms "
        f"grads_ok={grads_ok} within_share={within} "
        f"verify_errors={verify_errors} "
        f"qos={'/'.join(n for n, _, _ in CONC_QOS)}")]
    JSON_RECORDS.append({
        "bench": "serve_concurrent", "model": CONC_MODEL,
        "sessions": CONC_USERS, "rounds": CONC_ROUNDS,
        "buckets": list(CONC_BUCKETS), "n_buckets": len(CONC_BUCKETS),
        "qos_classes": [{"name": n, "weight": w, "slots": s}
                        for n, w, s in CONC_QOS],
        "steps_ok_interleaved": ok[True], "steps_ok_fifo": ok[False],
        "aggregate_steps_per_sec_interleaved": inter_sps,
        "aggregate_steps_per_sec_fifo": fifo_sps,
        "speedup_vs_fifo": speedup,
        "bus_gbps": CONC_BUS_GBPS,
        "bus_latency_s": CONC_BUS_LATENCY_S,
        "overlap_fraction": overlap_fraction,
        "cross_hidden_dma_s": cross_hidden,
        "hidden_dma_s": timed_rep["hidden_dma_s"],
        "exposed_dma_s": timed_rep["exposed_dma_s"],
        "opt_hidden_dma_s": timed_rep["opt_hidden_dma_s"],
        "opt_exposed_dma_s": timed_rep["opt_exposed_dma_s"],
        "grads_ok": grads_ok,
        "all_sessions_within_share": within,
        "verify_errors": verify_errors,
        "scheduler_rounds": wave["rounds"],
        "phase_advances": wave["phase_advances"],
        "by_qos": rep["serve"]["by_qos"],
        "admission": rep["admission"],
    })
    return rows


ALL = {
    "swap_tradeoff": bench_swap_tradeoff,
    "swap_model": bench_swap_model,
    "host_planner": bench_host_planner,
    "swap_exec": bench_swap_exec,
    "optim_offload": bench_optim_offload,
    "verify": bench_verify,
    "fusion": bench_fusion,
    "serve": bench_serve,
    "serve_concurrent": bench_serve_concurrent,
}
