#!/usr/bin/env bash
# Tier-1 CI gate: run the full test suite on CPU, skipping slow probes.
# Collection errors fail the run (pytest exits non-zero on them), matching
# the paper's own commit gate ("if a weight or activation value has an
# error over 1e-4 the commit is rejected").
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# lint gate: ruff config lives in pyproject.toml ([tool.ruff]); the step
# is skipped when ruff isn't on PATH (the dev container doesn't ship it)
# but CI installs it, so violations still fail the workflow.
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "ruff not installed; skipping lint gate" >&2
fi

# invariant lint: AST rules ruff cannot express — no src/ call site may
# reach a backend's run() without verify admission, and no src/ module may
# import the deprecated repro.core re-exports or the planned_exec shim.
python tools/lint_invariants.py

python -m pytest -q -m "not slow" "$@"

# compile_plan smoke: the facade must take a zoo model from graph to a
# validated, co-optimised plan (peak <= no-swap baseline) in one call,
# and a transformer ModelConfig to a joint keep/recompute/offload plan
# with honest DMA accounting.
PYTHONPATH=src python - <<'EOF'
from repro.core import MemoryPlanConfig, compile_plan, plan_step_time_s
from repro.core.remat_policy import transformer_intermediates
from repro.core.zoo import ZOO
from repro.configs import ARCHS

for name in ("lenet5", "resnet18"):
    cp = compile_plan(ZOO[name](),
                      MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12),
                      batch=8)
    cp.plan.validate()
    assert cp.peak_bytes <= cp.baseline.arena_bytes, name
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes, name
    print(f"compile_plan smoke {name}: peak={cp.peak_bytes} "
          f"base={cp.baseline.arena_bytes} swaps={len(cp.swapped_names())} "
          f"dropped={len(cp.coopt.dropped)}")

# allocator-layer smoke: one zoo model compiled with every host_planner;
# the executor must replay the lowered ExecutionSchedule EXACTLY (op list
# equality — no late swap-ins, no skipped transfers) and respect both
# planned high-water bounds.
import jax
import jax.numpy as jnp

g = ZOO["lenet5"]()
for hp in ("sorting", "bestfit", "segregated", "buddy"):
    cp = compile_plan(g, MemoryPlanConfig(planner="bestfit", host_planner=hp,
                                          min_idle_phases=3,
                                          min_bytes=1 << 12), batch=8)
    cp.plan.validate()
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (8,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.replayed_ops == cp.lowered.ops, \
        f"host_planner={hp}: executor replay diverged from compiled schedule"
    assert stats.late_swap_ins == 0, hp
    assert stats.hbm_high_water <= stats.planned_peak, hp
    assert stats.host_high_water <= cp.host_pool_bytes, hp
    print(f"exec-schedule smoke lenet5/{hp}: "
          f"ops={cp.lowered.counts()} host={cp.host_pool_bytes} "
          f"host_hw={stats.host_high_water} "
          f"inplace={cp.inplace_prefetch_count}")

# executor-backend gate: EVERY registered backend must execute the
# compiled plan end-to-end, agree on transfer accounting, and match
# jax.grad.  Replay semantics are per-backend: sim/async replay the op
# list verbatim; jit_blocks replays a proven-equivalent fused permutation
# (same multiset, every dependence edge preserved — schedules_equivalent
# gates it) with strictly fewer Python-level dispatch calls than ops.
from collections import Counter
from repro.core.exec import BACKENDS
from repro.core.exec.layers import reference_loss_and_grads
from repro.core.verify import schedules_equivalent
import numpy as np

_, grads_ref = reference_loss_and_grads(g, params, x, y)
per_backend = {}
for ex in sorted(BACKENDS):
    cp = compile_plan(g, MemoryPlanConfig(min_idle_phases=3,
                                          min_bytes=1 << 12, executor=ex),
                      batch=8)
    _, grads, stats = cp.loss_and_grads(params, x, y)
    assert stats.backend == ex
    if ex == "jit_blocks":
        assert Counter(stats.replayed_ops) == Counter(cp.lowered.ops), \
            "executor=jit_blocks: replayed op multiset diverged"
        schedules_equivalent(cp.lowered, stats.replayed_ops,
                             ordered=cp.ordered,
                             plan=cp.plan).raise_if_errors()
        assert stats.dispatch_calls < len(cp.lowered.ops), \
            "jit_blocks must fuse at least one block"
    else:
        assert stats.replayed_ops == cp.lowered.ops, \
            f"executor={ex}: replay diverged from compiled schedule"
        assert stats.dispatch_calls == len(stats.replayed_ops), ex
    assert stats.late_swap_ins == 0, ex
    assert stats.host_high_water <= cp.host_pool_bytes, ex
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    per_backend[ex] = stats
    extra = ""
    if ex == "async":
        assert stats.achieved_overlap is not None
        assert 0 < stats.inflight_high_water \
            <= cp.schedule.peak_inflight_prefetch
        extra = (f" overlap={stats.achieved_overlap:.2f}"
                 f" inflight_hw={stats.inflight_high_water}"
                 f"/{cp.schedule.peak_inflight_prefetch}")
    if ex == "jit_blocks":
        extra = f" dispatch={stats.dispatch_calls}/{len(cp.lowered.ops)}"
    print(f"backend gate lenet5/{ex}: dma={stats.dma_bytes} "
          f"swaps={stats.swap_outs}/{stats.prefetches}{extra}")
# all backends executed the same schedule: identical transfer accounting
for ex in sorted(set(BACKENDS) - {"sim"}):
    assert per_backend["sim"].dma_bytes == per_backend[ex].dma_bytes, ex
    assert per_backend["sim"].host_high_water \
        == per_backend[ex].host_high_water, ex

# model-config joint-plan smoke: a tight budget must force evictions down
# both priced lanes, and the plan's DMA traffic must be visible end-to-end.
cfg = ARCHS["llama3.2-3b"]
hw = {"dma_gbps": 80.0, "device_tflops": 200.0}
inter = transformer_intermediates(
    batch_tokens=2048, d_model=cfg.d_model, d_ff=cfg.d_ff,
    n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim)
cp = compile_plan(cfg, MemoryPlanConfig(remat=True,
                                        remat_budget_bytes=1 << 20,
                                        offload=True, **hw),
                  batch_tokens=2048)
r = cp.report()
assert cp.remat_plan.dropped and cp.remat_plan.offloaded, "joint plan must mix lanes"
assert cp.dma_bytes == r["offload_dma_bytes_per_layer"] * cfg.n_layers > 0
assert r["recompute_flops_per_layer"] > 0
pure = compile_plan(cfg, MemoryPlanConfig(remat=True,
                                          remat_budget_bytes=1 << 20,
                                          offload=False), batch_tokens=2048)
assert (plan_step_time_s(cp.remat_plan, inter, **hw)
        < plan_step_time_s(pure.remat_plan, inter, **hw))
print(f"compile_plan smoke {cfg.name}: decisions={r['remat_decisions']} "
      f"dma={cp.dma_bytes} est={r['est_step_time_s_per_layer']:.6f}s/layer "
      f"lowering={r.get('offload_lowering')}")
EOF

# static-verifier gate (1/2): the whole zoo x device planner x host
# planner sweep must compile with verify="error" — i.e. every lowered
# schedule passes all registered checks with zero diagnostics.
PYTHONPATH=src python - <<'EOF'
from repro.core import MemoryPlanConfig, compile_plan
from repro.core.verify import CHECKS
from repro.core.zoo import ZOO

ops = placements = 0
for name in sorted(ZOO):
    for planner in ("sorting", "bestfit", "segregated", "buddy"):
        for hp in ("sorting", "segregated"):
            cp = compile_plan(
                ZOO[name](),
                MemoryPlanConfig(planner=planner, host_planner=hp,
                                 min_idle_phases=3, min_bytes=1 << 12,
                                 cooptimize=False, verify="error"),
                batch=4)
            r = cp.verify_report
            assert r.ok, (name, planner, hp)
            assert set(r.checks_run) == set(CHECKS), (name, planner, hp)
            ops += r.ops_scanned
            placements += r.placements_scanned
print(f"verify sweep clean: {len(ZOO)} models x 4 planners x 2 host "
      f"planners, {ops} ops / {placements} placements scanned, "
      f"checks={sorted(CHECKS)}")
EOF

# static-verifier gate (2/2): the mutation harness forges one corruption
# per class and requires every class flagged with the expected check id —
# a verifier that never fires would pass gate 1/2 trivially.
PYTHONPATH=src python tools/mutate_schedule.py

# serving smoke: 2 buckets x 4 users on lenet5 through the multi-tenant
# PersonalizationService — every request must complete, plans must be
# shared across tenants (hits >= users - buckets), every session's
# measured peak must stay inside its arena share, and the queue must
# never deadlock.
PYTHONPATH=src python - <<'EOF'
from repro.core.zoo import ZOO
from repro.serve import PersonalizationService
from repro.serve.buckets import dummy_batch

USERS, BUCKETS = 4, (8, 16)
g = ZOO["lenet5"]()
svc = PersonalizationService(g, buckets=BUCKETS, max_live_sessions=USERS)
svc.warmup()
for u in range(USERS):
    n = 5 if u % 2 else 12     # both buckets, both padded
    res = svc.submit(f"u{u}", *dummy_batch(g, n, seed=u))
    assert res.ok, (u, res.status, res.reason)
    assert res.peak_bytes <= res.arena_share_bytes, u
rep = svc.report()
assert rep["serve"]["completed"] == USERS
assert rep["serve"]["deadlocks"] == 0, "admission deadlock detected"
assert rep["plan_cache"]["hits"] >= USERS - len(BUCKETS), rep["plan_cache"]
assert rep["plan_cache"]["entries"] == len(BUCKETS)
print(f"serving smoke: {USERS} users over {len(BUCKETS)} buckets, "
      f"cache={rep['plan_cache']['hits']}h/{rep['plan_cache']['misses']}m, "
      f"share={rep['admission']['arena_share_bytes']}B, deadlocks=0")
EOF

# benchmark JSON emission: the swap benches (graph + model path) must keep
# producing the machine-readable perf-trajectory file, now including the
# per-planner host-pool fragmentation sweep.
PYTHONPATH=src python -m benchmarks.run \
    --only swap_tradeoff,swap_model,host_planner,swap_exec,optim_offload,verify,fusion,serve,serve_concurrent \
    --bench-json results/BENCH_swap.json > /dev/null
test -s results/BENCH_swap.json
PYTHONPATH=src python - <<'EOF'
import json
recs = json.load(open("results/BENCH_swap.json"))["records"]
model_rows = [r for r in recs if r["bench"] == "swap_model"]
assert model_rows, "BENCH_swap.json must carry model-path rows"
assert any(r["dma_bytes"] > 0 for r in model_rows)
assert all("remat_decisions" in r for r in model_rows)
host_rows = [r for r in recs if r["bench"] == "host_planner"]
assert host_rows, "BENCH_swap.json must carry host-planner sweep rows"
assert {r["host_planner"] for r in host_rows} \
    == {"sorting", "bestfit", "segregated", "buddy"}
assert all("host_utilization" in r and "legacy_host_bytes" in r
           for r in host_rows)
# the fragmentation-aware pool must strictly beat the legacy
# pack-every-copy bytes somewhere in the sweep
assert any(r["host_pool_bytes"] < r["legacy_host_bytes"]
           for r in host_rows if r["host_planner"] in ("segregated", "buddy"))
# executor overlap rows: every registered backend ran end-to-end with its
# own replay semantics honoured (verbatim for sim/async, proven-equivalent
# fused permutation for jit_blocks), and the async rows carry the measured
# overlap (achieved fraction, in-flight high water, DMA bytes)
exec_rows = [r for r in recs if r["bench"] == "swap_exec"]
assert exec_rows, "BENCH_swap.json must carry swap_exec rows"
assert {r["executor"] for r in exec_rows} == {"sim", "async", "jit_blocks"}
assert all(r["replay_matches_compiled"] for r in exec_rows)
assert all(r["late_swap_ins"] == 0 for r in exec_rows)
# per-backend wall-clock: every exec row measures its step time, and the
# llama3.2-3b MLP trunk cut runs on all three backends so the dispatch
# overhead comparison is anchored to real 3072x8192 matmuls
assert all(r.get("wall_time_s", 0) > 0 for r in exec_rows), \
    "swap_exec rows must carry measured step wall time"
trunk_rows = [r for r in exec_rows
              if r["model"].startswith("transformer_mlp_stack")]
assert {r["executor"] for r in trunk_rows} == {"sim", "async", "jit_blocks"}, \
    "the MLP-trunk wall-clock rows must cover every backend"
for r in exec_rows:
    assert r["dispatch_calls"] > 0 and r["schedule_op_count"] > 0, r
    if r["executor"] == "jit_blocks":
        # the whole point: fewer Python-level dispatches than ops
        assert r["replay_equivalent_modulo_fusion"], r
        assert r["dispatch_calls"] < r["schedule_op_count"], r
    else:
        assert r["dispatch_calls"] == r["schedule_op_count"], r
    # the compile-time dependence analysis rides every graph-path row
    assert "deps" in r and r["deps"]["fusion"]["n_blocks"] >= 1, r
async_rows = [r for r in exec_rows if r["executor"] == "async"]
overlapped = [r for r in async_rows if r["prefetches"] > 0]
assert overlapped, "at least one async row must issue real transfers"
for r in overlapped:
    assert r["achieved_overlap"] is not None
    assert 0.0 <= r["achieved_overlap"] <= 1.0
    assert 0 < r["inflight_high_water"] \
        <= r["planned_peak_inflight_prefetch"]
    assert r["measured_dma_bytes"] > 0
# zero-swap plans degrade gracefully on the async backend too
for r in [r for r in async_rows if r["prefetches"] == 0]:
    assert r["achieved_overlap"] is None
    assert r["inflight_high_water"] == 0
# static-verifier rows: every sweep point verified clean at compile time
# and carries the verifier's own cost/coverage stats
verify_rows = [r for r in recs if r["bench"] == "verify"]
assert verify_rows, "BENCH_swap.json must carry verify rows"
assert {r["planner"] for r in verify_rows} \
    == {"sorting", "bestfit", "segregated", "buddy"}
for r in verify_rows:
    assert r["ok"] and r["errors"] == 0, r
    assert r["ops_scanned"] > 0 and r["placements_scanned"] > 0
    assert r["wall_time_s"] >= 0.0
    assert len(r["checks_run"]) >= 7
    # per-check wall time: every registered pass accounts its own cost,
    # including the dependence prover
    assert set(r["check_wall_time_s"]) == set(r["checks_run"]), r
    assert "deps" in r["check_wall_time_s"], r
    assert all(t >= 0.0 for t in r["check_wall_time_s"].values()), r
# fusion-prover scaling row: on the llama3.2-3b MLP trunk the proven
# fusion plan must cut Python-level dispatch calls >= 5x vs per-op
# dispatch, with the fused stream proven dependence-equivalent and the
# plan re-proven legal by verify_fusion
fusion_rows = [r for r in recs if r["bench"] == "fusion"]
assert fusion_rows, "BENCH_swap.json must carry the fusion row"
for r in fusion_rows:
    assert r["dispatch_reduction"] >= 5.0, r["dispatch_reduction"]
    assert r["replay_equivalent"] and r["fusion_legal"], r
    assert r["fused_dispatch_calls"] < r["per_op_dispatch_calls"], r
    assert r["deps"]["fusion"]["splits"]["fence"] >= 1, \
        "the fusion bench must exercise real transfer fences"
# multi-tenant serving rows: N sessions over bucketed traffic, plans
# shared through the compile cache, aggregate throughput strictly above
# the per-user-recompile baseline, every session inside its arena share
serve_rows = [r for r in recs if r["bench"] == "serve"]
assert serve_rows, "BENCH_swap.json must carry serve rows"
for r in serve_rows:
    assert r["sessions"] >= 2 and r["n_buckets"] >= 2, r
    assert r["cache_hits"] + r["cache_misses"] > 0
    assert 0.0 <= r["cache_hit_rate"] <= 1.0
    assert r["cache_hits"] >= r["sessions"] - r["n_buckets"], r
    assert r["aggregate_steps_per_sec_shared"] > 0
    assert (r["aggregate_steps_per_sec_shared"]
            > r["aggregate_steps_per_sec_recompile_baseline"]), \
        "plan sharing must beat per-user recompiles"
    assert r["all_sessions_within_share"], r
    assert r["deadlocks"] == 0
    assert r["admission"]["arena_share_bytes"] > 0
# optimizer-state offload rows: the tentpole acceptance is measured, not
# asserted — on vgg16 under AdamW the device-resident optimizer bytes
# must drop >= 3x vs the all-resident baseline, the EF-compressed update
# must track the resident fp32 reference within the established
# tolerance, the uncompressed path must match to float noise, and every
# backend must have replayed the opt-extended schedule faithfully
optim_rows = [r for r in recs if r["bench"] == "optim_offload"]
assert optim_rows, "BENCH_swap.json must carry the optim_offload row"
for r in optim_rows:
    assert r["reduction_x"] >= 3.0, \
        f"optimizer offload reduction {r['reduction_x']:.2f}x < 3.0x floor"
    assert r["update_accuracy_ok"], \
        (r["update_max_abs_drift"], r["nocompress_max_abs_err"])
    assert r["update_max_abs_drift"] <= r["update_tolerance_abs"], r
    assert r["nocompress_max_abs_err"] <= r["nocompress_tolerance_abs"], r
    assert set(r["replay_matches_compiled"]) \
        == {"sim", "async", "jit_blocks"}
    assert all(r["replay_matches_compiled"].values()), \
        r["replay_matches_compiled"]
    assert r["optim_n_slots"] > 0 and r["optim_compress"], r
    assert r["opt_dma_bytes_measured"] > 0
    # the compressed host copy must actually be smaller than fp32
    assert r["optim_host_pool_bytes"] < r["optim_host_fp32_bytes"], r
# phase-interleaved concurrent serving row: N sessions round-robined at
# phase boundaries over a shared paced bus — the interleaved drain must
# beat the synchronous FIFO baseline >= 1.5x, hide a nonzero amount of
# one tenant's DMA under another tenant's compute, keep every session
# inside its QoS-priced arena share, and replay grads that match
# jax.grad, with the cross-session arena proof clean
conc_rows = [r for r in recs if r["bench"] == "serve_concurrent"]
assert conc_rows, "BENCH_swap.json must carry the serve_concurrent row"
for r in conc_rows:
    assert r["sessions"] == 8 and r["n_buckets"] == 2, r
    assert r["speedup_vs_fifo"] >= 1.5, \
        f"interleaved speedup {r['speedup_vs_fifo']:.2f}x < 1.5x floor"
    assert 0.0 <= r["overlap_fraction"] <= 1.0, r["overlap_fraction"]
    assert r["cross_hidden_dma_s"] > 0.0, \
        "no cross-session DMA was hidden under foreign compute"
    assert r["opt_hidden_dma_s"] > 0.0, \
        "optimizer-state DMA must stream on the async engine"
    assert r["grads_ok"], "per-session grads diverged from jax.grad"
    assert r["all_sessions_within_share"], r
    assert r["verify_errors"] == 0, r
    assert r["steps_ok_interleaved"] == r["steps_ok_fifo"] > 0, r
    assert len(r["qos_classes"]) >= 2, "bench must exercise >= 2 QoS classes"
EOF
echo "BENCH_swap.json emitted ($(wc -c < results/BENCH_swap.json) bytes)"
