#!/usr/bin/env bash
# Tier-1 CI gate: run the full test suite on CPU, skipping slow probes.
# Collection errors fail the run (pytest exits non-zero on them), matching
# the paper's own commit gate ("if a weight or activation value has an
# error over 1e-4 the commit is rejected").
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -q -m "not slow" "$@"
