#!/usr/bin/env bash
# Tier-1 CI gate: run the full test suite on CPU, skipping slow probes.
# Collection errors fail the run (pytest exits non-zero on them), matching
# the paper's own commit gate ("if a weight or activation value has an
# error over 1e-4 the commit is rejected").
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -q -m "not slow" "$@"

# compile_plan smoke: the facade must take a zoo model from graph to a
# validated, co-optimised plan (peak <= no-swap baseline) in one call.
PYTHONPATH=src python - <<'EOF'
from repro.core import MemoryPlanConfig, compile_plan
from repro.core.zoo import ZOO

for name in ("lenet5", "resnet18"):
    cp = compile_plan(ZOO[name](),
                      MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12),
                      batch=8)
    cp.plan.validate()
    assert cp.peak_bytes <= cp.baseline.arena_bytes, name
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes, name
    print(f"compile_plan smoke {name}: peak={cp.peak_bytes} "
          f"base={cp.baseline.arena_bytes} swaps={len(cp.swapped_names())} "
          f"dropped={len(cp.coopt.dropped)}")
EOF

# benchmark JSON emission: the swap benches must keep producing the
# machine-readable perf-trajectory file.
PYTHONPATH=src python -m benchmarks.run --only swap_tradeoff \
    --bench-json results/BENCH_swap.json > /dev/null
test -s results/BENCH_swap.json
echo "BENCH_swap.json emitted ($(wc -c < results/BENCH_swap.json) bytes)"
