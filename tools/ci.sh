#!/usr/bin/env bash
# Tier-1 CI gate: run the full test suite on CPU, skipping slow probes.
# Collection errors fail the run (pytest exits non-zero on them), matching
# the paper's own commit gate ("if a weight or activation value has an
# error over 1e-4 the commit is rejected").
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -q -m "not slow" "$@"

# compile_plan smoke: the facade must take a zoo model from graph to a
# validated, co-optimised plan (peak <= no-swap baseline) in one call,
# and a transformer ModelConfig to a joint keep/recompute/offload plan
# with honest DMA accounting.
PYTHONPATH=src python - <<'EOF'
from repro.core import MemoryPlanConfig, compile_plan, plan_step_time_s
from repro.core.remat_policy import transformer_intermediates
from repro.core.zoo import ZOO
from repro.configs import ARCHS

for name in ("lenet5", "resnet18"):
    cp = compile_plan(ZOO[name](),
                      MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12),
                      batch=8)
    cp.plan.validate()
    assert cp.peak_bytes <= cp.baseline.arena_bytes, name
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes, name
    print(f"compile_plan smoke {name}: peak={cp.peak_bytes} "
          f"base={cp.baseline.arena_bytes} swaps={len(cp.swapped_names())} "
          f"dropped={len(cp.coopt.dropped)}")

# allocator-layer smoke: one zoo model compiled with every host_planner;
# the executor must replay the lowered ExecutionSchedule EXACTLY (op list
# equality — no late swap-ins, no skipped transfers) and respect both
# planned high-water bounds.
import jax
import jax.numpy as jnp

g = ZOO["lenet5"]()
for hp in ("sorting", "bestfit", "segregated", "buddy"):
    cp = compile_plan(g, MemoryPlanConfig(planner="bestfit", host_planner=hp,
                                          min_idle_phases=3,
                                          min_bytes=1 << 12), batch=8)
    cp.plan.validate()
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (8,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.replayed_ops == cp.lowered.ops, \
        f"host_planner={hp}: executor replay diverged from compiled schedule"
    assert stats.late_swap_ins == 0, hp
    assert stats.hbm_high_water <= stats.planned_peak, hp
    assert stats.host_high_water <= cp.host_pool_bytes, hp
    print(f"exec-schedule smoke lenet5/{hp}: "
          f"ops={cp.lowered.counts()} host={cp.host_pool_bytes} "
          f"host_hw={stats.host_high_water} "
          f"inplace={cp.inplace_prefetch_count}")

# model-config joint-plan smoke: a tight budget must force evictions down
# both priced lanes, and the plan's DMA traffic must be visible end-to-end.
cfg = ARCHS["llama3.2-3b"]
hw = {"dma_gbps": 80.0, "device_tflops": 200.0}
inter = transformer_intermediates(
    batch_tokens=2048, d_model=cfg.d_model, d_ff=cfg.d_ff,
    n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim)
cp = compile_plan(cfg, MemoryPlanConfig(remat=True,
                                        remat_budget_bytes=1 << 20,
                                        offload=True, **hw),
                  batch_tokens=2048)
r = cp.report()
assert cp.remat_plan.dropped and cp.remat_plan.offloaded, "joint plan must mix lanes"
assert cp.dma_bytes == r["offload_dma_bytes_per_layer"] * cfg.n_layers > 0
assert r["recompute_flops_per_layer"] > 0
pure = compile_plan(cfg, MemoryPlanConfig(remat=True,
                                          remat_budget_bytes=1 << 20,
                                          offload=False), batch_tokens=2048)
assert (plan_step_time_s(cp.remat_plan, inter, **hw)
        < plan_step_time_s(pure.remat_plan, inter, **hw))
print(f"compile_plan smoke {cfg.name}: decisions={r['remat_decisions']} "
      f"dma={cp.dma_bytes} est={r['est_step_time_s_per_layer']:.6f}s/layer "
      f"lowering={r.get('offload_lowering')}")
EOF

# benchmark JSON emission: the swap benches (graph + model path) must keep
# producing the machine-readable perf-trajectory file, now including the
# per-planner host-pool fragmentation sweep.
PYTHONPATH=src python -m benchmarks.run \
    --only swap_tradeoff,swap_model,host_planner \
    --bench-json results/BENCH_swap.json > /dev/null
test -s results/BENCH_swap.json
PYTHONPATH=src python - <<'EOF'
import json
recs = json.load(open("results/BENCH_swap.json"))["records"]
model_rows = [r for r in recs if r["bench"] == "swap_model"]
assert model_rows, "BENCH_swap.json must carry model-path rows"
assert any(r["dma_bytes"] > 0 for r in model_rows)
assert all("remat_decisions" in r for r in model_rows)
host_rows = [r for r in recs if r["bench"] == "host_planner"]
assert host_rows, "BENCH_swap.json must carry host-planner sweep rows"
assert {r["host_planner"] for r in host_rows} \
    == {"sorting", "bestfit", "segregated", "buddy"}
assert all("host_utilization" in r and "legacy_host_bytes" in r
           for r in host_rows)
# the fragmentation-aware pool must strictly beat the legacy
# pack-every-copy bytes somewhere in the sweep
assert any(r["host_pool_bytes"] < r["legacy_host_bytes"]
           for r in host_rows if r["host_planner"] in ("segregated", "buddy"))
EOF
echo "BENCH_swap.json emitted ($(wc -c < results/BENCH_swap.json) bytes)"
