#!/usr/bin/env python
"""Mutation harness: forge corrupted schedules, prove the verifier catches
each one.

The static verifier (``repro.core.verify``) is only worth trusting if its
false-negative rate is measured: a checker that never fires also "passes"
every plan.  This harness compiles a known-good reference plan, applies
one corruption per class — the planner-bug shapes the verifier exists to
catch — and asserts every class is flagged *with the expected check id*:

==================  =======================  ==========================
mutation class      forged corruption        expected check id
==================  =======================  ==========================
shift_offset        prefetch lands at the    arena_alias
                    wrong arena offset
drop_prefetch       swap-out with no         use_before_resident
                    matching prefetch
reorder_swap_out    swap-out retires after   transfer_race
                    its prefetch issued
double_free         one Free replayed twice  double_free
truncate_free       one Free dropped         leak
budget_overflow     prefetch target beyond   budget
                    the packed arena peak
misalign            offset off the ALIGN     alignment
                    grid
corrupt_opt_offset  OptPrefetch working      optim_region
                    buffer off its packed
                    opt-arena slot
hoist_compute       Compute hoisted before   dep_transfer_fence
                    the Prefetch feeding it
drop_dep_edge       SwapOut permuted ahead   dep_edge
                    of its producing Compute
fuse_across_swap    forged FusedBlock        fusion_fence
                    spanning a SwapOut
overlap_arena_      two sessions' arena      cross_session_arena
shares              shares alias
==================  =======================  ==========================

The first eight corrupt op *metadata* (offsets, phases, multiset) with
positions intact — the residency/aliasing checkers' beat
(``corrupt_opt_offset`` targets the optimizer-offload lane: the reference
plan compiles with ``optim_offload=True`` so its schedule carries real
``OptPrefetch``/``OptSwapOut`` ops).  The last three
corrupt op *positions* (or a fusion plan) with metadata intact — the
dependence prover's beat (``repro.core.verify.deps``): a checker suite
blind to either axis would pass one of the two families.
``fuse_across_swap`` forges a :class:`FusionPlan` rather than an op list,
so it is judged by ``verify_fusion`` instead of ``verify_schedule``.
``overlap_arena_shares`` corrupts neither axis of one schedule: it forges
the *admission-time* per-session arena partition the phase-interleaved
scheduler trusts (two sessions' base offsets overlapping), so it is
judged by ``verify_interleaving`` — the cross-session aliasing prover
every other checker is structurally blind to (they each see one session's
private offsets, which remain individually clean).

Run as a script (CI gate: exits non-zero on any missed corruption) or
import ``MUTATIONS`` / ``forge`` from tests.
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import MemoryPlanConfig, compile_plan   # noqa: E402
from repro.core.plan import (Compute, ExecutionSchedule, Free,  # noqa: E402
                             OptPrefetch, Prefetch, SwapOut)
from repro.core.planner import ALIGN  # noqa: E402
from repro.core.verify import (FusedBlock, FusionPlan,  # noqa: E402
                               SessionArenaSlice, verify_fusion,
                               verify_interleaving, verify_schedule)
from repro.core.zoo import ZOO  # noqa: E402


def _first(ops, kind):
    for op in ops:
        if isinstance(op, kind):
            return op
    raise AssertionError(
        f"reference schedule has no {kind.__name__} op — pick a config "
        f"that actually swaps")


def _replace_op(ops, old, new):
    return tuple(new if op is old else op for op in ops)


def mutate_shift_offset(ops):
    """Prefetch lands ALIGN*2 bytes away from its packed placement."""
    p = _first(ops, Prefetch)
    return _replace_op(ops, p, dataclasses.replace(
        p, device_offset=p.device_offset + 2 * ALIGN))


def mutate_drop_prefetch(ops):
    """The swap-out stays; the prefetch bringing the bytes back is gone."""
    p = _first(ops, Prefetch)
    return tuple(op for op in ops if op is not p)


def mutate_reorder_swap_out(ops):
    """The swap-out is delayed past its own prefetch's issue phase."""
    p = _first(ops, Prefetch)
    out = next(o for o in ops
               if type(o).__name__ == "SwapOut" and o.tensor == p.tensor)
    return _replace_op(ops, out, dataclasses.replace(out, eo=p.eo + 1))


def mutate_double_free(ops):
    """One Free op replayed twice — the second frees dead bytes."""
    f = _first(ops, Free)
    return tuple(ops) + (f,)


def mutate_truncate_free(ops):
    """One Free op dropped — its arena bytes are never released."""
    f = _first(ops, Free)
    return tuple(op for op in ops if op is not f)


def mutate_budget_overflow(arena_bytes):
    def apply(ops):
        """Prefetch target past the packed arena peak (still aligned)."""
        p = _first(ops, Prefetch)
        beyond = (arena_bytes // ALIGN + 1) * ALIGN
        return _replace_op(ops, p,
                           dataclasses.replace(p, device_offset=beyond))
    return apply


def mutate_misalign(ops):
    """Prefetch offset knocked off the ALIGN grid."""
    p = _first(ops, Prefetch)
    return _replace_op(ops, p, dataclasses.replace(
        p, device_offset=p.device_offset + 3))


def mutate_opt_offset(ops):
    """OptPrefetch working buffer lands off its packed opt-arena slot.

    The optimizer slots pack into their *own* device region, so the
    activation-arena checkers (arena_alias walks ``X:`` placements) are
    structurally blind to this — only ``check_optim_region``'s
    op<->opt-placement comparison can fire."""
    p = _first(ops, OptPrefetch)
    return _replace_op(ops, p, dataclasses.replace(
        p, device_offset=p.device_offset + 2 * ALIGN))


def mutate_hoist_compute(ops):
    """A Compute hoisted before the Prefetch feeding it.

    Phase metadata is untouched — every eo/offset/nbytes field still
    reads like the clean schedule — only the op's *position* moves, so
    the residency checkers (which walk metadata) stay silent and the
    dependence prover's fence edge (Prefetch -> Compute at its read
    phase) is the one that must fire."""
    p = _first(ops, Prefetch)
    pi = ops.index(p)
    c = next(o for o in ops if isinstance(o, Compute) and o.eo == p.read_eo)
    rest = [o for o in ops if o is not c]
    rest.insert(pi, c)          # lands just before the Prefetch feeding it
    return tuple(rest)


def mutate_drop_dep_edge(ops):
    """A SwapOut permuted to the list front, ahead of its producing
    Compute — a dependence-edge-dropping permutation (same op multiset,
    one data edge inverted)."""
    out = _first(ops, SwapOut)
    return (out,) + tuple(o for o in ops if o is not out)


def forge_illegal_fusion(cp) -> FusionPlan:
    """A forged FusedBlock spanning a SwapOut of one of its inputs.

    ``plan_fusion`` would never emit this — blocks split at every
    transfer — so it exercises :func:`verify_fusion`'s independent
    re-proof: the SwapOut inside the block span must be flagged as
    ``fusion_fence``."""
    ops = cp.lowered.ops
    si = ops.index(_first(ops, SwapOut))
    before = max(i for i in range(si) if isinstance(ops[i], Compute))
    after = min(i for i in range(si + 1, len(ops))
                if isinstance(ops[i], Compute))
    block = FusedBlock(index=0, op_indices=(before, si, after),
                       compute_indices=(before, after), free_indices=())
    return FusionPlan(blocks=(block,), n_ops=len(ops),
                      n_computes=sum(isinstance(o, Compute) for o in ops),
                      fence_splits=0, hazard_splits=0, inplace_splits=0,
                      peak_splits=0)


def reference_plan(model: str = "lenet5"):
    """A known-good compiled plan with real data-moving swaps."""
    cp = compile_plan(
        ZOO[model](),
        MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                         min_idle_phases=3, min_bytes=1 << 12,
                         cooptimize=False, optim_offload=True),
        batch=8)
    assert cp.lowered.transfers(), "reference plan must move data"
    assert any(isinstance(op, OptPrefetch) for op in cp.lowered.ops), \
        "reference plan must carry optimizer-offload ops"
    return cp


def mutations(cp):
    """mutation class -> (expected check id, op-list transform)."""
    return {
        "shift_offset": ("arena_alias", mutate_shift_offset),
        "drop_prefetch": ("use_before_resident", mutate_drop_prefetch),
        "reorder_swap_out": ("transfer_race", mutate_reorder_swap_out),
        "double_free": ("double_free", mutate_double_free),
        "truncate_free": ("leak", mutate_truncate_free),
        "budget_overflow": ("budget",
                            mutate_budget_overflow(cp.plan.arena_bytes)),
        "misalign": ("alignment", mutate_misalign),
        "corrupt_opt_offset": ("optim_region", mutate_opt_offset),
        "hoist_compute": ("dep_transfer_fence", mutate_hoist_compute),
        "drop_dep_edge": ("dep_edge", mutate_drop_dep_edge),
    }


# Fusion-plan corruption classes: judged by verify_fusion, not
# verify_schedule — forge() does not apply (there is no op list to forge).
FUSION_MUTATIONS = {
    "fuse_across_swap": ("fusion_fence", forge_illegal_fusion),
}


def forge_overlapping_shares(cp):
    """Two sessions' arena shares overlapping — the admission bug the
    phase-interleaved scheduler would otherwise silently trust.

    Each forged session's *own* plan is the clean reference plan (every
    per-schedule checker passes), and each peak fits its share — only the
    partition is corrupt: session b's base offset starts inside session
    a's share, so a's swap traffic would land in b's live arena bytes.
    ``verify_interleaving`` must flag the pair (``cross_session_arena``)."""
    share = cp.peak_bytes + cp.optim_device_bytes
    return [
        SessionArenaSlice(session="a", qos="standard", base_offset=0,
                          share_bytes=share, peak_bytes=cp.peak_bytes),
        SessionArenaSlice(session="b", qos="standard",
                          base_offset=share // 2,   # inside a's share
                          share_bytes=share, peak_bytes=cp.peak_bytes),
    ]


# Cross-session corruption classes: judged by verify_interleaving over
# forged per-session arena slices — there is no single op list to forge.
INTERLEAVE_MUTATIONS = {
    "overlap_arena_shares": ("cross_session_arena", forge_overlapping_shares),
}


def forge(cp, name: str) -> ExecutionSchedule:
    """Apply one named corruption to ``cp``'s lowered op list."""
    _, fn = mutations(cp)[name]
    return ExecutionSchedule(ops=fn(cp.lowered.ops))


def main() -> int:
    cp = reference_plan()
    clean = verify_schedule(cp.ordered, cp.schedule, cp.plan, cp.lowered)
    if not clean.ok:
        print("FAIL reference plan is not clean:")
        for d in clean.errors():
            print(" ", d.render())
        return 1
    print(f"reference plan clean: {clean.ops_scanned} ops, "
          f"{len(clean.checks_run)} checks")

    missed = 0
    for name, (expected, _) in mutations(cp).items():
        report = verify_schedule(cp.ordered, cp.schedule, cp.plan,
                                 forge(cp, name))
        got = sorted(report.check_ids())
        caught = expected in got and not report.ok
        status = "caught" if caught else "MISSED"
        print(f"{status:>7} {name}: expected={expected} got={got} "
              f"({len(report.errors())} error(s))")
        if not caught:
            missed += 1
    for name, (expected, forge_fn) in FUSION_MUTATIONS.items():
        diags = verify_fusion(forge_fn(cp), cp.lowered, cp.ordered, cp.plan)
        got = sorted({d.check for d in diags})
        caught = expected in got and any(
            d.severity == "error" for d in diags)
        status = "caught" if caught else "MISSED"
        print(f"{status:>7} {name}: expected={expected} got={got} "
              f"({len(diags)} diagnostic(s))")
        if not caught:
            missed += 1
    for name, (expected, forge_fn) in INTERLEAVE_MUTATIONS.items():
        report = verify_interleaving(forge_fn(cp))
        got = sorted(report.check_ids())
        caught = expected in got and not report.ok
        status = "caught" if caught else "MISSED"
        print(f"{status:>7} {name}: expected={expected} got={got} "
              f"({len(report.errors())} error(s))")
        if not caught:
            missed += 1
    if missed:
        print(f"FAIL {missed} corruption class(es) escaped the verifier")
        return 1
    print("all corruption classes caught with the expected check id")
    return 0


if __name__ == "__main__":
    sys.exit(main())
