#!/usr/bin/env python
"""AST lint for repo invariants ruff cannot express.

Two rules, both over every ``.py`` file under ``src/``:

admission
    No src/ code path may call an executor backend's ``run`` or
    ``start`` entry (recognised as ``<anything>.run(..., schedule=...)``
    / ``<anything>.start(..., schedule=...)`` — the ``ExecutorBackend``
    signatures) outside the admitted call sites (``repro.core.plan``
    routing through ``_apply_verify``, ``repro.core.exec.backends``
    itself, whose ``run``/``start`` perform the verify admission, and
    ``repro.serve.scheduler``, whose cursors come only from the
    admission-gated ``start`` and which re-asserts ``is_verified`` per
    cursor).  A new call site would bypass the static verifier:
    schedules must be proven before they reach a device stream.  The
    admitted modules are additionally required to still contain the
    ``is_verified`` admission tripwire, so deleting the admission block
    fails the lint rather than silently unguarding every call site.

deprecated-import
    No src/ module may import the deprecated ``repro.core``
    package-level re-exports (the ``_DEPRECATED`` table in
    ``repro/core/__init__.py`` — read from its AST, so the rule tracks
    the table) or anything from the ``repro.core.planned_exec``
    compatibility shim.  The shims exist for *external* callers; code
    inside src/ must import from the real modules.

Run as a script: prints one ``path:line: [rule] message`` per finding
and exits non-zero on any.  Wired into ``tools/ci.sh`` beside ruff.
"""

import ast
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
CORE_INIT = SRC / "repro" / "core" / "__init__.py"
SHIM_MODULE = "repro.core.planned_exec"

# modules whose backend-run/start call sites are admission-checked
# (relative to src/) -> the admission token each must still contain:
# backends.py gates run()/start() on is_verified; plan.py marks
# schedules verified through _apply_verify before any run; the
# interleaving scheduler re-asserts is_verified on every cursor it opens
RUN_ALLOWLIST = {
    "repro/core/plan.py": "mark_verified",
    "repro/core/exec/backends.py": "is_verified",
    "repro/serve/scheduler.py": "is_verified",
}
# modules allowed to mention the shim / deprecated table (the shims
# themselves and the package __init__ that hosts the table)
SHIM_ALLOWLIST = {
    "repro/core/__init__.py",
    "repro/core/planned_exec.py",
}


def deprecated_names() -> set:
    """Keys of repro.core._DEPRECATED, read from the AST (no import)."""
    tree = ast.parse(CORE_INIT.read_text(), filename=str(CORE_INIT))
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "_DEPRECATED" \
                        and isinstance(node.value, ast.Dict):
                    return {k.value for k in node.value.keys
                            if isinstance(k, ast.Constant)}
    raise AssertionError(f"_DEPRECATED table not found in {CORE_INIT}")


def lint_file(path: Path, rel: str, deprecated: set) -> list:
    findings = []
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        # ---- admission: <expr>.run/.start(..., schedule=...) ----------
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("run", "start") \
                and any(kw.arg == "schedule" for kw in node.keywords):
            if rel not in RUN_ALLOWLIST:
                findings.append((
                    node.lineno, "admission",
                    f"backend .{node.func.attr}(schedule=...) outside the "
                    "admitted call sites — route through "
                    "compile_plan(...).loss_and_grads or the StepScheduler"
                    " so the schedule passes verify admission"))
        # ---- deprecated-import ----------------------------------------
        if isinstance(node, ast.ImportFrom) and rel not in SHIM_ALLOWLIST:
            mod = node.module or ""
            if mod == SHIM_MODULE:
                findings.append((
                    node.lineno, "deprecated-import",
                    f"import from the {SHIM_MODULE} shim — import from "
                    f"repro.core.exec instead"))
            elif mod == "repro.core":
                bad = sorted({a.name for a in node.names} & deprecated)
                if bad:
                    findings.append((
                        node.lineno, "deprecated-import",
                        f"deprecated repro.core re-export(s) "
                        f"{', '.join(bad)} — import from the real module "
                        f"(see repro.core._DEPRECATED)"))
        if isinstance(node, ast.Import) and rel not in SHIM_ALLOWLIST:
            for a in node.names:
                if a.name == SHIM_MODULE:
                    findings.append((
                        node.lineno, "deprecated-import",
                        f"import of the {SHIM_MODULE} shim — import from "
                        f"repro.core.exec instead"))
    return findings


def main() -> int:
    deprecated = deprecated_names()
    n = 0
    files = sorted(SRC.rglob("*.py"))
    for path in files:
        rel = path.relative_to(SRC).as_posix()
        for lineno, rule, msg in lint_file(path, rel, deprecated):
            print(f"{path.relative_to(SRC.parent)}:{lineno}: [{rule}] {msg}")
            n += 1
    # tripwire: the admitted modules must still perform admission
    for rel, token in sorted(RUN_ALLOWLIST.items()):
        text = (SRC / rel).read_text()
        if token not in text:
            print(f"src/{rel}:1: [admission] admitted module lost its "
                  f"{token} admission check")
            n += 1
    if n:
        print(f"FAIL {n} invariant violation(s)")
        return 1
    print(f"invariant lint clean: {len(files)} files, "
          f"{len(deprecated)} deprecated names tracked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
