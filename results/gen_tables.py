"""Generate markdown tables for EXPERIMENTS.md from results/ artifacts."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
RES = Path(__file__).resolve().parent


def dryrun_table(mesh: str) -> str:
    rows = []
    for p in sorted((RES / "dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — |")
            continue
        mem = r["memory_analysis"]
        peak = mem.get("peak_memory_in_bytes", mem.get("temp_size_in_bytes", 0))
        args = mem.get("argument_size_in_bytes", 0)
        coll = r["collectives"]["per_op"]
        csum = ", ".join(f"{k}:{v['count']}" for k, v in coll.items()
                         if v["count"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK | {peak/2**30:.2f} | "
            f"{args/2**30:.2f} | {r['timing']['compile_s']:.0f}s | {csum} |")
    hdr = ("| arch | shape | status | peak GiB/dev | args GiB/dev | compile |"
           " collectives (count) |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    from repro.launch.roofline import load_all
    rows = []
    for r in load_all():
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_compute_ratio']:.1%} | "
            f"{r['roofline_fraction']:.2%} | "
            f"{r['peak_bytes_per_dev']/2**30:.2f} |")
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant |"
           " useful | roofline | peak GiB |\n|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_table() -> str:
    rows = []
    for p in sorted((RES / "perf").glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] != "ok":
            rows.append(f"| {r['variant']} | ERROR | | | | | |")
            continue
        rows.append(
            f"| {r['variant']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_compute_ratio']:.1%} | "
            f"{r['roofline_fraction']:.2%} |")
    hdr = ("| variant | t_comp (s) | t_mem (s) | t_coll (s) | dominant |"
           " useful | roofline |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod (16x16)\n")
        print(dryrun_table("pod"))
        print("\n### multi-pod (2x16x16)\n")
        print(dryrun_table("multipod"))
    if which in ("all", "roofline"):
        print("\n## Roofline\n")
        print(roofline_table())
    if which in ("all", "perf"):
        print("\n## Perf\n")
        print(perf_table())
