import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
import json, sys
sys.path.insert(0, "/root/repo/src")
from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import make_production_mesh, DCN_BW, ICI_BW
from repro.launch.probe import run_probe
from repro.launch.dryrun import TRAIN_MICROBATCHES

out = {}
for arch in sys.argv[1:]:
    cfg = ARCHS[arch]
    shape = SHAPES["train_4k"]
    mb = TRAIN_MICROBATCHES.get(arch, 1)
    p_single = run_probe(cfg, shape, make_production_mesh(multi_pod=False),
                         microbatches=mb)
    p_multi = run_probe(cfg, shape, make_production_mesh(multi_pod=True),
                        microbatches=mb)
    pod_traffic = max(p_multi["collective_bytes"] - p_single["collective_bytes"], 0)
    out[arch] = {
        "coll_singlepod": p_single["collective_bytes"],
        "coll_multipod": p_multi["collective_bytes"],
        "pod_axis_bytes": pod_traffic,
        "t_dcn_s": pod_traffic / DCN_BW,
        "t_dcn_ef_int8_s": pod_traffic / 4.0 / DCN_BW,
        "t_ici_s": p_single["collective_bytes"] / ICI_BW,
    }
    print(arch, json.dumps(out[arch], indent=1), flush=True)
json.dump(out, open("/root/repo/results/multipod_dcn.json", "w"), indent=2)
