"""XLA-level validation of the paper's memory claims: the in-place
(derivative-from-output) activations and planner-driven remat must change
XLA's OWN buffer assignment, not just our analytical model.

Uses ``compiled.memory_analysis().temp_size_in_bytes`` — the real
post-buffer-assignment peak of temporaries — on a deep tower where
activation residuals dominate.
"""

import jax
import jax.numpy as jnp

from repro.core import inplace


def _tower_loss(act_fn, n_layers=12, d=256, batch=32):
    """Deep elementwise tower: residuals dominate the backward memory."""
    def loss(ws, x):
        h = x
        for i in range(n_layers):
            h = act_fn(h @ ws[i])
        return jnp.sum(h * h)
    return loss


def _temp_bytes(fn, *args) -> int:
    compiled = jax.jit(fn).lower(*args).compile()
    ma = compiled.memory_analysis()
    return int(ma.temp_size_in_bytes)


def _input_residual_sigmoid():
    """The paper's 'conventional' strawman: VJP residual = the INPUT."""
    @jax.custom_vjp
    def act(x):
        return jax.nn.sigmoid(x)

    def fwd(x):
        return jax.nn.sigmoid(x), x            # keeps x alive

    def bwd(x, dy):
        y = jax.nn.sigmoid(x)                  # recompute y from x
        return (dy * y * (1 - y),)

    act.defvjp(fwd, bwd)
    return act


def test_output_residual_never_worse_than_input_residual():
    """The paper's in-place mechanism at the XLA level.

    Empirical finding (documented in EXPERIMENTS.md): XLA's CSE + buffer
    assignment already neutralise the input- vs output-residual
    distinction on this tower — it CSEs the backward's recomputed
    ``sigmoid(x)`` with the forward value and schedules the frees
    identically.  In other words, the paper's §3 observation ("such
    techniques can improve conventional mechanisms including TensorFlow
    and PyTorch") has since been absorbed by the XLA stack; our
    output-residual activations are guaranteed never to do worse, and the
    analytical planner remains the tool that PREDICTS the peak (XLA does
    not expose one before compilation)."""
    n, d, b = 12, 256, 32
    ws = jnp.stack([jnp.eye(d) * 0.5 for _ in range(n)])
    x = jnp.ones((b, d))

    t_in = _temp_bytes(jax.grad(_tower_loss(_input_residual_sigmoid(),
                                            n, d, b)), ws, x)
    t_out = _temp_bytes(jax.grad(_tower_loss(inplace.sigmoid, n, d, b)),
                        ws, x)
    assert t_out <= t_in, (t_out, t_in)


def test_inplace_parity_with_jax_default():
    """JAX's stock sigmoid already uses the output-form derivative — our
    in-place version matches its XLA temp footprint exactly."""
    n, d, b = 12, 256, 32
    ws = jnp.stack([jnp.eye(d) * 0.5 for _ in range(n)])
    x = jnp.ones((b, d))
    t_std = _temp_bytes(jax.grad(_tower_loss(jax.nn.sigmoid, n, d, b)),
                        ws, x)
    t_inp = _temp_bytes(jax.grad(_tower_loss(inplace.sigmoid, n, d, b)),
                        ws, x)
    assert t_inp <= t_std


def test_remat_policy_trades_memory_for_flops():
    """nothing_saveable remat must cut XLA temp bytes vs save-everything."""
    n, d, b = 8, 512, 64
    ws = jnp.stack([jnp.eye(d) for _ in range(n)])
    x = jnp.ones((b, d))

    def body(h, w):
        return jnp.tanh(h @ w), None

    def loss_plain(ws, x):
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h * h)

    def loss_remat(ws, x):
        rb = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(rb, x, ws)
        return jnp.sum(h * h)

    t_plain = _temp_bytes(jax.grad(loss_plain), ws, x)
    t_remat = _temp_bytes(jax.grad(loss_remat), ws, x)
    assert t_remat < t_plain, (t_remat, t_plain)


def test_donation_enables_in_place_update():
    """Donated params make the SGD update alias its input (arena reuse)."""
    d = 1024
    w = jnp.ones((d, d))

    def step(w, g):
        return w - 0.1 * g

    lowered = jax.jit(step, donate_argnums=(0,)).lower(w, w)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    # with donation the output aliases the input: temp stays far below
    # one full parameter copy
    assert int(ma.temp_size_in_bytes) < d * d * 4 // 2
