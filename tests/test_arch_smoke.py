"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + one decode step on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.model import build_model, input_specs, reduce_config
from repro.models.transformer import padded_vocab

ARCH_IDS = list(ARCHS)


def _small_batch(cfg, batch=2, seq=16, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    b = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
        "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        b["enc_frames"] = jax.random.normal(
            k3, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            k3, (batch, cfg.image_tokens, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _small_batch(cfg)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 16, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _small_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()
    # at least one non-zero gradient
    total = sum(float(jnp.sum(jnp.abs(l)))
                for l in jax.tree_util.tree_leaves(grads))
    assert total > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    if model.decode_fn is None:
        pytest.skip("no decode path")
    params = model.init(jax.random.PRNGKey(0))
    batch_size, max_seq = 2, 32
    state = model.decode_init(batch_size, max_seq)
    tokens = jnp.array([1, 2], jnp.int32)
    cache_len = jnp.array([5, 9], jnp.int32)
    logits, new_state = jax.jit(model.decode_fn)(params, state, tokens,
                                                 cache_len)
    assert logits.shape == (batch_size, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    # state structure preserved
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(new_state))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_greedy_decode_consistent_with_forward(arch):
    """Prefill logits at position t == decode logits after consuming t tokens
    (for architectures with exact cache/state semantics)."""
    # fp32: bf16 rounding drift across layers/steps exceeds the tolerance
    # even for identical math (whole-seq vs per-token matmul accumulation)
    cfg = reduce_config(ARCHS[arch], dtype="float32")
    if cfg.is_moe:
        pytest.skip("capacity-dropped tokens make MoE decode/prefill differ")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    seq = 8
    batch = _small_batch(cfg, batch=1, seq=seq)
    if cfg.family in ("audio", "vlm"):
        pytest.skip("cross-attn caches are decode-session initialised")
    full_logits = model.forward(params, batch)            # (1, seq, V)

    state = model.decode_init(1, 16)
    for t in range(seq):
        tok = batch["tokens"][:, t]
        logits, state = model.decode_fn(params, state, tok,
                                        jnp.array([t], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits[0], np.float32),
        np.asarray(full_logits[0, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_param_specs_match_param_tree():
    """Every param leaf has a logical-axis spec of matching rank."""
    for arch in ARCH_IDS:
        cfg = reduce_config(ARCHS[arch])
        model = build_model(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        specs = model.param_specs()
        flat_p = jax.tree_util.tree_leaves_with_path(params)
        flat_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda v: isinstance(v, tuple))
        assert len(flat_p) == len(flat_s), (
            f"{arch}: {len(flat_p)} params vs {len(flat_s)} specs")
        sdict = {jax.tree_util.keystr(kp): v.shape for kp, v in flat_p}
        for (kp, leaf), spec in zip(flat_p, flat_s):
            assert len(spec) == len(leaf.shape), (
                f"{arch} {jax.tree_util.keystr(kp)}: spec {spec} vs "
                f"shape {leaf.shape}")


def test_input_specs_abstract():
    from repro.configs import SHAPES, shape_applicable
    for arch in ARCH_IDS:
        cfg = ARCHS[arch]
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            for v in jax.tree_util.tree_leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)
