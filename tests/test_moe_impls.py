"""MoE dispatch implementations: GShard one-hot einsum vs gather routing
must agree exactly (both are §Perf cell-A variants)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                n_kv_heads=4, d_ff=64, moe_d_ff=64, vocab=64, n_experts=8,
                top_k=2, dtype="float32", capacity_factor=2.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("e,k,cf", [(8, 2, 2.0), (4, 1, 1.5), (16, 4, 1.25)])
def test_gather_matches_einsum_forward(e, k, cf):
    cfg = _cfg(n_experts=e, top_k=k, capacity_factor=cf)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out_e, aux_e = moe.moe_forward(cfg, p, x)
    out_g, aux_g = moe.moe_forward(
        dataclasses.replace(cfg, moe_impl="gather"), p, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-6)


def test_gather_matches_einsum_grads():
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p, impl):
        c = dataclasses.replace(cfg, moe_impl=impl)
        o, a = moe.moe_forward(c, p, x)
        return jnp.sum(o ** 2) + a

    ge = jax.grad(loss)(p, "einsum")
    gg = jax.grad(loss)(p, "gather")
    for a, b in zip(jax.tree_util.tree_leaves(ge),
                    jax.tree_util.tree_leaves(gg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_capacity_drop_consistent():
    """With a tight capacity both impls drop the SAME tokens."""
    cfg = _cfg(capacity_factor=0.5)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32))
    out_e, _ = moe.moe_forward(cfg, p, x)
    out_g, _ = moe.moe_forward(
        dataclasses.replace(cfg, moe_impl="gather"), p, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=1e-5, atol=1e-6)


def test_long_sequence_regrouping():
    """Sequences longer than MAX_GROUP are split into dispatch sub-groups."""
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    old = moe.MAX_GROUP
    try:
        moe.MAX_GROUP = 8
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32))
        out, aux = moe.moe_forward(cfg, p, x)
        assert out.shape == (2, 32, 32)
        # regrouping == explicitly reshaping into (B*4, 8, d) sub-sequences
        # (capacity is per-group, so this is the exact semantic)
        out2, _ = moe.moe_forward(cfg, p, x.reshape(8, 8, 32))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(out2.reshape(2, 32, 32)),
                                   rtol=1e-5, atol=1e-6)
    finally:
        moe.MAX_GROUP = old


def test_skip_paths_preserve_shapes():
    """Probe skip modes keep output shapes (attention/mixer/mlp)."""
    from repro.configs import ARCHS
    from repro.models.model import build_model, reduce_config
    for arch, field in (("llama3.2-3b", {"attention_impl": "skip"}),
                        ("llama3.2-3b", {"mlp_skip": True}),
                        ("xlstm-1.3b", {"mixer_skip": True}),
                        ("zamba2-7b", {"mixer_skip": True})):
        cfg = reduce_config(ARCHS[arch], **field)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
                 "targets": jnp.zeros((2, 8), jnp.int32)}
        logits = model.forward(params, batch)
        assert logits.shape[0:2] == (2, 8)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
