"""The unified MemoryPlan compile API: ``compile_plan`` from graph (or model
config) to executor, including the schedule/planner co-optimisation fixed
point that ships as a behaviour of the facade.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.offload import make_schedule
from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planned_exec import reference_loss_and_grads
from repro.core.planner import plan_memory_swapped
from repro.core.zoo import ZOO

PLAN_CFG = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)


def _shrink(graph):
    for l in graph.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = 96
    if graph.input_shape == (150528,):
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


# ---------------------------------------------------------------------------
# Every zoo model compiles through the facade
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_every_zoo_model_compiles(name):
    cp = compile_plan(ZOO[name](), PLAN_CFG, batch=8)
    assert cp.source == "graph"
    cp.plan.validate()
    # acceptance: peak never above the no-swap sorting planner
    assert cp.peak_bytes <= cp.baseline.arena_bytes
    # co-optimisation never raises the peak above the single pass
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes
    assert cp.dma_bytes <= cp.coopt.single_pass_dma_bytes
    r = cp.report()
    for key in ("peak_bytes", "baseline_peak_bytes", "dma_bytes",
                "host_pool_bytes", "n_swaps", "coopt_rounds"):
        assert key in r, key


# ---------------------------------------------------------------------------
# Co-optimisation fixed point: terminates with only load-bearing swaps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["vgg16", "resnet18"])
def test_coopt_fixed_point_leaves_no_droppable_swaps(name):
    cp = compile_plan(ZOO[name](), PLAN_CFG, batch=8)
    assert cp.schedule.decisions, "models must keep load-bearing swaps"
    # all scheduled swaps vacate bytes (non-vacating never scheduled)
    assert all(d.vacates for d in cp.schedule.decisions)
    # fixed point: removing ANY remaining data-moving swap raises the
    # packed peak.  In-place decisions are exempt — they move no data, so
    # the co-optimisation keeps them regardless of peak impact.
    for d in cp.schedule.decisions:
        if d.inplace:
            continue
        rest = tuple(o for o in cp.schedule.decisions if o.name != d.name)
        trial = plan_memory_swapped(cp.ordered, make_schedule(rest),
                                    planner=cp.config.planner)
        assert trial.arena_bytes > cp.peak_bytes, d.name


def test_coopt_drops_non_load_bearing_swaps():
    # model_a's swaps reclaim no packed bytes: the fixed point removes them
    # all, at equal peak and zero DMA traffic
    cp = compile_plan(_shrink(ZOO["model_a_linear"]()),
                      MemoryPlanConfig(min_idle_phases=3, min_bytes=1),
                      batch=4)
    assert cp.coopt.dropped
    assert not cp.schedule.decisions
    assert cp.dma_bytes == 0
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes


def test_cooptimize_off_reproduces_single_pass():
    cfg = dataclasses.replace(PLAN_CFG, cooptimize=False)
    cp = compile_plan(ZOO["vgg16"](), cfg, batch=8)
    assert cp.coopt is None
    on = compile_plan(ZOO["vgg16"](), PLAN_CFG, batch=8)
    assert on.coopt.single_pass_peak_bytes == cp.peak_bytes
    assert on.coopt.single_pass_dma_bytes == cp.dma_bytes


# ---------------------------------------------------------------------------
# The compiled executor: grads match jax.grad through the facade
# ---------------------------------------------------------------------------

def _exec_case(g, batch, one_hot=False):
    cp = compile_plan(
        g, MemoryPlanConfig(min_idle_phases=3, min_bytes=1,
                            prefetch_margin=2), batch=batch)
    params = cp.init_params(jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
    if one_hot:
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    loss_s, grads_s, stats = cp.loss_and_grads(params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_s), float(loss_r), rtol=1e-5)
    la = jax.tree_util.tree_leaves(grads_s)
    lb = jax.tree_util.tree_leaves(grads_r)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    return cp, stats


def test_compiled_exec_grads_match_lenet5():
    cp, stats = _exec_case(ZOO["lenet5"](), 4, one_hot=True)
    assert cp.schedule.decisions          # swaps survive co-optimisation
    assert stats.swap_outs == stats.prefetches > 0
    assert stats.late_swap_ins == 0
    assert stats.hbm_high_water <= stats.planned_peak


def test_compiled_exec_grads_match_model_b():
    _exec_case(_shrink(ZOO["model_b_linear"]()), 4)


def test_compiled_exec_grads_match_unrolled_lstm():
    g = ZOO["tacotron2_decoder"](time_steps=4, mel_dim=8, prenet_dim=8,
                                 lstm_dim=8)
    cp, stats = _exec_case(g, 2)
    assert stats.late_swap_ins == 0


def test_worstcase_planner_reports_no_phantom_savings():
    # the no-swap baseline must be packed over the same tensor universe as
    # the swapped re-pack: with every swap dropped, savings must be zero
    # even for WorstCasePlanner (which materialises merged views too)
    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner="worstcase", min_idle_phases=3,
                         min_bytes=1 << 12), batch=8)
    if not cp.swapped_names():
        assert cp.hbm_bytes_saved == 0


def test_graph_plan_has_no_checkpoint_policy():
    # graph plans execute swaps via loss_and_grads; their arena names would
    # match no checkpoint_name tag, so no jax.checkpoint policy is faked
    cp = compile_plan(ZOO["lenet5"](), PLAN_CFG, batch=8)
    assert cp.swapped_names()
    assert cp.offload_policy is None


def test_swap_disabled_is_plain_plan():
    g = ZOO["lenet5"]()
    cp = compile_plan(g, MemoryPlanConfig(swap=False), batch=4)
    assert not cp.schedule.decisions
    assert cp.peak_bytes == cp.baseline.arena_bytes
    assert cp.coopt is None and cp.dma_bytes == 0
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(4) % 10, 10)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.swap_outs == stats.dma_bytes == 0


# ---------------------------------------------------------------------------
# Model-config path: the remat/offload knapsack behind the same facade
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, **kw)


def test_model_config_path_produces_policy():
    cp = compile_plan(_tiny_cfg(remat=True), batch_tokens=1024)
    assert cp.source == "model"
    assert cp.remat_plan is not None
    assert cp.offload_policy is not None
    assert cp.peak_bytes == cp.remat_plan.saved_bytes_per_layer * 2
    assert cp.report()["remat_saved"] == list(cp.remat_plan.saved)


def test_model_config_remat_off_is_empty_plan():
    cp = compile_plan(_tiny_cfg(remat=False), batch_tokens=1024)
    assert cp.remat_plan is None and cp.offload_policy is None
    assert cp.peak_bytes == 0


def test_model_config_knobs_override_cfg():
    cfg = _tiny_cfg(remat=True, offload=False)
    # deprecated alias: free-DMA offload-everything, now with a warning
    with pytest.warns(DeprecationWarning):
        cp = compile_plan(cfg, MemoryPlanConfig(remat_budget_bytes=0,
                                                offload_dropped=True),
                          batch_tokens=1024)
    assert cp.remat_plan.saved == ()
    assert cp.remat_plan.offloaded       # everything streams through host
    assert cp.dma_bytes > 0              # the traffic is no longer hidden
    # the replacement knob: priced offload lane through the same facade
    cp2 = compile_plan(cfg, MemoryPlanConfig(remat_budget_bytes=0,
                                             offload=True),
                       batch_tokens=1024)
    assert set(cp2.remat_plan.dropped) | set(cp2.remat_plan.offloaded) \
        == {"qkv", "attn_out", "mlp_hidden", "mlp_out"}


def test_model_config_requires_batch_tokens():
    with pytest.raises(TypeError):
        compile_plan(_tiny_cfg(remat=True))


def test_graph_executor_unavailable_for_model_config():
    cp = compile_plan(_tiny_cfg(remat=True), batch_tokens=1024)
    with pytest.raises(TypeError):
        cp.loss_and_grads(None, None, None)
    with pytest.raises(TypeError):
        cp.init_params(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# host_planner knob: pluggable host-pool allocator behind the same facade
# ---------------------------------------------------------------------------

def test_unknown_planner_names_raise_clear_valueerror():
    g = ZOO["lenet5"]()
    with pytest.raises(ValueError, match="unknown planner 'firstfit'"):
        compile_plan(g, MemoryPlanConfig(planner="firstfit"), batch=4)
    with pytest.raises(ValueError, match="unknown planner 'slab'"):
        compile_plan(g, MemoryPlanConfig(host_planner="slab"), batch=4)


@pytest.mark.parametrize("name", ["lenet5", "vgg16", "model_d"])
def test_host_planner_default_is_bit_for_bit_sorting(name):
    """The knob's default must reproduce the explicit "sorting" choice
    exactly: same arenas, same placements, same schedule."""
    g1, g2 = ZOO[name](), ZOO[name]()
    cfg = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)
    dflt = compile_plan(g1, cfg, batch=8)
    expl = compile_plan(
        g2, dataclasses.replace(cfg, host_planner="sorting"), batch=8)
    assert dflt.config.host_planner == "sorting"
    assert dflt.peak_bytes == expl.peak_bytes
    assert dflt.host_pool_bytes == expl.host_pool_bytes
    assert dflt.schedule.decisions == expl.schedule.decisions
    assert dflt.lowered.ops == expl.lowered.ops
    for arena in ("device", "host"):
        a = getattr(dflt.plan, arena).placements
        b = getattr(expl.plan, arena).placements
        assert {n: (p.offset, p.nbytes) for n, p in a.items()} \
            == {n: (p.offset, p.nbytes) for n, p in b.items()}


def test_host_planner_sweep_packs_validly():
    g = ZOO["resnet18"]()
    seen = {}
    for hp in ("sorting", "bestfit", "segregated", "buddy"):
        cp = compile_plan(
            g, MemoryPlanConfig(planner="bestfit", host_planner=hp,
                                min_idle_phases=3, min_bytes=1 << 12),
            batch=8)
        cp.plan.validate()
        r = cp.report()
        assert r["host_planner"] == hp
        assert 0.0 < r["host_utilization"] <= 1.0
        assert 0.0 < r["device_utilization"] <= 1.0
        seen[hp] = cp.host_pool_bytes
    # the host workload is the same for every packer; all must cover the
    # peak-live lower bound, none may be wildly fragmented
    assert min(seen.values()) > 0


# ---------------------------------------------------------------------------
# The lowered ExecutionSchedule: typed ops the executor replays verbatim
# ---------------------------------------------------------------------------

def test_lowered_schedule_op_ordering_and_offsets():
    from repro.core.plan import Compute, Free, Prefetch, SwapOut

    cp = compile_plan(ZOO["lenet5"](), PLAN_CFG, batch=8)
    ops = cp.lowered.ops
    rank = {Prefetch: 0, Compute: 1, SwapOut: 2, Free: 3}
    keys = [(op.eo, rank[type(op)]) for op in ops]
    assert keys == sorted(keys), "ops must be sorted by (eo, phase rank)"
    counts = cp.lowered.counts()
    assert counts["compute"] == len(cp.ordered.phase_schedule())
    moving = [d for d in cp.schedule.decisions
              if d.vacates and not d.inplace and d.name.startswith("X:")]
    assert counts.get("swapout", 0) == len(moving)
    assert counts.get("prefetch", 0) == len(moving)
    for op in cp.lowered.transfers():
        assert op.nbytes > 0
        assert op.device_offset >= 0, "compiled plans carry real offsets"
        assert op.host_offset >= 0
        hp = cp.plan.host.placements[op.tensor + "@host"]
        assert op.host_offset == hp.offset
    # Free ops release every planned X: tensor exactly once, at its last
    # access
    frees = {op.tensor: op.eo for op in ops if isinstance(op, Free)}
    for t in cp.ordered.planned_tensors():
        if t.name.startswith("X:"):
            assert frees[t.name] == t.max_eo


def test_executor_replays_compiled_schedule_exactly():
    cp, stats = _exec_case(ZOO["lenet5"](), 4, one_hot=True)
    assert stats.replayed_ops == cp.lowered.ops
    assert stats.late_swap_ins == 0


def test_swap_disabled_lowers_to_compute_and_free_only():
    cp = compile_plan(ZOO["lenet5"](), MemoryPlanConfig(swap=False), batch=4)
    counts = cp.lowered.counts()
    assert set(counts) == {"compute", "free"}
    assert cp.lowered.transfers() == ()


# ---------------------------------------------------------------------------
# Deprecation shims: old entry points still import, with a warning
# ---------------------------------------------------------------------------

def test_deprecated_core_reexports_warn():
    import repro.core as core
    with pytest.warns(DeprecationWarning):
        fn = core.plan_memory
    from repro.core.planner import plan_memory
    assert fn is plan_memory
    with pytest.warns(DeprecationWarning):
        assert core.compute_execution_order is not None


def test_deprecated_shim_covers_every_legacy_name():
    """Every name in the deprecation table resolves (warning included) to
    the real attribute of its home module, and unknown names still raise."""
    import importlib

    import repro.core as core

    for name, (module_name, attr) in core._DEPRECATED.items():
        with pytest.warns(DeprecationWarning, match=name):
            got = getattr(core, name)
        assert got is getattr(importlib.import_module(module_name), attr), name
    with pytest.raises(AttributeError):
        core.definitely_not_a_symbol


def test_new_compile_surface_imports_without_warning(recwarn):
    from repro.core import (PLANNERS, ArenaAllocator, ExecutionSchedule,
                            get_planner, lower_schedule)
    assert {"sorting", "bestfit", "segregated", "buddy",
            "worstcase"} <= set(PLANNERS)
    assert ExecutionSchedule is not None and lower_schedule is not None
    assert isinstance(get_planner("buddy"), ArenaAllocator)
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]
