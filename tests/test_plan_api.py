"""The unified MemoryPlan compile API: ``compile_plan`` from graph (or model
config) to executor, including the schedule/planner co-optimisation fixed
point that ships as a behaviour of the facade.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.offload import make_schedule
from repro.core.plan import (CompiledMemoryPlan, MemoryPlanConfig,
                             compile_plan)
from repro.core.planned_exec import reference_loss_and_grads
from repro.core.planner import plan_memory_swapped
from repro.core.zoo import ZOO

PLAN_CFG = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)


def _shrink(graph):
    for l in graph.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = 96
    if graph.input_shape == (150528,):
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


# ---------------------------------------------------------------------------
# Every zoo model compiles through the facade
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_every_zoo_model_compiles(name):
    cp = compile_plan(ZOO[name](), PLAN_CFG, batch=8)
    assert cp.source == "graph"
    cp.plan.validate()
    # acceptance: peak never above the no-swap sorting planner
    assert cp.peak_bytes <= cp.baseline.arena_bytes
    # co-optimisation never raises the peak above the single pass
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes
    assert cp.dma_bytes <= cp.coopt.single_pass_dma_bytes
    r = cp.report()
    for key in ("peak_bytes", "baseline_peak_bytes", "dma_bytes",
                "host_pool_bytes", "n_swaps", "coopt_rounds"):
        assert key in r, key


# ---------------------------------------------------------------------------
# Co-optimisation fixed point: terminates with only load-bearing swaps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["vgg16", "resnet18"])
def test_coopt_fixed_point_leaves_no_droppable_swaps(name):
    cp = compile_plan(ZOO[name](), PLAN_CFG, batch=8)
    assert cp.schedule.decisions, "models must keep load-bearing swaps"
    # all scheduled swaps vacate bytes (non-vacating never scheduled)
    assert all(d.vacates for d in cp.schedule.decisions)
    # fixed point: removing ANY remaining swap raises the packed peak,
    # i.e. there are zero non-vacating (non-load-bearing) swaps left
    for d in cp.schedule.decisions:
        rest = tuple(o for o in cp.schedule.decisions if o.name != d.name)
        trial = plan_memory_swapped(cp.ordered, make_schedule(rest),
                                    planner=cp.config.planner)
        assert trial.arena_bytes > cp.peak_bytes, d.name


def test_coopt_drops_non_load_bearing_swaps():
    # model_a's swaps reclaim no packed bytes: the fixed point removes them
    # all, at equal peak and zero DMA traffic
    cp = compile_plan(_shrink(ZOO["model_a_linear"]()),
                      MemoryPlanConfig(min_idle_phases=3, min_bytes=1),
                      batch=4)
    assert cp.coopt.dropped
    assert not cp.schedule.decisions
    assert cp.dma_bytes == 0
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes


def test_cooptimize_off_reproduces_single_pass():
    cfg = dataclasses.replace(PLAN_CFG, cooptimize=False)
    cp = compile_plan(ZOO["vgg16"](), cfg, batch=8)
    assert cp.coopt is None
    on = compile_plan(ZOO["vgg16"](), PLAN_CFG, batch=8)
    assert on.coopt.single_pass_peak_bytes == cp.peak_bytes
    assert on.coopt.single_pass_dma_bytes == cp.dma_bytes


# ---------------------------------------------------------------------------
# The compiled executor: grads match jax.grad through the facade
# ---------------------------------------------------------------------------

def _exec_case(g, batch, one_hot=False):
    cp = compile_plan(
        g, MemoryPlanConfig(min_idle_phases=3, min_bytes=1,
                            prefetch_margin=2), batch=batch)
    params = cp.init_params(jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
    if one_hot:
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    loss_s, grads_s, stats = cp.loss_and_grads(params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_s), float(loss_r), rtol=1e-5)
    la = jax.tree_util.tree_leaves(grads_s)
    lb = jax.tree_util.tree_leaves(grads_r)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    return cp, stats


def test_compiled_exec_grads_match_lenet5():
    cp, stats = _exec_case(ZOO["lenet5"](), 4, one_hot=True)
    assert cp.schedule.decisions          # swaps survive co-optimisation
    assert stats.swap_outs == stats.prefetches > 0
    assert stats.late_swap_ins == 0
    assert stats.hbm_high_water <= stats.planned_peak


def test_compiled_exec_grads_match_model_b():
    _exec_case(_shrink(ZOO["model_b_linear"]()), 4)


def test_compiled_exec_grads_match_unrolled_lstm():
    g = ZOO["tacotron2_decoder"](time_steps=4, mel_dim=8, prenet_dim=8,
                                 lstm_dim=8)
    cp, stats = _exec_case(g, 2)
    assert stats.late_swap_ins == 0


def test_worstcase_planner_reports_no_phantom_savings():
    # the no-swap baseline must be packed over the same tensor universe as
    # the swapped re-pack: with every swap dropped, savings must be zero
    # even for WorstCasePlanner (which materialises merged views too)
    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner="worstcase", min_idle_phases=3,
                         min_bytes=1 << 12), batch=8)
    if not cp.swapped_names():
        assert cp.hbm_bytes_saved == 0


def test_graph_plan_has_no_checkpoint_policy():
    # graph plans execute swaps via loss_and_grads; their arena names would
    # match no checkpoint_name tag, so no jax.checkpoint policy is faked
    cp = compile_plan(ZOO["lenet5"](), PLAN_CFG, batch=8)
    assert cp.swapped_names()
    assert cp.offload_policy is None


def test_swap_disabled_is_plain_plan():
    g = ZOO["lenet5"]()
    cp = compile_plan(g, MemoryPlanConfig(swap=False), batch=4)
    assert not cp.schedule.decisions
    assert cp.peak_bytes == cp.baseline.arena_bytes
    assert cp.coopt is None and cp.dma_bytes == 0
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(4) % 10, 10)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.swap_outs == stats.dma_bytes == 0


# ---------------------------------------------------------------------------
# Model-config path: the remat/offload knapsack behind the same facade
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, **kw)


def test_model_config_path_produces_policy():
    cp = compile_plan(_tiny_cfg(remat=True), batch_tokens=1024)
    assert cp.source == "model"
    assert cp.remat_plan is not None
    assert cp.offload_policy is not None
    assert cp.peak_bytes == cp.remat_plan.saved_bytes_per_layer * 2
    assert cp.report()["remat_saved"] == list(cp.remat_plan.saved)


def test_model_config_remat_off_is_empty_plan():
    cp = compile_plan(_tiny_cfg(remat=False), batch_tokens=1024)
    assert cp.remat_plan is None and cp.offload_policy is None
    assert cp.peak_bytes == 0


def test_model_config_knobs_override_cfg():
    cfg = _tiny_cfg(remat=True, offload=False)
    # deprecated alias: free-DMA offload-everything, now with a warning
    with pytest.warns(DeprecationWarning):
        cp = compile_plan(cfg, MemoryPlanConfig(remat_budget_bytes=0,
                                                offload_dropped=True),
                          batch_tokens=1024)
    assert cp.remat_plan.saved == ()
    assert cp.remat_plan.offloaded       # everything streams through host
    assert cp.dma_bytes > 0              # the traffic is no longer hidden
    # the replacement knob: priced offload lane through the same facade
    cp2 = compile_plan(cfg, MemoryPlanConfig(remat_budget_bytes=0,
                                             offload=True),
                       batch_tokens=1024)
    assert set(cp2.remat_plan.dropped) | set(cp2.remat_plan.offloaded) \
        == {"qkv", "attn_out", "mlp_hidden", "mlp_out"}


def test_model_config_requires_batch_tokens():
    with pytest.raises(TypeError):
        compile_plan(_tiny_cfg(remat=True))


def test_graph_executor_unavailable_for_model_config():
    cp = compile_plan(_tiny_cfg(remat=True), batch_tokens=1024)
    with pytest.raises(TypeError):
        cp.loss_and_grads(None, None, None)
    with pytest.raises(TypeError):
        cp.init_params(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Deprecation shims: old entry points still import, with a warning
# ---------------------------------------------------------------------------

def test_deprecated_core_reexports_warn():
    import repro.core as core
    with pytest.warns(DeprecationWarning):
        fn = core.plan_memory
    from repro.core.planner import plan_memory
    assert fn is plan_memory
    with pytest.warns(DeprecationWarning):
        assert core.compute_execution_order is not None
