"""The pluggable executor subsystem (repro.core.exec).

Backend equivalence (the paper's 1e-4 commit gate, per backend): grads
from SimulatedBackend AND AsyncDeviceBackend match whole-graph ``jax.grad``
on every zoo model, both replay the compiled op list verbatim, and the
measured host-pool high water respects the packed bound on both.  Plus:
the ExecutionSchedule edge-case unit tests, the transfer-engine seam, and
the warn-once-per-call-site deprecation shims.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.exec import (AsyncDeviceBackend, SimulatedBackend,
                             SwapExecStats, get_backend)
from repro.core.exec.layers import reference_loss_and_grads
from repro.core.exec.store import DeviceStreamEngine, SyncHostEngine
from repro.core.plan import (Compute, ExecutionSchedule, Free,
                             MemoryPlanConfig, Prefetch, SwapOut,
                             compile_plan, lower_schedule)
from repro.core.zoo import ZOO

EXEC_CFG = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)

# CPU-heavy archs get the slow marker so the quick gate stays quick; the
# full suite still covers every zoo model on both backends.
_HEAVY = {"vgg16", "resnet18"}
ZOO_CASES = [
    pytest.param(name, marks=pytest.mark.slow) if name in _HEAVY
    else name
    for name in sorted(ZOO)
]


def _shrink(graph):
    for l in graph.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = 96
    if graph.input_shape == (150528,):
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


def _batch_for(g, batch=2):
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    if any(l.kind == "embedding" for l in g.layers):
        x = jax.random.randint(kx, (batch,) + tuple(g.input_shape), 0, 50)
    else:
        x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
    if g.layers[-1].kind == "loss_ce":
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    return x, y


def _tree_allclose(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Backend equivalence over the whole zoo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ZOO_CASES)
def test_backends_match_jax_grad_on_zoo(name):
    """Both backends replay the same compiled plan to jax.grad-identical
    grads, verbatim op replay, and in-bound host-pool high water."""
    g = _shrink(ZOO[name]())
    batch = 2
    cp = compile_plan(g, EXEC_CFG, batch=batch)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, batch)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)

    results = {}
    for executor in ("sim", "async"):
        loss, grads, stats = cp.loss_and_grads(params, x, y,
                                               executor=executor)
        np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-5)
        _tree_allclose(grads, grads_r)
        assert stats.backend == executor
        assert stats.replayed_ops == cp.lowered.ops, executor
        assert stats.late_swap_ins == 0
        assert stats.host_high_water <= cp.host_pool_bytes
        if stats.planned_peak is not None:
            assert stats.hbm_high_water <= stats.planned_peak
        results[executor] = stats

    # the two backends executed the same schedule: identical transfer
    # accounting, bit for bit
    sim, asy = results["sim"], results["async"]
    for field in ("swap_outs", "prefetches", "dma_bytes", "hbm_high_water",
                  "host_high_water", "peak_inflight_prefetch"):
        assert getattr(sim, field) == getattr(asy, field), field


def test_async_overlap_report_vs_planned_inflight():
    g = ZOO["lenet5"]()
    cp = compile_plan(g, dataclasses.replace(EXEC_CFG, executor="async"),
                      batch=16)
    assert cp.schedule.decisions, "needs a plan with real transfers"
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 16)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.backend == "async"
    assert stats.fences == stats.prefetches > 0
    assert stats.achieved_overlap is not None
    assert 0.0 <= stats.achieved_overlap <= 1.0
    # the stream never held more in flight than the plan budgeted
    assert 0 < stats.inflight_high_water \
        <= cp.schedule.peak_inflight_prefetch
    ex = cp.report()["exec"]
    assert ex["backend"] == "async"
    assert ex["achieved_overlap"] == stats.achieved_overlap
    assert ex["inflight_high_water"] == stats.inflight_high_water
    assert ex["planned_peak_inflight_prefetch"] \
        == cp.schedule.peak_inflight_prefetch
    assert ex["inflight_vs_planned"] <= 1.0


def test_sim_backend_stats_bit_for_bit_default():
    """The default path is the simulated backend and its stats carry the
    defaulted async fields — old consumers see unchanged values."""
    g = ZOO["lenet5"]()
    cp = compile_plan(g, EXEC_CFG, batch=8)
    assert cp.config.executor == "sim"
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 8)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.backend == "sim"
    assert stats.inflight_high_water == 0
    assert stats.fences == stats.stalled_fences == 0
    assert stats.achieved_overlap is None
    assert cp.report()["exec"]["backend"] == "sim"


# ---------------------------------------------------------------------------
# Backend registry / selection plumbing
# ---------------------------------------------------------------------------

def test_get_backend_registry_and_errors():
    assert get_backend(None).name == "sim"
    assert isinstance(get_backend("sim"), SimulatedBackend)
    assert isinstance(get_backend("async"), AsyncDeviceBackend)
    custom = AsyncDeviceBackend()
    assert get_backend(custom) is custom
    with pytest.raises(ValueError, match="unknown executor backend"):
        get_backend("cuda-graphs")
    with pytest.raises(TypeError, match="ExecutorBackend"):
        get_backend(42)


def test_unknown_executor_fails_at_compile_time():
    with pytest.raises(ValueError, match="unknown executor backend"):
        compile_plan(ZOO["lenet5"](),
                     MemoryPlanConfig(executor="asycn"), batch=4)


def test_backend_report_requires_a_run():
    with pytest.raises(RuntimeError, match="run"):
        SimulatedBackend().report()


def test_engines_expose_the_transfer_seam():
    """The store's engine seam: sync engine moves bytes immediately, the
    device-stream engine tracks in-flight transfers until fenced."""
    sync = SyncHostEngine()
    a = jnp.arange(16.0)
    host = sync.swap_out("X:t", {"t": a}, a.nbytes)
    assert isinstance(host["t"], np.ndarray)
    back = sync.swap_in("X:t", host, a.nbytes)
    np.testing.assert_array_equal(np.asarray(back["t"]), np.asarray(a))

    eng = DeviceStreamEngine()
    stats = SwapExecStats()
    h = eng.swap_out("X:t", {"t": jnp.arange(16.0)}, 64)
    dev = eng.swap_in("X:t", h, 64)
    assert eng.inflight_bytes == 64
    assert eng.inflight_high_water == 64
    eng.fence("X:t", stats)
    assert eng.inflight_bytes == 0
    assert eng.fences == 1
    eng.fence("X:t", stats)       # double fence is a no-op
    assert eng.fences == 1
    np.testing.assert_array_equal(np.asarray(dev["t"]), np.arange(16.0))


# ---------------------------------------------------------------------------
# ExecutionSchedule.counts()/transfers() edge cases (direct unit tests)
# ---------------------------------------------------------------------------

def test_empty_schedule_counts_and_transfers():
    empty = ExecutionSchedule(ops=())
    assert empty.counts() == {}
    assert empty.transfers() == ()


def test_counts_and_transfers_on_handmade_ops():
    ops = (
        Prefetch(eo=2, tensor="X:a", nbytes=8, device_offset=0,
                 host_offset=0, read_eo=3),
        Compute(eo=2, layer="a", kind="F"),
        SwapOut(eo=2, tensor="X:b", nbytes=16, device_offset=8,
                host_offset=8),
        Free(eo=4, tensor="X:a", nbytes=8, device_offset=0),
    )
    sched = ExecutionSchedule(ops=ops)
    assert sched.counts() == {"prefetch": 1, "compute": 1, "swapout": 1,
                              "free": 1}
    # transfers: DMA ops only, in issue order
    assert sched.transfers() == (ops[0], ops[2])


def test_zero_swap_plan_lowers_to_no_transfers():
    # min_bytes too large for anything to qualify: compute + free only
    cp = compile_plan(ZOO["lenet5"](),
                      MemoryPlanConfig(min_bytes=1 << 40), batch=4)
    assert not cp.schedule.decisions
    assert cp.lowered.transfers() == ()
    counts = cp.lowered.counts()
    assert set(counts) == {"compute", "free"}
    assert counts["compute"] == len(cp.ordered.phase_schedule())


def test_inplace_prefetch_only_plan_lowers_to_no_transfers():
    """A schedule whose every decision is an in-place prefetch moves no
    bytes: transfers() is empty though decisions exist."""
    from repro.core.execution_order import compute_execution_order
    from repro.core.offload import OffloadDecision, make_schedule

    g = ZOO["lenet5"]()
    ordered = compute_execution_order(g, 4)
    name = next(t.name for t in ordered.planned_tensors()
                if t.name.startswith("X:") and len(t.exec_orders) >= 2)
    t = ordered.tensors[name]
    write, read = t.largest_gap()
    d = OffloadDecision(name=name, nbytes=t.nbytes, write_eo=write,
                        read_eo=read, prefetch_at_eo=read - 1, inplace=True)
    sched = make_schedule((d,))
    assert sched.decisions and all(x.inplace for x in sched.decisions)
    assert sched.dma_bytes == 0 and sched.hbm_bytes_saved == 0
    lowered = lower_schedule(ordered, sched)
    assert lowered.transfers() == ()
    assert set(lowered.counts()) == {"compute", "free"}


# ---------------------------------------------------------------------------
# Deprecation shims: warn once per call site, still assertable
# ---------------------------------------------------------------------------

def test_warn_once_dedupes_per_call_site_under_default_filters():
    from repro.core import deprecation

    deprecation.reset_seen_call_sites()
    try:
        with warnings.catch_warnings(record=True) as rec:
            # "default" action: our helper's dedup is in charge
            warnings.simplefilter("default")
            for _ in range(3):
                deprecation.warn_once("shim is deprecated (dedup test)")
        assert len(rec) == 1
        assert issubclass(rec[0].category, DeprecationWarning)
    finally:
        deprecation.reset_seen_call_sites()


def test_warn_once_stays_alive_under_pytest_warns():
    from repro.core import deprecation

    # pytest.warns installs an "always" filter: every invocation must warn,
    # even from one call site, so warning assertions (and parametrized
    # re-runs of the same site) keep working
    for _ in range(2):
        with pytest.warns(DeprecationWarning, match="alive test"):
            deprecation.warn_once("shim is deprecated (alive test)")


def test_step_bundle_remat_plan_shim_warns():
    from repro.core.remat_policy import RematPlan
    from repro.train.step import StepBundle

    bundle = StepBundle(fn=None, in_shardings=None, out_shardings=None,
                        donate_argnums=(), abstract_args=(), act_rules={},
                        mesh=None, memory_plan=None)
    with pytest.warns(DeprecationWarning, match="StepBundle.remat_plan"):
        assert bundle.remat_plan is None
    assert RematPlan is not None


def test_offload_dropped_shim_still_warns():
    from repro.configs import ARCHS

    with pytest.warns(DeprecationWarning, match="offload_dropped"):
        cp = compile_plan(
            ARCHS["llama3.2-3b"],
            MemoryPlanConfig(remat=True, remat_budget_bytes=1 << 20,
                             offload_dropped=True),
            batch_tokens=1024)
    assert cp.remat_plan is not None


def test_core_free_function_shim_still_warns():
    import repro.core as core

    with pytest.warns(DeprecationWarning, match="plan_offload"):
        assert core.plan_offload is not None
