"""Planner-managed optimizer-state offload: slot tagging, the packed
opt arenas, lowering to OptPrefetch/OptSwapOut, backend replay, the
check_optim_region verifier lane, update numerics vs the resident AdamW
reference, and the serving admission accounting.
"""

import collections
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import MemoryPlanConfig, compile_plan
from repro.core.optim_offload import (OptimRuntime, compressed_nbytes,
                                      offloaded_update, optim_slot_specs,
                                      plan_optim_offload)
from repro.core.plan import Compute, ExecutionSchedule, OptPrefetch, OptSwapOut
from repro.core.verify import (CHECKS, schedules_equivalent, verify_schedule)
from repro.core.zoo import ZOO
from repro.optim.optimizers import adamw

CFG = dict(min_idle_phases=3, min_bytes=1 << 12)


def _compile(model="lenet5", batch=8, **kw):
    return compile_plan(ZOO[model](),
                        MemoryPlanConfig(optim_offload=True, **CFG, **kw),
                        batch=batch)


def _batch(g, n, seed=0, classes=10):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jax.random.randint(ky, (n,), 0, classes), classes)
    return x, y


# ---------------------------------------------------------------------------
# the plan: slots, arenas, pricing
# ---------------------------------------------------------------------------

def test_default_config_carries_no_optimizer_plan():
    cp = compile_plan(ZOO["lenet5"](), MemoryPlanConfig(**CFG), batch=8)
    assert cp.optim_plan is None
    assert cp.optim_device_bytes == 0
    assert not any(isinstance(op, (OptPrefetch, OptSwapOut))
                   for op in cp.lowered.ops)
    assert "optim" not in cp.report()


def test_slots_cover_every_trainable_layer():
    cp = _compile()
    g = ZOO["lenet5"]()
    opt = cp.optim_plan
    owners = {l.name for l in g.layers
              if l.trainable and l.weight_shapes()
              and not l.shares_weights_with}
    assert {s.layer for s in opt.slots} == owners
    for s in opt.slots:
        l = g.layer(s.layer)
        assert s.name == f"O:{s.layer}"
        assert s.nbytes == 2 * l.weight_nbytes()       # m and v, fp32
        assert s.n_elems == s.nbytes // 4
        assert s.host_nbytes == compressed_nbytes(s.n_elems)
        assert s.prefetch_eo <= s.read_eo < s.swapout_eo


def test_frozen_layers_get_no_slot():
    cp = compile_plan(ZOO["resnet18_transfer"](),
                      MemoryPlanConfig(optim_offload=True, **CFG), batch=8)
    g = ZOO["resnet18_transfer"]()
    frozen = {l.name for l in g.layers if not l.trainable}
    assert frozen, "transfer model must freeze its backbone"
    assert not frozen & {s.layer for s in cp.optim_plan.slots}


def test_plan_reduction_and_compressed_host_pool():
    cp = _compile("vgg16", batch=4)
    opt = cp.optim_plan
    opt.validate()
    # the acceptance floor is measured on vgg16: working region vs all-
    # resident moments, and int8+scales host copies vs the fp32 baseline
    assert opt.reduction_x >= 3.0
    assert opt.host_pool_bytes < opt.host_fp32_bytes
    assert opt.ef_residual_host_bytes > 0          # EF stays host-side
    assert opt.dma_bytes_per_step == sum(s.nbytes + s.host_nbytes
                                         for s in opt.slots)
    assert cp.report()["optim"]["reduction_x"] == opt.reduction_x


def test_uncompressed_plan_prices_fp32_host_copies():
    cp = compile_plan(ZOO["lenet5"](),
                      MemoryPlanConfig(optim_offload=True,
                                       optim_compress=False, **CFG), batch=8)
    opt = cp.optim_plan
    assert not opt.compress
    for s in opt.slots:
        assert s.host_nbytes == s.nbytes
    assert opt.ef_residual_host_bytes == 0
    assert opt.compress_flops_per_step == 0


# ---------------------------------------------------------------------------
# lowering + verification
# ---------------------------------------------------------------------------

def test_lowered_schedule_pairs_and_orders_opt_ops():
    cp = _compile()
    ops = cp.lowered.ops
    pre = [op for op in ops if isinstance(op, OptPrefetch)]
    out = [op for op in ops if isinstance(op, OptSwapOut)]
    assert len(pre) == len(out) == len(cp.optim_plan.slots)
    for p in pre:
        o = next(o for o in out if o.tensor == p.tensor)
        assert ops.index(p) < ops.index(o)
        # the prefetch is resident across the CG update that reads it
        assert p.eo <= p.read_eo < o.eo
        assert p.host_nbytes <= p.nbytes            # compressed H2D payload


def test_verifier_has_optim_region_check_and_passes():
    assert "optim_region" in CHECKS
    cp = _compile()
    rep = verify_schedule(cp.ordered, cp.schedule, cp.plan, cp.lowered)
    assert rep.ok and "optim_region" in rep.checks_run


def test_corrupt_opt_offset_caught_only_by_optim_region():
    cp = _compile()
    p = next(op for op in cp.lowered.ops if isinstance(op, OptPrefetch))
    from repro.core.planner import ALIGN
    forged = ExecutionSchedule(ops=tuple(
        dataclasses.replace(op, device_offset=op.device_offset + 2 * ALIGN)
        if op is p else op for op in cp.lowered.ops))
    rep = verify_schedule(cp.ordered, cp.schedule, cp.plan, forged)
    assert not rep.ok
    assert set(rep.check_ids()) == {"optim_region"}


# ---------------------------------------------------------------------------
# backend replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["sim", "async", "jit_blocks"])
def test_backends_replay_opt_ops(executor):
    cp = _compile()
    g = ZOO["lenet5"]()
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch(g, 8)
    _, _, stats = cp.loss_and_grads(params, x, y, executor=executor)
    n_slots = len(cp.optim_plan.slots)
    assert stats.opt_prefetches == n_slots
    assert stats.opt_swap_outs == n_slots
    assert stats.opt_dma_bytes == sum(s.nbytes + s.host_nbytes
                                      for s in cp.optim_plan.slots)
    assert stats.opt_device_high_water <= cp.optim_plan.device_peak_bytes
    if executor == "jit_blocks":
        assert (collections.Counter(stats.replayed_ops)
                == collections.Counter(cp.lowered.ops))
        assert schedules_equivalent(cp.lowered, stats.replayed_ops,
                                    ordered=cp.ordered, plan=cp.plan).ok
        n_comp = sum(isinstance(op, Compute) for op in cp.lowered.ops)
        assert stats.dispatch_calls < len(cp.lowered.ops)
        assert stats.dispatch_calls >= len(cp.lowered.ops) - n_comp
    else:
        assert stats.replayed_ops == cp.lowered.ops


# ---------------------------------------------------------------------------
# update numerics vs the resident AdamW reference
# ---------------------------------------------------------------------------

def test_offloaded_update_tracks_reference_within_tolerance():
    # compressed host copies with error feedback: both optimizers consume
    # the same gradient stream; the drift is pure compression error
    cp = _compile()
    g = ZOO["lenet5"]()
    params = cp.init_params(jax.random.PRNGKey(0))
    rt = OptimRuntime(cp.optim_plan, g)
    opt = adamw()
    state = opt.init(params)
    ref_p = off_p = params
    for step in range(8):
        x, y = _batch(g, 8, seed=100 + step)
        _, grads, _ = cp.loss_and_grads(ref_p, x, y, executor="sim")
        ref_p, state = opt.update(grads, state, ref_p)
        off_p = offloaded_update(rt, off_p, grads)
    drift = max(float(jnp.max(jnp.abs(ref_p[ln][wn] - off_p[ln][wn])))
                for ln in ref_p for wn in ref_p[ln])
    assert drift <= 2e-2, drift


def test_first_offloaded_step_decodes_exact_zero_state():
    # the host copy is stored in encoded (log-v) space: the first
    # prefetch must decode to exact zero moments, or step 1 already
    # diverges from the reference by O(1) in the v estimate
    cp = _compile()
    g = ZOO["lenet5"]()
    params = cp.init_params(jax.random.PRNGKey(0))
    rt = OptimRuntime(cp.optim_plan, g)
    x, y = _batch(g, 8, seed=7)
    _, grads, _ = cp.loss_and_grads(params, x, y, executor="sim")
    opt = adamw()
    ref_p, _ = opt.update(grads, opt.init(params), params)
    off_p = offloaded_update(rt, params, grads)
    err = max(float(jnp.max(jnp.abs(ref_p[ln][wn] - off_p[ln][wn])))
              for ln in ref_p for wn in ref_p[ln])
    assert err <= 1e-6, err


def test_uncompressed_offload_matches_reference_to_float_noise():
    cp = compile_plan(ZOO["lenet5"](),
                      MemoryPlanConfig(optim_offload=True,
                                       optim_compress=False, **CFG), batch=8)
    g = ZOO["lenet5"]()
    params = cp.init_params(jax.random.PRNGKey(0))
    rt = OptimRuntime(cp.optim_plan, g)
    opt = adamw()
    state = opt.init(params)
    ref_p = off_p = params
    for step in range(3):
        x, y = _batch(g, 8, seed=200 + step)
        _, grads, _ = cp.loss_and_grads(ref_p, x, y, executor="sim")
        ref_p, state = opt.update(grads, state, ref_p)
        off_p = offloaded_update(rt, off_p, grads)
    err = max(float(jnp.max(jnp.abs(ref_p[ln][wn] - off_p[ln][wn])))
              for ln in ref_p for wn in ref_p[ln])
    assert err <= 1e-5, err


def test_offloaded_update_counts_stats():
    from repro.core.exec.store import SwapExecStats
    cp = _compile()
    g = ZOO["lenet5"]()
    params = cp.init_params(jax.random.PRNGKey(0))
    rt = OptimRuntime(cp.optim_plan, g)
    stats = SwapExecStats()
    x, y = _batch(g, 8)
    _, grads, _ = cp.loss_and_grads(params, x, y, executor="sim")
    offloaded_update(rt, params, grads, stats)
    n = len(cp.optim_plan.slots)
    assert stats.opt_prefetches == n and stats.opt_swap_outs == n
    assert stats.opt_dma_bytes == cp.optim_plan.dma_bytes_per_step
    assert stats.opt_compressed_bytes == sum(
        s.host_nbytes for s in cp.optim_plan.slots)


# ---------------------------------------------------------------------------
# serving admission accounting
# ---------------------------------------------------------------------------

def test_serve_derives_optim_accounting():
    from repro.serve import PersonalizationService
    g = ZOO["lenet5"]()
    svc = PersonalizationService(
        g, buckets=(8,), max_live_sessions=4,
        config=MemoryPlanConfig(optim_offload=True, **CFG))
    svc.warmup()
    acct = svc.report()["optim_offload"]
    assert acct["share_bytes"] < acct["share_resident_bytes"]
    assert acct["sessions_in_resident_arena"] >= 4
    assert acct["sessions_per_arena_x"] >= 1.0
    assert acct["optim_device_bytes"] < acct["optim_resident_bytes"]


def test_serve_without_offload_reports_no_optim_accounting():
    from repro.serve import PersonalizationService
    g = ZOO["lenet5"]()
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=2,
                                 config=MemoryPlanConfig(**CFG))
    svc.warmup()
    assert "optim_offload" not in svc.report()
